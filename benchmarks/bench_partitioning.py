"""Benchmarks for the paper's §4.1 use-case table: kaffpa presets on mesh vs
social instances against baselines, KaBaPE strict balance, KaFFPaE budget
runs, ParHIP, plus comm-volume objective."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core.evolve import kaffpaE
from repro.core.initial import random_partition, bfs_grow_bisection
from repro.core.kabape import kabape_refine
from repro.core.kaffpa import kaffpa
from repro.core.parhip import parhip
from repro.core.partition import comm_volume, edge_cut, evaluate, is_feasible
from repro.io.generators import barabasi_albert, grid2d, random_geometric, rmat


def instances():
    return {
        "grid48": grid2d(48, 48),
        "geo4k": random_geometric(4096, seed=1),
        "ba4k": barabasi_albert(4096, 4, seed=1),
        "rmat11": rmat(11, 6, seed=1),
    }


def bench_kaffpa_presets(k: int = 8):
    for gname, g in instances().items():
        social = gname in ("ba4k", "rmat11")
        # baselines
        p_rand = random_partition(g, k, seed=0)
        row(f"baseline_random/{gname}/k{k}", 0, edge_cut(g, p_rand))
        presets = ("fastsocial", "ecosocial", "strongsocial") if social \
            else ("fast", "eco", "strong")
        for preset in presets:
            part, us = timed(kaffpa, g, k, 0.03, preset, 1)
            ev = evaluate(g, part, k)
            assert ev["feasible"], (gname, preset)
            row(f"kaffpa_{preset}/{gname}/k{k}", us, ev["cut"])


def bench_kabape():
    g = grid2d(32, 32)
    p = kaffpa(g, 4, 0.03, "fast", seed=2)
    out, us = timed(kabape_refine, g, p, 4, 0.0)
    row("kabape_eps0/grid32/k4", us,
        f"cut={edge_cut(g, out)};feasible={is_feasible(g, out, 4, 0.0)}")


def bench_kaffpaE(budget: float = 8.0):
    g = grid2d(32, 32)
    single = kaffpa(g, 4, 0.03, "fast", seed=3)
    evo, us = timed(kaffpaE, g, 4, 0.03, "fast", 2, 2, budget, 3)
    row("kaffpaE_8s/grid32/k4", us,
        f"evo_cut={edge_cut(g, evo)};single_cut={edge_cut(g, single)}")


def bench_comm_volume():
    g = barabasi_albert(2048, 4, seed=2)
    p_cut = kaffpaE(g, 8, 0.03, "fastsocial", 2, 2, 4.0, 1)
    p_vol = kaffpaE(g, 8, 0.03, "fastsocial", 2, 2, 4.0, 1,
                    optimize_comm_volume=True)
    row("kaffpaE_maxvol/ba2k/k8", 0,
        f"vol_opt={comm_volume(g, p_vol, 8).max()};"
        f"cut_opt={comm_volume(g, p_cut, 8).max()}")


def bench_parhip():
    for gname, g in (("grid48", grid2d(48, 48)),
                     ("ba4k", barabasi_albert(4096, 4, seed=1))):
        pre = "fastsocial" if gname == "ba4k" else "fastmesh"
        part, us = timed(parhip, g, 8, 0.03, pre, 1)
        ev = evaluate(g, part, 8)
        row(f"parhip_{pre}/{gname}/k8", us, ev["cut"])


def main():
    bench_kaffpa_presets()
    bench_kabape()
    bench_kaffpaE()
    bench_comm_volume()
    bench_parhip()


if __name__ == "__main__":
    main()
