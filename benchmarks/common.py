"""Shared benchmark helpers: timing + CSV emission.

Every bench prints ``name,us_per_call,derived`` rows (derived = the
paper-relevant quality metric: cut, replication, QAP, fill-in, …).
"""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.0f},{derived}"
    print(line, flush=True)
    return line
