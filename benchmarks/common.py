"""Shared benchmark helpers: timing, run metadata + CSV emission.

Every bench prints ``name,us_per_call,derived`` rows (derived = the
paper-relevant quality metric: cut, replication, QAP, fill-in, …) and
stamps its JSON report with ``run_metadata()`` so BENCH_*.json artifacts
record which jax/backend/host produced them.
"""
from __future__ import annotations

import time


def _block(out):
    """Wait for any async device work hiding in ``out`` (pytree-safe).

    JAX dispatch is asynchronous: without this, a timed region can stop
    the clock while the device is still computing.  Works on arbitrary
    pytrees and is a no-op for host values (numpy arrays, scalars).
    """
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return out


def timed(fn, *args, repeat: int = 1, **kw):
    """Run ``fn`` ``repeat`` times, blocking on the result each time, and
    return ``(last_out, mean_microseconds)``."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = _block(fn(*args, **kw))
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def timed_call(fn, *args, **kw):
    """Single synchronized call → ``(out, seconds)``."""
    t0 = time.perf_counter()
    out = _block(fn(*args, **kw))
    return out, time.perf_counter() - t0


def peak_rss_mb() -> float:
    """Peak resident set size of this process in MiB (VmHWM), or 0.0 when
    /proc is unavailable — the host-memory column of the scale benches."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return round(int(line.split()[1]) / 1024.0, 1)
    except OSError:
        pass
    return 0.0


def span_seconds(events, name: str) -> float:
    """Total seconds spent inside ``name`` spans of an ``obs.Recorder``
    event list (sums every matched B→E pair; ts is µs)."""
    total, stack = 0.0, []
    for ev in events:
        if ev.get("name") != name:
            continue
        if ev.get("ph") == "B":
            stack.append(ev["ts"])
        elif ev.get("ph") == "E" and stack:
            total += ev["ts"] - stack.pop()
    return total / 1e6


def run_metadata() -> dict:
    """Environment stamp for BENCH_*.json reports (DESIGN.md §11)."""
    import datetime
    import platform
    import socket
    meta = {
        "hostname": socket.gethostname(),
        "python": platform.python_version(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        import jaxlib
        meta.update(jax=jax.__version__, jaxlib=jaxlib.__version__,
                    backend=jax.default_backend(),
                    device_count=jax.device_count())
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        pass
    return meta


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.0f},{derived}"
    print(line, flush=True)
    return line
