"""Node-separator benchmark → ``BENCH_nodesep.json``.

Multilevel separator engine (core/nodesep) vs the post-hoc baseline
(KaFFPa bipartition + boundary vertex cover, core/separator.py) on three
fixed seeded instances × eps ∈ {0.05, 0.20}.  Records wall-clock and the
achieved separator weight per cell so the quality/perf trajectory is
tracked across PRs.  Invoked by ``python benchmarks/run.py --smoke`` (CI)
or directly.
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import run_metadata, timed_call
except ImportError:                      # direct: python benchmarks/bench_nodesep.py
    from common import run_metadata, timed_call

EPS = (0.05, 0.20)
SEED = 1
PRESET = "eco"


def _instances():
    from repro.io.generators import (barabasi_albert, grid2d,
                                     random_geometric)
    return {
        "grid32": grid2d(32, 32),
        "ba1k": barabasi_albert(1024, 4, seed=3),
        "geo1k": random_geometric(1024, seed=5),
    }


def collect() -> dict:
    from repro.core.nodesep import (nodesep_labels, separator_invariant_ok,
                                    separator_is_feasible, separator_weight)
    from repro.core.separator import node_separator, verify_separator

    res = {}
    for name, g in _instances().items():
        for eps in EPS:
            labels, ml_s = timed_call(nodesep_labels, g, eps, PRESET,
                                      seed=SEED)
            ml_w = separator_weight(g, labels)
            ml_ok = bool(separator_invariant_ok(g, labels)
                         and separator_is_feasible(g, labels, eps))
            (sep, part), ph_s = timed_call(node_separator, g, eps, PRESET,
                                           seed=SEED)
            ph_w = int(g.vwgt[sep].sum())
            ph_ok = bool(verify_separator(g, part, sep, 2))
            res[f"{name}_eps{eps:g}"] = {
                "ml_s": round(ml_s, 2), "ml_w": ml_w, "ml_ok": ml_ok,
                "posthoc_s": round(ph_s, 2), "posthoc_w": ph_w,
                "posthoc_ok": ph_ok,
            }
    return res


def main(out_path: str = "BENCH_nodesep.json") -> dict:
    cells = collect()
    # only a valid (feasible + separating) result may count as a win/tie
    wins = sum(c["ml_ok"] and c["ml_w"] < c["posthoc_w"]
               for c in cells.values())
    ties = sum(c["ml_ok"] and c["ml_w"] == c["posthoc_w"]
               for c in cells.values())
    report = {"nodesep": cells,
              "summary": {"cells": len(cells), "ml_strictly_better": wins,
                          "ties": ties,
                          "ml_never_worse": wins + ties == len(cells)},
              "meta": run_metadata()}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in cells.items():
        print(f"{name}: ml w={cell['ml_w']} ({cell['ml_s']}s) vs "
              f"posthoc w={cell['posthoc_w']} ({cell['posthoc_s']}s)",
              flush=True)
    print(f"summary: {report['summary']}")
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
