"""Hypergraph partitioning benchmarks: kahypar presets vs the classical
star-expansion-through-kaffpa baseline and random assignment.

Rows report wall-clock and the connectivity (λ−1) objective (cut-net for
the cut rows) on planted and uniform-random instances.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core.hypergraph import (connectivity, cut_net, is_feasible,
                                   kahypar, star_expansion)
from repro.core.hypergraph.initial import random_partition
from repro.core.kaffpa import kaffpa
from repro.io.generators import planted_hypergraph, random_hypergraph


def instances():
    return {
        "hplant2k": planted_hypergraph(2048, 3072, blocks=8, seed=1),
        "hrand1k": random_hypergraph(1024, 1536, seed=1),
    }


def star_baseline(hg, k: int, eps: float, seed: int) -> np.ndarray:
    """Partition the star expansion with kaffpa; read off real vertices."""
    g = star_expansion(hg)
    part = kaffpa(g, k, eps, "eco", seed=seed)
    return part[:hg.n]


def bench_kahypar(k: int = 8):
    for name, hg in instances().items():
        p_rand = random_partition(hg, k, seed=0)
        row(f"baseline_random/{name}/k{k}", 0, connectivity(hg, p_rand))
        part, us = timed(star_baseline, hg, k, 0.03, 1)
        row(f"baseline_star_kaffpa/{name}/k{k}", us, connectivity(hg, part))
        for preset in ("fast", "eco"):
            part, us = timed(kahypar, hg, k, 0.03, preset, 1)
            assert is_feasible(hg, part, k, 0.03), (name, preset)
            row(f"kahypar_{preset}/{name}/k{k}", us, connectivity(hg, part))
        part, us = timed(kahypar, hg, k, 0.03, "eco", 1, "cut")
        row(f"kahypar_eco_cut/{name}/k{k}", us, cut_net(hg, part))


def main():
    bench_kahypar(k=8)


if __name__ == "__main__":
    main()
