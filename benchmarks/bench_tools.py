"""Benchmarks for separators (§2.8), edge partitioning (§2.7), node ordering
(§2.9), process mapping (§2.6) and the exact solver (§2.10)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core.csr import Graph
from repro.core.edgepart import edge_partition, naive_edge_partition
from repro.core.ilp import ilp_exact, ilp_improve
from repro.core.kaffpa import kaffpa
from repro.core.mapping import (process_mapping, processor_distance_matrix,
                                qap_cost)
from repro.core.ordering import fast_reduced_nd, fill_in, reduced_nd, \
    _min_degree_order
from repro.core.partition import edge_cut, edge_partition_metrics
from repro.core.separator import node_separator, \
    partition_to_vertex_separator, verify_separator
from repro.io.generators import barabasi_albert, grid2d, grid3d, \
    random_geometric


def bench_separator():
    for gname, g in (("grid32", grid2d(32, 32)),
                     ("geo2k", random_geometric(2048, seed=3))):
        (sep, part), us = timed(node_separator, g, 0.2, "fast", 1)
        assert verify_separator(g, part, sep, 2)
        src = g.edge_sources()
        cutedge = part[src] != part[g.adjncy]
        triv = min(len(np.unique(src[cutedge & (part[src] == 0)])),
                   len(np.unique(src[cutedge & (part[src] == 1)])))
        row(f"separator_2way/{gname}", us, f"sep={len(sep)};boundary={triv}")
        p4 = kaffpa(g, 4, 0.03, "fast", seed=1)
        sep4, us4 = timed(partition_to_vertex_separator, g, p4, 4)
        assert verify_separator(g, p4, sep4, 4)
        row(f"separator_4way/{gname}", us4, len(sep4))


def bench_edge_partition():
    for gname, g in (("grid32", grid2d(32, 32)),
                     ("ba2k", barabasi_albert(2048, 4, seed=1))):
        preset = "fastsocial" if gname == "ba2k" else "fast"
        ep, us = timed(edge_partition, g, 8, 0.05, preset, 1000, 1)
        m = edge_partition_metrics(g, ep, 8)
        nv = edge_partition_metrics(g, naive_edge_partition(g, 8), 8)
        row(f"edgepart_spac/{gname}/k8", us,
            f"repl={m['replication']:.3f};naive={nv['replication']:.3f}")


def bench_ordering():
    for gname, g in (("grid16", grid2d(16, 16)), ("grid3d8", grid3d(8, 8, 8))):
        order, us = timed(fast_reduced_nd, g, 1)
        fnd = fill_in(g, order)
        fnat = fill_in(g, np.arange(g.n))
        fmd = fill_in(g, _min_degree_order(g))
        row(f"ordering_nd/{gname}", us,
            f"fill={fnd};natural={fnat};mindeg={fmd}")


def bench_mapping():
    rng = np.random.default_rng(0)
    k = 64
    comm = np.zeros((k, k), dtype=np.int64)
    perm = rng.permutation(k)
    for c in range(8):                       # 8 chatty groups of 8
        ids = perm[c * 8:(c + 1) * 8]
        for i in ids:
            for j in ids:
                if i != j:
                    comm[i, j] = rng.integers(50, 150)
    comm = (comm + comm.T) // 2
    hierarchy, dists = [4, 4, 4], [1, 10, 100]
    dist = processor_distance_matrix(hierarchy, dists)
    mapping, us = timed(process_mapping, comm, hierarchy, dists)
    q_map = qap_cost(comm, dist, mapping)
    q_id = qap_cost(comm, dist, np.arange(k))
    q_rnd = qap_cost(comm, dist, rng.permutation(k))
    row("process_mapping/64proc", us,
        f"qap={q_map};identity={q_id};random={q_rnd}")


def bench_exact():
    # ring: known optimum
    n = 12
    ring = Graph.from_edges(n, np.arange(n), (np.arange(n) + 1) % n)
    part, us = timed(ilp_exact, ring, 3, 0.0, 30, 1)
    row("ilp_exact/ring12/k3", us, f"cut={edge_cut(ring, part)};opt=3")
    g = grid2d(12, 12)
    p0 = kaffpa(g, 4, 0.03, "fast", seed=4)
    p1, us = timed(ilp_improve, g, p0, 4)
    row("ilp_improve/grid12/k4", us,
        f"before={edge_cut(g, p0)};after={edge_cut(g, p1)}")


def main():
    bench_separator()
    bench_edge_partition()
    bench_ordering()
    bench_mapping()
    bench_exact()


if __name__ == "__main__":
    main()
