"""Memetic-vs-single-run smoke → ``BENCH_memetic.json``.

Runs the memetic partitioners through their C-API interface entries
(``interface.kahyparE`` for both objectives, ``interface.kaffpaE``)
against a single run of the corresponding partitioner at the same preset
and seed.  The memetic side runs a *deterministic* generation budget
(``GENERATIONS``) rather than a wall clock, so the gate cannot flake
with runner speed; both sides' wall times are recorded to show the
budgets are comparable.  Island 0's first member rides exactly the
single run's seed (``multilevel.population`` applies the preset's full
V-cycle schedule) and the island driver never replaces with a worse
individual, so the memetic result is structurally never worse; the gate
additionally requires at least one strict improvement per kahyparE
objective (the acceptance criterion).  Invoked by ``python
benchmarks/run.py --smoke`` (CI) or directly.
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import run_metadata, timed_call as _timed
except ImportError:                      # direct: python benchmarks/bench_memetic.py
    from common import run_metadata, timed_call as _timed

GENERATIONS = 3              # deterministic memetic budget per smoke cell


def collect() -> dict:
    import numpy as np                                   # noqa: F401
    from repro.core import interface
    from repro.core.hypergraph import connectivity, cut_net
    from repro.core.hypergraph import metrics as HM
    from repro.core.partition import edge_cut, is_feasible
    from repro.io.generators import (grid2d, planted_hypergraph,
                                     random_hypergraph)

    res = {}
    hp = planted_hypergraph(200, 300, blocks=4, seed=11)
    hr = random_hypergraph(256, 384, seed=5)
    for name, hg, k, objective in [
        ("kahyparE_km1_hp200_k4", hp, 4, "km1"),
        ("kahyparE_km1_hr256_k2", hr, 2, "km1"),
        ("kahyparE_cut_hp200_k4", hp, 4, "cut"),
        ("kahyparE_cut_hr256_k2", hr, 2, "cut"),
    ]:
        score = connectivity if objective == "km1" else cut_net
        (obj_s, part_s), dt_s = _timed(
            interface.kahypar, hg.n, hg.m, None, None, hg.eptr, hg.eind, k,
            0.03, seed=1, mode=interface.FAST, objective=objective)
        (obj_e, part_e), dt_e = _timed(
            interface.kahyparE, hg.n, hg.m, None, None, hg.eptr, hg.eind, k,
            0.03, generations=GENERATIONS, seed=1, mode=interface.FAST,
            objective=objective, n_islands=2, population=2)
        assert obj_e == score(hg, part_e), name
        assert HM.is_feasible(hg, part_e, k, 0.03), name
        assert obj_e <= obj_s, (name, obj_e, obj_s)
        res[name] = {"objective": objective, "s_mem": round(dt_e, 2),
                     "obj_mem": obj_e, "s_single": round(dt_s, 2),
                     "obj_single": obj_s,
                     "ratio": round(obj_e / max(obj_s, 1), 4)}
    for objective in ("km1", "cut"):
        wins = [n for n, c in res.items()
                if c["objective"] == objective and c["obj_mem"] < c["obj_single"]]
        assert wins, f"no strict kahyparE improvement for {objective}"

    g = grid2d(20, 20)
    (cut_s, part_s), dt_s = _timed(
        interface.kaffpa, g.n, None, g.xadj, None, g.adjncy, 4, 0.03,
        seed=1, mode=interface.FAST)
    (cut_e, part_e), dt_e = _timed(
        interface.kaffpaE, g.n, None, g.xadj, None, g.adjncy, 4, 0.03,
        generations=GENERATIONS, seed=1, mode=interface.FAST, n_islands=2,
        population=2)
    assert is_feasible(g, part_e, 4, 0.03)
    assert cut_e <= cut_s, (cut_e, cut_s)
    res["kaffpaE_grid20_k4"] = {"objective": "cut", "s_mem": round(dt_e, 2),
                                "obj_mem": cut_e, "s_single": round(dt_s, 2),
                                "obj_single": cut_s,
                                "ratio": round(cut_e / max(cut_s, 1), 4)}

    # batched vs sequential island generations (DESIGN.md §12): per-island
    # sweep keys make the two modes bit-identical, so the cell isolates the
    # cost of stepping the archipelago one island at a time vs one vmapped
    # device call per generation
    import dataclasses as _dc
    from repro.core import memetic as MEM
    from repro.core.kaffpa import GraphMedium, PRESETS
    cfg = MEM.MemeticConfig(n_islands=4, population=2, time_limit=0.0,
                            generations=GENERATIONS)
    cfg_seq = _dc.replace(cfg, batched_generations=False)
    # warm both modes' programs first: at 3 generations a single cold
    # compile would swamp the per-generation device-call cost under test
    MEM.evolve_islands(GraphMedium(g, PRESETS["fast"]), 4, 0.03, cfg_seq, 1)
    MEM.evolve_islands(GraphMedium(g, PRESETS["fast"]), 4, 0.03, cfg, 1)
    st_seq, dt_seq = _timed(
        MEM.evolve_islands, GraphMedium(g, PRESETS["fast"]), 4, 0.03,
        cfg_seq, 1)
    st_bat, dt_bat = _timed(
        MEM.evolve_islands, GraphMedium(g, PRESETS["fast"]), 4, 0.03, cfg, 1)
    assert all(np.array_equal(a.part, b.part)
               for pa, pb in zip(st_bat.islands, st_seq.islands)
               for a, b in zip(pa, pb)), "batched generations changed state"
    res["island_gen_batched_vs_seq_grid20_k4"] = {
        "objective": "cut", "s_batched": round(dt_bat, 2),
        "s_sequential": round(dt_seq, 2),
        "obj": st_bat.best().fitness,
        "islands": cfg.n_islands}
    return res


def main(out_path: str = "BENCH_memetic.json") -> dict:
    report = {"memetic": collect(), "generations": GENERATIONS,
              "meta": run_metadata()}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in report["memetic"].items():
        print(f"{name}: {cell}", flush=True)
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
