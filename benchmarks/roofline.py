"""Roofline analysis (assignment deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch × shape) on the single-pod mesh:

  compute term    = HLO_FLOPs / (chips × 197 TF/s)
  memory term     = HLO_bytes / (chips × 819 GB/s)
  collective term = collective_bytes / (chips × 50 GB/s)

HLO numbers are the trip-count-corrected module totals (see dryrun.py —
XLA counts while bodies once; dryrun extrapolates from 1/2-layer variants).
cost_analysis is per-device on the SPMD module, so totals are ×chips; the
per-chip terms below therefore divide by 1 (the numbers are already
per-chip).  Collective bytes are per-device operand bytes from the HLO —
each chip moves ~that many bytes over its links.

Emits a markdown table + CSV and identifies the dominant term, the
MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line lever per cell.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(result_dir: str = RESULTS, mesh: str = "single",
               tag: str | None = None):
    cells = {}
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        parts = os.path.basename(path)[:-5].split("__")
        if len(parts) == 3:
            arch, shape, mk = parts
            t = None
        else:
            arch, shape, mk, t = parts
        if mk != mesh or t != tag:
            continue
        with open(path) as f:
            cells[(arch, shape)] = json.load(f)
    return cells


def terms(rec: dict) -> dict:
    """Per-chip roofline terms in seconds (cost numbers are per-device)."""
    compute = rec["hlo_flops"] / PEAK_FLOPS
    memory = rec["hlo_bytes"] / HBM_BW
    collective = rec["collective_bytes"] / LINK_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    useful = rec["model_flops"] / max(rec["hlo_flops"] * rec["n_chips"], 1.0)
    # roofline fraction: useful model flops per chip-second at the bound
    bound = max(compute, memory, collective)
    frac = (rec["model_flops"] / rec["n_chips"] / PEAK_FLOPS) / bound \
        if bound > 0 else 0.0
    return dict(compute_s=compute, memory_s=memory, collective_s=collective,
                dominant=dom[0], bound_s=bound, useful_ratio=useful,
                roofline_frac=frac)


LEVERS = {
    "compute": "reduce non-model FLOPs (remat policy, attention blocking) or "
               "raise MXU utilization via tile-aligned shapes",
    "memory": "fuse elementwise chains / cast to bf16 / shrink remat-saved "
              "activations so HBM traffic approaches 2×params+activations",
    "collective": "reshard to cut all-gather volume (bigger per-chip blocks),"
                  " overlap collectives with compute, or compress gradients",
}


def render(cells: dict, out_md: str | None = None, out_csv: str | None = None):
    lines_md = ["| arch | shape | kind | compute s | memory s | coll s | "
                "dominant | MODEL/HLO | roofline frac | lever |",
                "|---|---|---|---|---|---|---|---|---|---|"]
    lines_csv = ["arch,shape,kind,compute_s,memory_s,collective_s,dominant,"
                 "useful_ratio,roofline_frac"]
    for (arch, shape), rec in sorted(cells.items()):
        if "skipped" in rec:
            lines_md.append(f"| {arch} | {shape} | — | — | — | — | "
                            f"skip | — | — | {rec['skipped'][:60]} |")
            lines_csv.append(f"{arch},{shape},skip,,,,,,")
            continue
        t = terms(rec)
        lines_md.append(
            f"| {arch} | {shape} | {rec['kind']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_frac']:.2%} | {LEVERS[t['dominant']][:60]} |")
        lines_csv.append(
            f"{arch},{shape},{rec['kind']},{t['compute_s']:.6f},"
            f"{t['memory_s']:.6f},{t['collective_s']:.6f},{t['dominant']},"
            f"{t['useful_ratio']:.3f},{t['roofline_frac']:.4f}")
    md = "\n".join(lines_md)
    csv = "\n".join(lines_csv)
    if out_md:
        with open(out_md, "w") as f:
            f.write(md + "\n")
    if out_csv:
        with open(out_csv, "w") as f:
            f.write(csv + "\n")
    return md, csv


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else None
    cells = load_cells(tag=tag)
    if not cells:
        print("no dry-run results found — run repro.launch.dryrun first")
        return
    md, csv = render(cells,
                     out_md=os.path.join(RESULTS, "..", "roofline.md"),
                     out_csv=os.path.join(RESULTS, "..", "roofline.csv"))
    print(md)


if __name__ == "__main__":
    main()
