"""Benchmark driver (deliverable d): one section per paper table/use-case.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline tables (deliverable
g) are produced by ``benchmarks/roofline.py`` from the dry-run artifacts.

``python benchmarks/run.py --smoke`` runs the end-to-end engine benchmark,
the node-separator benchmark, the distributed-hypergraph smoke, the
memetic smoke and the serve-telemetry smoke, writing ``BENCH_engine.json``,
``BENCH_nodesep.json``, ``BENCH_parhyp.json``, ``BENCH_memetic.json`` and
``BENCH_serve_obs.json`` (+ ``BENCH_serve_trace.json``, the Perfetto
serve timeline) — the CI perf-trajectory records.

``--scale`` / ``--scale-smoke`` run the million-vertex (resp. ~130k CI)
``parhyp_scale`` cells.  Host/runtime flags for scale runs are set up
*before* jax is imported:

* ``--devices N`` → ``--xla_force_host_platform_device_count=N`` (SPMD
  over N fake CPU devices);
* ``JAX_ENABLE_X64=0`` / ``JAX_DEFAULT_DTYPE_BITS=32`` defaulted (the
  engine is f32/int32 end to end);
* ``--tcmalloc`` → re-exec with ``LD_PRELOAD=libtcmalloc`` and a high
  ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — glibc malloc returns the
  multi-GB coarsening arenas to the OS poorly at 1M+ vertices.
"""
from __future__ import annotations

import os
import sys

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path — pin the root so `from benchmarks import …` always resolves
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

_TCMALLOC = "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4"


def _setup_env(argv: list) -> list:
    """Consume env-shaping flags; must run before any jax import."""
    args = list(argv)
    if "--devices" in args:
        i = args.index("--devices")
        n = int(args[i + 1])
        del args[i:i + 2]
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    os.environ.setdefault("JAX_ENABLE_X64", "0")
    os.environ.setdefault("JAX_DEFAULT_DTYPE_BITS", "32")
    if "--tcmalloc" in args:
        args.remove("--tcmalloc")
        if (not os.environ.get("_REPRO_TCMALLOC")
                and os.path.exists(_TCMALLOC)):
            env = dict(os.environ, LD_PRELOAD=_TCMALLOC,
                       TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="60000000000",
                       _REPRO_TCMALLOC="1")
            os.execve(sys.executable, [sys.executable] + sys.argv, env)
    return args


def scale(smoke_run: bool) -> None:
    from benchmarks import bench_parhyp
    bench_parhyp.scale_main(smoke=smoke_run)


def smoke() -> None:
    from benchmarks import (bench_engine, bench_memetic, bench_nodesep,
                            bench_parhyp, bench_serve_obs)
    eng = bench_engine.main()
    # compile-count columns (DESIGN.md §12): per cell, cold-run backend
    # compiles plus the shape-bucket registry's padding/sharing counters
    print("cell,compile_count,bucket_pads,compile_cache_hits,s")
    for name, cell in eng["engine"].items():
        print(f"{name},{cell['compile_count']},{cell['bucket_pads']},"
              f"{cell['compile_cache_hits']},{cell['s']}")
    bench_nodesep.main()
    bench_parhyp.main()
    bench_memetic.main()
    bench_serve_obs.main()


def main() -> None:
    from benchmarks import (bench_partitioning, bench_tools, bench_kernels,
                            bench_hypergraph)
    print("name,us_per_call,derived")
    print("# --- kaffpa presets / kabape / kaffpaE / parhip (paper §2.1-2.5)")
    bench_partitioning.main()
    print("# --- separators / edge partitioning / ordering / mapping / ILP "
          "(paper §2.6-2.10)")
    bench_tools.main()
    print("# --- multilevel node separators vs post-hoc baseline (§2.8)")
    from benchmarks import bench_nodesep
    bench_nodesep.main()
    print("# --- hypergraph partitioning (kahypar vs star-expansion baseline)")
    bench_hypergraph.main()
    print("# --- distributed hypergraph partitioning (parhyp vs kahypar)")
    from benchmarks import bench_parhyp
    bench_parhyp.main()
    print("# --- memetic engine (kahyparE/kaffpaE vs single runs)")
    from benchmarks import bench_memetic
    bench_memetic.main()
    print("# --- kernels (DESIGN.md §6)")
    bench_kernels.main()
    print("# --- roofline (from dry-run artifacts, if present)")
    try:
        from benchmarks import roofline
        cells = roofline.load_cells()
        if cells:
            md, _ = roofline.render(cells)
            for ln in md.splitlines():
                print("#", ln)
        else:
            print("# (no dry-run artifacts; run python -m repro.launch.dryrun"
                  " --all)")
    except Exception as e:  # pragma: no cover
        print(f"# roofline unavailable: {e}")


if __name__ == "__main__":
    _args = _setup_env(sys.argv[1:])
    if "--scale-smoke" in _args:
        scale(smoke_run=True)
    elif "--scale" in _args:
        scale(smoke_run=False)
    elif "--smoke" in _args:
        smoke()
    else:
        main()
