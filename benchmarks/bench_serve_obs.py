"""Serve-path telemetry smoke → ``BENCH_serve_obs.json`` (+ Chrome trace).

Three cells:

* ``serve_replay_minicpm`` — a bursty request stream through the
  continuous batcher twice: bare, and with `ServeTelemetry` recording onto
  a `Recorder`.  Gates: token streams bit-identical, telemetry overhead
  bounded (CI enforces ≤ 1.05× + absolute slack), and the per-slot request
  timeline exports as a Perfetto-loadable Chrome trace
  (``BENCH_serve_trace.json``) with balanced spans.
* ``traffic_drift_flip`` — scripted traffic skew: co-activation pairs flip
  from block-local to stride-residue patterns.  Gates: the drift score
  crosses the advise threshold, ``serve/repartition_advised`` fires, and
  repartitioning the snapshotted traffic hypergraph with ``kahypar``
  strictly beats the stale partition on observed-traffic (λ−1).
* ``serve_moe_traffic`` — a real MoE serve run (deepseek_v2 reduced) with
  ``moe.observe_gates`` streaming routing decisions into a
  `TrafficAccumulator`; the observed window snapshots to a valid
  `Hypergraph` and partitions.

Invoked by ``python benchmarks/run.py --smoke`` (CI) or directly.
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import run_metadata, timed_call as _timed
except ImportError:              # direct: python benchmarks/bench_serve_obs.py
    from common import run_metadata, timed_call as _timed

TRACE_PATH = "BENCH_serve_trace.json"

STREAM = [
    (0, [1, 2, 3], 6), (0, [4, 5], 5), (0, [6, 7, 8, 9], 6),
    (2, [2, 3, 4], 4), (4, [5, 6], 6), (4, [7, 8, 9], 5),
    (7, [1, 9, 2, 8], 4), (9, [3, 3, 3], 5),
]


def _serve_replay() -> dict:
    import numpy as np                                   # noqa: F401
    import jax
    from repro import obs
    from repro.configs.base import get_config
    from repro.models import transformer as T
    from repro.serve.batching import serve_stream

    cfg = get_config("minicpm_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    run = lambda tele=None: serve_stream(                # noqa: E731
        params, cfg, STREAM, batch_slots=4, max_len=32, telemetry=tele)

    run()                                    # warm the compile caches
    plain, s_plain = _timed(run)

    rec = obs.Recorder("serve")
    tele = obs.ServeTelemetry(recorder=rec)
    traced, s_tele = _timed(run, tele)

    outs_plain = [r.out for r in plain]
    outs_tele = [r.out for r in traced]
    assert outs_plain == outs_tele, "telemetry changed served tokens"
    assert all(r.done for r in traced)

    # balanced per-slot spans → Perfetto renders one row per slot
    slot_evs = [e for e in rec.events
                if str(e.get("track", "")).startswith("slot")]
    n_b = sum(e["ph"] == "B" for e in slot_evs)
    n_e = sum(e["ph"] == "E" for e in slot_evs)
    assert n_b == n_e > 0, (n_b, n_e)
    n_trace = obs.write_chrome_trace([rec], TRACE_PATH, registry_gauges=True)

    snap = tele.snapshot()
    assert snap["total_requests"] == len(STREAM)
    assert {"queue_us", "prefill_us", "decode_us", "e2e_us"} \
        <= set(snap["latency_us"])
    return {
        "s_plain": round(s_plain, 3), "s_telemetry": round(s_tele, 3),
        "overhead_ratio": round(s_tele / max(s_plain, 1e-9), 4),
        "requests": snap["total_requests"],
        "tokens": snap["total_tokens"],
        "latency_us": {k: {q: round(v, 1) for q, v in d.items()}
                       for k, d in snap["latency_us"].items()},
        "trace_events": n_trace, "trace_path": TRACE_PATH,
        "bit_identical": outs_plain == outs_tele,
    }


def _traffic_drift_flip() -> dict:
    import numpy as np
    from repro import obs
    from repro.core.hypergraph import connectivity, kahypar
    from repro.obs.live import TrafficAccumulator

    n_e, k_parts = 64, 8
    rng = np.random.default_rng(0)
    acc = TrafficAccumulator(n_e, decay=0.9)

    def block_pairs(t):                 # phase A: pairs inside 8-blocks
        g = rng.integers(0, k_parts, t)
        a, b = rng.integers(0, 8, (2, t))
        b = (a + 1 + (b % 7)) % 8       # distinct within the block
        return np.stack([g * 8 + a, g * 8 + b], axis=1)

    def stride_pairs(t):                # phase B: pairs inside residues mod 8
        r = rng.integers(0, 8, t)
        a, b = rng.integers(0, 8, (2, t))
        b = (a + 1 + (b % 7)) % 8
        return np.stack([r + 8 * a, r + 8 * b], axis=1)

    for _ in range(40):
        acc.observe(block_pairs(64))
    acc.set_baseline()
    hg_base = acc.snapshot()
    part_stale = kahypar(hg_base, k_parts, 0.03, "eco", seed=0)
    drift_cal = acc.drift()
    assert drift_cal < 0.1, drift_cal

    for _ in range(120):                # the skew flips
        acc.observe(stride_pairs(64))
    rec = obs.Recorder("drift")
    drift = acc.drift()
    advised = acc.advise(rec, threshold=0.3)
    assert drift > 0.3 and advised, drift

    hg_new = acc.snapshot()
    km1_stale = connectivity(hg_new, part_stale)
    part_fresh = kahypar(hg_new, k_parts, 0.03, "eco", seed=0)
    km1_fresh = connectivity(hg_new, part_fresh)
    # repartitioning on live traffic must strictly beat the stale layout
    assert km1_fresh < km1_stale, (km1_fresh, km1_stale)
    return {
        "n_items": n_e, "k": k_parts,
        "drift_calibration": round(drift_cal, 4),
        "drift_after_flip": round(drift, 4), "advised": bool(advised),
        "km1_stale": int(km1_stale), "km1_fresh": int(km1_fresh),
        "traffic_ratio": round(km1_fresh / max(km1_stale, 1), 4),
    }


def _serve_moe_traffic() -> dict:
    import jax
    from repro.configs.base import get_config
    from repro.core.hypergraph import connectivity, kahypar
    from repro.models import moe
    from repro.models import transformer as T
    from repro.obs.live import TrafficAccumulator
    from repro.serve.batching import serve_requests

    cfg = get_config("deepseek_v2_236b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    acc = TrafficAccumulator(cfg.n_experts, decay=1.0)
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8]]
    with moe.observe_gates(acc):
        (reqs, ), s = _timed(lambda: (serve_requests(
            params, cfg, prompts, batch_slots=2, max_len=16, max_new=3),))
    assert all(r.done for r in reqs)
    assert acc.events > 0, "gate observer saw no routing traffic"
    hg = acc.snapshot()
    hg.check()
    part = kahypar(hg, 2, 0.03, "fast", seed=0)
    return {
        "model": cfg.name, "experts": cfg.n_experts, "top_k": cfg.top_k,
        "gate_events": int(acc.events), "nets": int(hg.m),
        "km1": int(connectivity(hg, part)), "s": round(s, 3),
    }


def collect() -> dict:
    return {
        "serve_replay_minicpm": _serve_replay(),
        "traffic_drift_flip": _traffic_drift_flip(),
        "serve_moe_traffic": _serve_moe_traffic(),
    }


def main(out_path: str = "BENCH_serve_obs.json") -> dict:
    report = {"serve_obs": collect(), "meta": run_metadata()}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in report["serve_obs"].items():
        print(f"{name}: {cell}", flush=True)
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
