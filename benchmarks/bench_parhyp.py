"""Distributed-vs-sequential hypergraph smoke → ``BENCH_parhyp.json``.

Runs ``parhyp`` (the shard_map distributed partitioner, on a mesh over all
local devices — one device in CI) against sequential ``kahypar`` at an
equal quality budget (same engine preset, same instances/seeds), recording
wall-clock and the (λ−1) objective.  Asserts the acceptance criterion:
distributed quality within 5% of sequential on every cell.  Invoked by
``python benchmarks/run.py --smoke`` (CI) or directly.
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import run_metadata, timed_call as _timed
except ImportError:                      # direct: python benchmarks/bench_parhyp.py
    from common import run_metadata, timed_call as _timed

QUALITY_SLACK = 1.05         # distributed ≤ 5% over sequential (smoke gate)


def cells():
    from repro.io.generators import planted_hypergraph, random_hypergraph
    hp = planted_hypergraph(400, 600, blocks=4, seed=11)
    hr = random_hypergraph(512, 768, seed=5)
    return [
        ("parhyp_eco_hp400_k4", hp, 4, "eco"),
        ("parhyp_eco_hp400_k2", hp, 2, "eco"),
        ("parhyp_fast_hr512_k4", hr, 4, "fast"),
    ]


def collect() -> dict:
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core.hypergraph import connectivity, kahypar
    from repro.core.hypergraph import metrics as HM
    from repro.core.hypergraph.dist import PARHYP_PRESETS, parhyp

    mesh = Mesh(np.array(jax.devices()), ("nets",))
    res = {}
    for name, hg, k, pre in cells():
        seq_preset = PARHYP_PRESETS[pre]["preset"]
        part_s, dt_s = _timed(kahypar, hg, k, 0.03, seq_preset, 1)
        part_d, dt_d = _timed(parhyp, hg, k, 0.03, pre, 1, mesh)
        km1_s = connectivity(hg, part_s)
        km1_d = connectivity(hg, part_d)
        assert HM.is_feasible(hg, part_d, k, 0.03), name
        assert km1_d <= QUALITY_SLACK * km1_s, (name, km1_d, km1_s)
        res[name] = {
            "devices": len(mesh.devices.reshape(-1)),
            "s_dist": round(dt_d, 2), "km1_dist": km1_d,
            "s_seq": round(dt_s, 2), "km1_seq": km1_s,
            "ratio": round(km1_d / max(km1_s, 1), 4),
        }
    return res


def main(out_path: str = "BENCH_parhyp.json") -> dict:
    report = {"parhyp": collect(), "quality_slack": QUALITY_SLACK,
              "meta": run_metadata()}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in report["parhyp"].items():
        print(f"{name}: {cell}", flush=True)
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
