"""Distributed-vs-sequential hypergraph smoke → ``BENCH_parhyp.json``.

Runs ``parhyp`` (the shard_map distributed partitioner, on a mesh over all
local devices — one device in CI) against sequential ``kahypar`` at an
equal quality budget (same engine preset, same instances/seeds), recording
cold and warm wall-clock, the (λ−1) objective, the coarsening wall
fraction, and backend compile counts.  Asserts the acceptance criteria:
distributed quality within 5% of sequential on every cell and, at one
device, warm dist/seq overhead under 5×.  Invoked by
``python benchmarks/run.py --smoke`` (CI) or directly.

``scale_main`` adds the ``parhyp_scale`` section: million-vertex power-law
instances (``rmat_hypergraph``) run device-resident end-to-end, recording
``s_dist``, device count, coarsening wall fraction, peak host RSS and
``compile_count`` — ``python benchmarks/run.py --scale[-smoke]``.
"""
from __future__ import annotations

import json
import os

try:
    from benchmarks.common import (peak_rss_mb, run_metadata, span_seconds,
                                   timed_call as _timed)
except ImportError:                      # direct: python benchmarks/bench_parhyp.py
    from common import (peak_rss_mb, run_metadata, span_seconds,
                        timed_call as _timed)

QUALITY_SLACK = 1.05         # distributed ≤ 5% over sequential (smoke gate)
OVERHEAD_MAX = 5.0           # warm 1-device dist/seq wall ratio (smoke gate)


def cells():
    from repro.io.generators import planted_hypergraph, random_hypergraph
    hp = planted_hypergraph(400, 600, blocks=4, seed=11)
    hr = random_hypergraph(512, 768, seed=5)
    return [
        ("parhyp_eco_hp400_k4", hp, 4, "eco"),
        ("parhyp_eco_hp400_k2", hp, 2, "eco"),
        ("parhyp_fast_hr512_k4", hr, 4, "fast"),
    ]


def _coarsen_frac(rec) -> float:
    total = span_seconds(rec.events, "parhyp")
    if total <= 0:
        return 0.0
    return round(span_seconds(rec.events, "parhyp_coarsen") / total, 3)


def collect() -> dict:
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro import obs
    from repro.core.hypergraph import connectivity, kahypar
    from repro.core.hypergraph import metrics as HM
    from repro.core.hypergraph.dist import PARHYP_PRESETS, parhyp

    mesh = Mesh(np.array(jax.devices()), ("nets",))
    devices = len(mesh.devices.reshape(-1))
    res = {}
    for name, hg, k, pre in cells():
        seq_preset = PARHYP_PRESETS[pre]["preset"]
        part_s, dt_s = _timed(kahypar, hg, k, 0.03, seq_preset, 1)
        _, dt_s_warm = _timed(kahypar, hg, k, 0.03, seq_preset, 1)
        rec = obs.Recorder()
        part_d, dt_d = _timed(parhyp, hg, k, 0.03, pre, 1, mesh,
                              report=rec)
        _, dt_d_warm = _timed(parhyp, hg, k, 0.03, pre, 1, mesh)
        km1_s = connectivity(hg, part_s)
        km1_d = connectivity(hg, part_d)
        overhead = dt_d_warm / max(dt_s_warm, 1e-9)
        assert HM.is_feasible(hg, part_d, k, 0.03), name
        assert km1_d <= QUALITY_SLACK * km1_s, (name, km1_d, km1_s)
        if devices == 1:
            # satellite gate: the fixed dist overhead at one device must
            # stay under 5× sequential once compiles are cached
            assert overhead < OVERHEAD_MAX, (name, overhead)
        res[name] = {
            "devices": devices,
            "s_dist": round(dt_d, 2), "km1_dist": km1_d,
            "s_seq": round(dt_s, 2), "km1_seq": km1_s,
            "s_dist_warm": round(dt_d_warm, 3),
            "s_seq_warm": round(dt_s_warm, 3),
            "overhead_ratio": round(overhead, 2),
            "coarsen_frac": _coarsen_frac(rec),
            "compile_count": rec.compile_count,
            "ratio": round(km1_d / max(km1_s, 1), 4),
        }
    return res


def scale_cells(smoke: bool):
    # (name, log2 n, k) — the smoke cell (~130k vertices/nets) is the CI
    # variant of the full million-vertex cell
    out = [("parhyp_scale_100k", 17, 4)]
    if not smoke:
        out.append(("parhyp_scale_1M", 20, 8))
    return out


def collect_scale(smoke: bool = False) -> dict:
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro import obs
    from repro.core.hypergraph import connectivity
    from repro.core.hypergraph import metrics as HM
    from repro.core.hypergraph.dist import parhyp
    from repro.io.generators import rmat_hypergraph

    mesh = Mesh(np.array(jax.devices()), ("nets",))
    devices = len(mesh.devices.reshape(-1))
    res = {}
    for name, scale, k in scale_cells(smoke):
        hg = rmat_hypergraph(scale, seed=3)
        rec = obs.Recorder()
        part, dt = _timed(parhyp, hg, k, 0.03, "fast", 1, mesh, report=rec)
        assert HM.is_feasible(hg, part, k, 0.03), name
        levels = int(rec.counters().get("parhyp/device_levels", 0))
        assert levels >= 2, (name, "device-resident coarsening did not run")
        res[name] = {
            "n": hg.n, "m": hg.m, "pins": hg.pins, "k": k,
            "devices": devices,
            "s_dist": round(dt, 2),
            "km1": connectivity(hg, part),
            "device_levels": levels,
            "coarsen_frac": _coarsen_frac(rec),
            "rss_peak_mb": peak_rss_mb(),
            "compile_count": rec.compile_count,
        }
        print(f"{name}: {res[name]}", flush=True)
    return res


def main(out_path: str = "BENCH_parhyp.json") -> dict:
    report = {"parhyp": collect(), "quality_slack": QUALITY_SLACK,
              "overhead_max": OVERHEAD_MAX, "meta": run_metadata()}
    if os.path.exists(out_path):
        # keep a previously recorded scale section
        with open(out_path) as f:
            old = json.load(f)
        if "parhyp_scale" in old:
            report["parhyp_scale"] = old["parhyp_scale"]
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in report["parhyp"].items():
        print(f"{name}: {cell}", flush=True)
    print(f"wrote {out_path}")
    return report


def scale_main(out_path: str = "BENCH_parhyp.json",
               smoke: bool = False) -> dict:
    cells_out = collect_scale(smoke)
    report = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            report = json.load(f)
    scale_sec = report.setdefault("parhyp_scale", {})
    scale_sec.update(cells_out)
    report["meta_scale"] = run_metadata()
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
