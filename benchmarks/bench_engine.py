"""End-to-end engine benchmark → ``BENCH_engine.json`` (+ trace artifacts).

Times the engine-backed drivers (kaffpa / kahypar) on the fixed seeded
instances the engine-parity test pins, and records wall-clock plus the
achieved objective so the perf trajectory is tracked across PRs.  Invoked
by ``python benchmarks/run.py --smoke`` (CI) or directly.

Each cell is measured twice (DESIGN.md §11): first cold with observability
disabled — the ``s`` field, comparable with the pre-PR wall times — then
warm with an ``obs.Recorder`` attached (``s_obs``), which captures the
per-cycle quality trajectory and pins that the recorder does not change
results.  ``compile_count`` is the number of XLA backend compiles the cold
run triggered (global ``obs.metrics`` delta via jax.monitoring).  The
recorders are exported to ``BENCH_engine_trace.jsonl`` (event journal) and
``BENCH_engine_trace.json`` (Chrome trace, open in Perfetto).

The ``pre_refactor`` block stores the PR-2 measurements of the pre-engine
drivers on this container (same instances/seeds) for comparison.
"""
from __future__ import annotations

import json

try:
    from benchmarks.common import run_metadata, timed_call
except ImportError:                      # direct: python benchmarks/bench_engine.py
    from common import run_metadata, timed_call


# PR-2 baseline: the duplicated kaffpa/kahypar loops before the shared
# engine landed, measured on the same instances/seeds in this container.
PRE_REFACTOR = {
    "kaffpa_eco_grid32_k4": {"s": 8.46, "cut": 92},
    "kaffpa_strong_grid32_k4": {"s": 10.18, "cut": 89},
    "kaffpa_ecosocial_ba2k_k8": {"s": 11.20, "cut": 4561},
    "kahypar_eco_hp400_k4": {"s": 4.50, "km1": 106},
    "kahypar_eco_hp400_k2": {"s": 6.58, "km1": 49},
}


def _cell(name: str, fn, args, score, recorders: list) -> dict:
    """Cold obs-disabled timing + warm obs-enabled rerun of one cell."""
    import numpy as np
    from repro import obs
    c0 = obs.metrics.get("jax/compiles")
    p0 = obs.metrics.get("engine/bucket_pads")
    h0 = obs.metrics.get("engine/compile_cache_hits")
    out, dt = timed_call(fn, *args)
    compile_count = int(obs.metrics.get("jax/compiles") - c0)
    bucket_pads = int(obs.metrics.get("engine/bucket_pads") - p0)
    cache_hits = int(obs.metrics.get("engine/compile_cache_hits") - h0)
    rec = obs.Recorder(name)
    out_obs, dt_obs = timed_call(fn, *args, report=rec)
    assert np.array_equal(out, out_obs), f"recorder changed result: {name}"
    recorders.append(rec)
    cell = {"s": round(dt, 2), "s_obs": round(dt_obs, 2),
            "compile_count": compile_count,
            "bucket_pads": bucket_pads,
            "compile_cache_hits": cache_hits,
            "trajectory": rec.trajectory("cycles")}
    cell.update(score(out))
    return cell


def collect(recorders: list) -> dict:
    from repro import obs
    from repro.core.kaffpa import kaffpa
    from repro.core.partition import edge_cut, is_feasible
    from repro.core.hypergraph import connectivity, kahypar
    from repro.core.hypergraph import metrics as HM
    from repro.io.generators import (barabasi_albert, grid2d,
                                     planted_hypergraph)

    obs.install_jax_compile_listener()
    g32 = grid2d(32, 32)
    ba = barabasi_albert(2048, 4, seed=1)
    hp = planted_hypergraph(400, 600, blocks=4, seed=11)
    res = {}

    def gscore(g, k):
        return lambda p: {"cut": edge_cut(g, p),
                          "feasible": is_feasible(g, p, k, 0.03)}

    def hscore(hg, k):
        return lambda p: {"km1": connectivity(hg, p),
                          "feasible": HM.is_feasible(hg, p, k, 0.03)}

    res["kaffpa_eco_grid32_k4"] = _cell(
        "kaffpa_eco_grid32_k4", kaffpa, (g32, 4, 0.03, "eco", 3),
        gscore(g32, 4), recorders)
    res["kaffpa_strong_grid32_k4"] = _cell(
        "kaffpa_strong_grid32_k4", kaffpa, (g32, 4, 0.03, "strong", 3),
        gscore(g32, 4), recorders)
    res["kaffpa_ecosocial_ba2k_k8"] = _cell(
        "kaffpa_ecosocial_ba2k_k8", kaffpa, (ba, 8, 0.03, "ecosocial", 1),
        gscore(ba, 8), recorders)
    res["kahypar_eco_hp400_k4"] = _cell(
        "kahypar_eco_hp400_k4", kahypar, (hp, 4, 0.03, "eco", 1),
        hscore(hp, 4), recorders)
    res["kahypar_eco_hp400_k2"] = _cell(
        "kahypar_eco_hp400_k2", kahypar, (hp, 2, 0.03, "eco", 1),
        hscore(hp, 2), recorders)

    # deep-hierarchy stress (DESIGN.md §12): a tiny stop_n forces many more
    # levels than any preset — compile sharing across same-bucket levels is
    # what keeps compile_count flat while the level count triples
    def kaffpa_deep(g, k, eps, seed, report=None):
        from repro.core import multilevel as ML
        from repro.core.kaffpa import GraphMedium, KaffpaConfig
        cfg = KaffpaConfig(coarsening="matching", refine_rounds=10,
                           multi_try=2, initial_tries=4,
                           contraction_stop_factor=2, stop_n_floor=8)
        return ML.run(GraphMedium(g, cfg, recorder=report), k, eps, seed)

    res["kaffpa_deep_grid32_k2"] = _cell(
        "kaffpa_deep_grid32_k2", kaffpa_deep, (g32, 2, 0.03, 3),
        gscore(g32, 2), recorders)
    return res


def main(out_path: str = "BENCH_engine.json") -> dict:
    from repro.obs import trace as obs_trace
    recorders: list = []
    engine = collect(recorders)
    report = {"engine": engine, "pre_refactor": PRE_REFACTOR,
              "meta": run_metadata()}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    base = out_path[:-5] if out_path.endswith(".json") else out_path
    obs_trace.write_jsonl(recorders, base + "_trace.jsonl")
    obs_trace.write_chrome_trace(recorders, base + "_trace.json")
    for name, cell in engine.items():
        pre = PRE_REFACTOR.get(name, {})
        print(f"{name}: {cell} (pre-refactor: {pre})", flush=True)
    print(f"wrote {out_path}, {base}_trace.jsonl, {base}_trace.json")
    return report


if __name__ == "__main__":
    main()
