"""End-to-end engine benchmark → ``BENCH_engine.json``.

Times the engine-backed drivers (kaffpa / kahypar) on the fixed seeded
instances the engine-parity test pins, and records wall-clock plus the
achieved objective so the perf trajectory is tracked across PRs.  Invoked
by ``python benchmarks/run.py --smoke`` (CI) or directly.

The ``pre_refactor`` block stores the PR-2 measurements of the pre-engine
drivers on this container (same instances/seeds) for comparison.
"""
from __future__ import annotations

import json
import time


# PR-2 baseline: the duplicated kaffpa/kahypar loops before the shared
# engine landed, measured on the same instances/seeds in this container.
PRE_REFACTOR = {
    "kaffpa_eco_grid32_k4": {"s": 8.46, "cut": 92},
    "kaffpa_strong_grid32_k4": {"s": 10.18, "cut": 89},
    "kaffpa_ecosocial_ba2k_k8": {"s": 11.20, "cut": 4561},
    "kahypar_eco_hp400_k4": {"s": 4.50, "km1": 106},
    "kahypar_eco_hp400_k2": {"s": 6.58, "km1": 49},
}


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def collect() -> dict:
    from repro.core.kaffpa import kaffpa
    from repro.core.partition import edge_cut, is_feasible
    from repro.core.hypergraph import connectivity, kahypar
    from repro.core.hypergraph import metrics as HM
    from repro.io.generators import (barabasi_albert, grid2d,
                                     planted_hypergraph)

    g32 = grid2d(32, 32)
    ba = barabasi_albert(2048, 4, seed=1)
    hp = planted_hypergraph(400, 600, blocks=4, seed=11)
    res = {}

    part, dt = _timed(kaffpa, g32, 4, 0.03, "eco", 3)
    res["kaffpa_eco_grid32_k4"] = {
        "s": round(dt, 2), "cut": edge_cut(g32, part),
        "feasible": is_feasible(g32, part, 4, 0.03)}
    part, dt = _timed(kaffpa, g32, 4, 0.03, "strong", 3)
    res["kaffpa_strong_grid32_k4"] = {
        "s": round(dt, 2), "cut": edge_cut(g32, part),
        "feasible": is_feasible(g32, part, 4, 0.03)}
    part, dt = _timed(kaffpa, ba, 8, 0.03, "ecosocial", 1)
    res["kaffpa_ecosocial_ba2k_k8"] = {
        "s": round(dt, 2), "cut": edge_cut(ba, part),
        "feasible": is_feasible(ba, part, 8, 0.03)}
    part, dt = _timed(kahypar, hp, 4, 0.03, "eco", 1)
    res["kahypar_eco_hp400_k4"] = {
        "s": round(dt, 2), "km1": connectivity(hp, part),
        "feasible": HM.is_feasible(hp, part, 4, 0.03)}
    part, dt = _timed(kahypar, hp, 2, 0.03, "eco", 1)
    res["kahypar_eco_hp400_k2"] = {
        "s": round(dt, 2), "km1": connectivity(hp, part),
        "feasible": HM.is_feasible(hp, part, 2, 0.03)}
    return res


def main(out_path: str = "BENCH_engine.json") -> dict:
    engine = collect()
    report = {"engine": engine, "pre_refactor": PRE_REFACTOR}
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    for name, cell in engine.items():
        base = PRE_REFACTOR.get(name, {})
        print(f"{name}: {cell} (pre-refactor: {base})", flush=True)
    print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
