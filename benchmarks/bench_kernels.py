"""Kernel μ-benchmarks: the jnp oracle path is the CPU-meaningful timing;
the Pallas path runs in interpret mode here (TPU is the target), so its
numbers are correctness checks, not speed."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import row, timed
from repro.kernels import ops, ref


def bench_affinity():
    rng = np.random.default_rng(0)
    n_pad, dmax, k = 4096, 16, 16
    nbr = jnp.asarray(rng.integers(0, n_pad, (n_pad, dmax)), jnp.int32)
    wgt = jnp.asarray(rng.random((n_pad, dmax)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, k, (n_pad,)), jnp.int32)
    f_ref = jax.jit(lambda: ref.affinity_ref(labels[nbr], wgt, k))
    f_ref()  # compile
    _, us = timed(lambda: f_ref().block_until_ready(), repeat=20)
    flops = 2 * n_pad * dmax * k
    row("lp_affinity_jnp/4096x16xk16", us, f"gflops={flops/us/1e3:.2f}")
    out, us_p = timed(lambda: ops.lp_affinity(nbr, wgt, labels, k)
                      .block_until_ready())
    row("lp_affinity_pallas_interpret/4096x16xk16", us_p, "correctness-only")


def bench_ssd():
    rng = np.random.default_rng(0)
    bh, l, p, n = 8, 2048, 64, 64
    x = jnp.asarray(rng.standard_normal((bh, l, p)), jnp.float32)
    ld = jnp.asarray(-0.1 - 0.3 * rng.random((bh, l)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bh, l, n)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bh, l, n)) * 0.3, jnp.float32)
    from repro.models.mamba2 import ssd_chunked
    f = jax.jit(lambda: ssd_chunked(x, ld, b, c))
    f()
    _, us = timed(lambda: f().block_until_ready(), repeat=5)
    flops = bh * l * (2 * 128 * n + 2 * 128 * p + 4 * n * p)  # per-token chunk work
    row("ssd_chunked_jnp/8x2048", us, f"gflops~{flops/us/1e3:.2f}")
    f2 = jax.jit(lambda: ref.ssd_scan_ref(x, ld, b, c))
    f2()
    _, us2 = timed(lambda: f2().block_until_ready(), repeat=3)
    row("ssd_sequential_ref/8x2048", us2, f"chunked_speedup={us2/us:.1f}x")


def main():
    bench_affinity()
    bench_ssd()


if __name__ == "__main__":
    main()
