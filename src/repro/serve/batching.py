"""Continuous batching for the serving example: a fixed pool of B slots,
each slot owns a position cursor inside the shared (stacked) KV caches;
finished requests free their slot, queued requests prefill into free slots.

(The single-sequence prefill into slot ``b`` uses a per-slot cache view —
batched prefill of heterogeneous lengths is padded to the slot max.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serve.serve_step import decode_step, greedy_token


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, params, cfg: ArchConfig, batch_slots: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_len = max_len
        self.caches = T.init_caches(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, dtype=np.int64)
        self.budget = np.zeros(batch_slots, dtype=np.int64)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.last_tok = np.zeros((batch_slots, 1), dtype=np.int32)
        self._decode = jax.jit(
            lambda p, tok, caches, pos: self._decode_impl(p, tok, caches, pos))

    def _decode_impl(self, params, tok, caches, pos):
        # per-slot positions: run the stacked decode with per-slot masks by
        # taking the max position (safe upper bound) and masking per slot in
        # the attention via cache contents; positions differ per slot, so we
        # decode each slot against its own cursor using vmap over slots is
        # costly — instead we use the shared-step approximation: all slots
        # share the same step index (the cache is padded).  For exactness we
        # pass per-slot pos through the RoPE positions.
        logits, caches = T.forward(params, self.cfg, tok, caches=caches,
                                   cache_pos=pos)
        return logits[:, -1], caches

    def add(self, req: Request) -> bool:
        for s in range(self.b):
            if self.slot_req[s] is None:
                self.slot_req[s] = req
                # prefill this slot: simple loop decode over the prompt
                # (slot-local prefill keeps the example dependency-free)
                for t, tok in enumerate(req.prompt):
                    lg, self.caches = decode_step(
                        self.params, self.cfg,
                        jnp.asarray(np.full((self.b, 1), tok, np.int32)),
                        self.caches, jnp.int32(t))
                self.pos[s] = len(req.prompt)
                self.budget[s] = req.max_new
                self.last_tok[s, 0] = int(np.asarray(lg[s]).argmax())
                return True
        return False

    def step(self):
        """One decode step for every active slot."""
        active = [s for s in range(self.b) if self.slot_req[s] is not None]
        if not active:
            return []
        pos = int(self.pos[active].max())
        logits, self.caches = decode_step(
            self.params, self.cfg, jnp.asarray(self.last_tok),
            self.caches, jnp.int32(pos))
        nxt = np.asarray(greedy_token(logits))
        finished = []
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s]))
            self.last_tok[s, 0] = int(nxt[s])
            self.pos[s] += 1
            self.budget[s] -= 1
            if self.budget[s] <= 0 or self.pos[s] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                self.slot_req[s] = None
        return finished


def serve_requests(params, cfg: ArchConfig, prompts: list,
                   batch_slots: int = 4, max_len: int = 128,
                   max_new: int = 8) -> list:
    """Drive the batcher until every request completes; returns Requests."""
    todo = [Request(i, np.asarray(p, np.int32), max_new)
            for i, p in enumerate(prompts)]
    batcher = ContinuousBatcher(params, cfg, batch_slots, max_len)
    done: list = []
    queue = list(todo)
    while queue or any(r is not None for r in batcher.slot_req):
        while queue and batcher.add(queue[0]):
            queue.pop(0)
        done.extend(batcher.step())
    return todo
