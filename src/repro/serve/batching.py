"""Continuous batching for the serving stack: a fixed pool of B slots, each
slot owns a position cursor inside the shared (stacked) KV caches; finished
requests free their slot, queued requests prefill into free slots.

Slot isolation is exact (pinned by tests/test_train_serve.py):

  * Prefill runs on a **per-slot cache view** — ``caches[:, s:s+1]`` is
    sliced out, the prompt decoded token-by-token into it (one compiled
    (1, 1) shape regardless of prompt length), and the view written back.
    Other slots' cache entries are never touched.
  * Decode is a **vmapped per-slot step**: every slot attends and writes
    at its *own* position cursor (per-slot RoPE positions, per-slot
    causal mask), so heterogeneous prompt lengths coexist bit-exactly
    with single-request decoding.  Free slots decode inertly at cursor 0;
    whatever they write is overwritten by the next prefill before it can
    ever be attended (positions beyond a request's cursor are masked, and
    every position ≤ the cursor is freshly written by that request).

Telemetry is opt-in via ``telemetry=`` (`obs.live.ServeTelemetry`); the
default `NULL_TELEMETRY` makes every hook a no-op — no clock reads, no
allocations, bit-identical outputs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.obs.live import NULL_TELEMETRY
from repro.serve.serve_step import decode_step, greedy_token


#: Designed host sync points: functions where a device value *must* reach
#: the host (the sampled token feeds the python-side slot state).  The
#: `repro.analysis` host-sync lint skips device→host reads inside these and
#: flags any that appear elsewhere on the serve path.
_HOST_SYNC_OK = ("add", "step")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


# module-level jitted steps (cfg is a frozen dataclass → static arg), so
# every batcher instance for the same config shares one compile cache
@functools.partial(jax.jit, static_argnums=1)
def _step1(params, cfg, tok, caches, pos):
    """Single-slot decode at fixed (1, 1) shape — the prefill token loop."""
    return decode_step(params, cfg, tok, caches, pos)


@functools.partial(jax.jit, static_argnums=1)
def _decode_slots(params, cfg, toks, pos, caches):
    """Per-slot decode: each lane re-adds its batch dim, runs one token at
    its OWN cursor, and strips the dim again so the stacked caches keep
    their (layers, B, ...) layout."""
    def one(tok, p, cache):
        cache1 = jax.tree.map(lambda c: c[:, None], cache)
        lg, new = decode_step(params, cfg, tok[None, None], cache1, p)
        return lg[0], jax.tree.map(lambda c: c[:, 0], new)

    return jax.vmap(one, in_axes=(0, 0, 1), out_axes=(0, 1))(
        toks, pos, caches)


class ContinuousBatcher:
    def __init__(self, params, cfg: ArchConfig, batch_slots: int,
                 max_len: int, telemetry=None):
        self.params = params
        self.cfg = cfg
        self.b = batch_slots
        self.max_len = max_len
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.caches = T.init_caches(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, dtype=np.int64)
        self.budget = np.zeros(batch_slots, dtype=np.int64)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.last_tok = np.zeros((batch_slots, 1), dtype=np.int32)

    # -- slot cache views ----------------------------------------------------
    def _slot_view(self, s: int):
        return jax.tree.map(lambda c: c[:, s:s + 1], self.caches)

    def _write_slot(self, s: int, view) -> None:
        self.caches = jax.tree.map(
            lambda full, piece: full.at[:, s:s + 1].set(
                piece.astype(full.dtype)), self.caches, view)

    def _free_slot(self, s: int) -> None:
        self.slot_req[s] = None
        self.pos[s] = 0
        self.budget[s] = 0
        self.last_tok[s, 0] = 0

    def active_slots(self) -> List[int]:
        return [s for s in range(self.b) if self.slot_req[s] is not None]

    def add(self, req: Request) -> bool:
        """Place ``req`` into a free slot (prefill); False when all busy."""
        if len(req.prompt) > self.max_len - 1:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds cache capacity "
                f"{self.max_len - 1}")
        if req.max_new <= 0 or len(req.prompt) == 0:
            req.done = True             # nothing to generate: never slotted
            return True
        tele = self.telemetry
        for s in range(self.b):
            if self.slot_req[s] is None:
                tele.started(req.rid, s, len(req.prompt),
                             active=len(self.active_slots()) + 1)
                view = self._slot_view(s)
                lg = None
                for t, tok in enumerate(req.prompt):
                    lg, view = _step1(
                        self.params, self.cfg,
                        jnp.full((1, 1), int(tok), jnp.int32), view,
                        jnp.int32(t))
                self._write_slot(s, view)
                tele.prefilled(req.rid, s, len(req.prompt))
                first = int(np.asarray(lg[0]).argmax())
                req.out.append(first)
                self.pos[s] = len(req.prompt)
                if req.max_new == 1 or self.pos[s] >= self.max_len - 1:
                    req.done = True     # prefill token was the whole budget
                    tele.finished(req.rid, s, len(req.out))
                    return True
                self.slot_req[s] = req
                self.budget[s] = req.max_new - 1
                self.last_tok[s, 0] = first
                return True
        return False

    def step(self, queue_depth: int = 0) -> List[Request]:
        """One decode step for every active slot; returns finished requests."""
        active = self.active_slots()
        if not active:
            return []
        tele = self.telemetry
        t0 = time.perf_counter() if tele.enabled else 0.0
        logits, self.caches = _decode_slots(
            self.params, self.cfg, jnp.asarray(self.last_tok[:, 0]),
            jnp.asarray(self.pos, dtype=jnp.int32), self.caches)
        nxt = np.asarray(greedy_token(logits))
        finished = []
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self.last_tok[s, 0] = tok
            self.pos[s] += 1
            self.budget[s] -= 1
            tele.tick(req.rid, s, tok)
            if self.budget[s] <= 0 or self.pos[s] >= self.max_len - 1:
                req.done = True
                finished.append(req)
                tele.finished(req.rid, s, len(req.out))
                self._free_slot(s)
        if tele.enabled:
            tele.step(len(active), len(self.active_slots()),
                      queue_depth=queue_depth,
                      step_s=time.perf_counter() - t0)
        return finished


def serve_stream(params, cfg: ArchConfig,
                 stream: Sequence[Tuple[int, Sequence[int], int]],
                 batch_slots: int = 4, max_len: int = 128,
                 telemetry=None) -> List[Request]:
    """Replay a request stream through the batcher until drained.

    ``stream``: (arrival_tick, prompt, max_new) triples; a tick is one
    batched decode step, so bursty traces interleave arrivals with decode
    progress exactly like a live server.  Returns the Requests in stream
    order.
    """
    tele = telemetry if telemetry is not None else NULL_TELEMETRY
    reqs = [Request(i, np.asarray(p, np.int32), mn)
            for i, (_, p, mn) in enumerate(stream)]
    arrivals = sorted(range(len(reqs)), key=lambda i: (stream[i][0], i))
    batcher = ContinuousBatcher(params, cfg, batch_slots, max_len,
                                telemetry=tele)
    queue: List[Request] = []
    tick = 0
    i = 0
    while i < len(arrivals) or queue or batcher.active_slots():
        while i < len(arrivals) and stream[arrivals[i]][0] <= tick:
            req = reqs[arrivals[i]]
            queue.append(req)
            tele.enqueued(req.rid, len(queue))
            i += 1
        while queue and batcher.add(queue[0]):
            queue.pop(0)
        batcher.step(queue_depth=len(queue))
        tick += 1
    return reqs


def serve_requests(params, cfg: ArchConfig, prompts: list,
                   batch_slots: int = 4, max_len: int = 128,
                   max_new: int = 8, telemetry=None) -> list:
    """Drive the batcher until every request completes; returns Requests."""
    return serve_stream(params, cfg,
                        [(0, p, max_new) for p in prompts],
                        batch_slots=batch_slots, max_len=max_len,
                        telemetry=telemetry)
