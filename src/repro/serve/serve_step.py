"""Serving steps: prefill + decode (the functions dryrun.py lowers for the
``prefill_*`` / ``decode_*`` / ``long_*`` cells).

Both steps emit an ambient-recorder span (`obs.use`) when called eagerly —
the serve path's Chrome trace shows each prefill/decode dispatch.  Inside a
jit trace the hook is skipped (it would only time tracing), and with no
recorder installed the cost is one attribute read on the NULL singleton.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ArchConfig
from repro.models import transformer as T


def _serve_span(rec, name: str, arr, **attrs):
    """An ambient span unless disabled or mid-trace (``arr`` is probed)."""
    if rec.enabled and not isinstance(arr, jax.core.Tracer):
        return rec.span(name, **attrs)
    return obs.NULL.span(name)


def prefill_step(params, cfg: ArchConfig, tokens, caches,
                 prefix_embeds=None, enc_frames=None, remat: str = "none"):
    """Full-sequence forward that fills the KV/state caches.
    Returns (last_token_logits, caches)."""
    kw = {}
    if prefix_embeds is not None:
        kw["prefix_embeds"] = prefix_embeds
    if enc_frames is not None:
        kw["enc_frames"] = enc_frames
    with _serve_span(obs.current(), "serve/prefill_step", tokens,
                     tokens=int(tokens.shape[0] * tokens.shape[1])):
        logits, caches = T.forward(params, cfg, tokens, caches=caches,
                                   cache_pos=0, remat=remat, **kw)
    return logits[:, -1], caches


def decode_step(params, cfg: ArchConfig, last_token, caches, pos,
                enc_frames=None):
    """One token in, one token out; O(cache) attention / O(1) SSM state.
    last_token: (B, 1) int32; pos: scalar int32 (tokens already cached)."""
    kw = {}
    if enc_frames is not None:
        kw["enc_frames"] = enc_frames
    with _serve_span(obs.current(), "serve/decode_step", last_token,
                     batch=int(last_token.shape[0])):
        logits, caches = T.forward(params, cfg, last_token, caches=caches,
                                   cache_pos=pos, **kw)
    return logits[:, -1], caches


def greedy_token(logits: jax.Array, temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)
