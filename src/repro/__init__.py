"""repro: KaHIP-in-JAX + multi-pod LM framework."""
__version__ = "3.0.0"
