"""Metis / Chaco / DIMACS-challenge text graph format (paper §3.1.1) and the
partition / separator / clustering output formats (§3.2)."""
from __future__ import annotations

import numpy as np

from repro.core.csr import Graph, GraphFormatError


def read_metis(path: str) -> Graph:
    """Parse the Metis text format. 1-indexed vertices, % comments.

    Empty lines after the header are kept — an isolated vertex is stored as
    an empty line.
    """
    with open(path, "r") as f:
        raw = [l.strip() for l in f if not l.strip().startswith("%")]
    # header = first non-empty line; everything after it is a vertex line
    while raw and not raw[0]:
        raw.pop(0)
    lines = raw
    if not lines:
        raise GraphFormatError("empty graph file")
    head = lines[0].split()
    if len(head) not in (2, 3):
        raise GraphFormatError(f"bad header: {lines[0]!r}")
    n, m = int(head[0]), int(head[1])
    fmt = head[2] if len(head) == 3 else "0"
    has_ew = fmt.endswith("1")
    has_vw = len(fmt) >= 2 and fmt[-2] == "1"
    while len(lines) - 1 > n and not lines[-1]:
        lines.pop()                      # trailing blank lines at EOF
    if len(lines) - 1 != n:
        raise GraphFormatError(f"expected {n} vertex lines, got {len(lines) - 1}")
    xadj = np.zeros(n + 1, dtype=np.int64)
    adjncy, adjwgt = [], []
    vwgt = np.ones(n, dtype=np.int64)
    for i in range(n):
        tok = [int(t) for t in lines[1 + i].split()]
        p = 0
        if has_vw:
            if not tok:
                raise GraphFormatError(f"vertex {i + 1}: missing weight")
            vwgt[i] = tok[0]
            p = 1
        rest = tok[p:]
        if has_ew:
            if len(rest) % 2:
                raise GraphFormatError(f"vertex {i + 1}: odd token count with edge weights")
            adjncy.extend(r - 1 for r in rest[0::2])
            adjwgt.extend(rest[1::2])
            xadj[i + 1] = xadj[i] + len(rest) // 2
        else:
            adjncy.extend(r - 1 for r in rest)
            adjwgt.extend([1] * len(rest))
            xadj[i + 1] = xadj[i] + len(rest)
    adjncy = np.asarray(adjncy, dtype=np.int64)
    adjwgt = np.asarray(adjwgt, dtype=np.int64)
    if len(adjncy) != 2 * m:
        raise GraphFormatError(
            f"header says m={m} (=> {2 * m} directed edges) but file has {len(adjncy)}")
    g = Graph(xadj=xadj, adjncy=adjncy, vwgt=vwgt, adjwgt=adjwgt)
    return g


def write_metis(g: Graph, path: str) -> None:
    has_vw = not np.all(g.vwgt == 1)
    has_ew = not np.all(g.adjwgt == 1)
    fmt = f"{int(has_vw)}{int(has_ew)}"
    with open(path, "w") as f:
        head = f"{g.n} {g.m}"
        if fmt != "00":
            head += f" {fmt.lstrip('0') if fmt != '10' else '10'}"
        f.write(head + "\n")
        for v in range(g.n):
            parts = []
            if has_vw:
                parts.append(str(int(g.vwgt[v])))
            nb = g.neighbors(v)
            ew = g.edge_weights(v)
            for j in range(len(nb)):
                parts.append(str(int(nb[j]) + 1))
                if has_ew:
                    parts.append(str(int(ew[j])))
            f.write(" ".join(parts) + "\n")


def graphchecker(path: str) -> list:
    """The ``graphchecker`` program: returns [] iff the file is valid."""
    try:
        g = read_metis(path)
    except GraphFormatError as e:
        return [str(e)]
    return g.check(raise_on_error=False)


# -- output formats (§3.2) ---------------------------------------------------

def write_partition(part: np.ndarray, path: str) -> None:
    """tmppartition<k>: line i = block id of vertex i."""
    np.savetxt(path, np.asarray(part, dtype=np.int64), fmt="%d")


def read_partition(path: str) -> np.ndarray:
    return np.loadtxt(path, dtype=np.int64, ndmin=1)


def write_separator(part: np.ndarray, sep_ids: np.ndarray, k: int, path: str) -> None:
    """Separator format: separator nodes get block id k, others keep theirs."""
    out = np.asarray(part, dtype=np.int64).copy()
    out[np.asarray(sep_ids, dtype=np.int64)] = k
    np.savetxt(path, out, fmt="%d")


def read_separator(path: str, k: int):
    """Inverse of ``write_separator``: returns (part, sep_ids).

    Vertices labelled ``k`` are the separator; their ``part`` entry is reset
    to block 0 (the information the format drops).  ``k`` is required
    because the format does not encode it — inferring it from the maximum
    label would misread an empty-separator file (max label k−1) as having
    the whole top block in the separator.
    """
    raw = np.loadtxt(path, dtype=np.int64, ndmin=1)
    if len(raw) and raw.max() > k:
        raise GraphFormatError(
            f"separator file has label {int(raw.max())} > k={k}")
    sep_ids = np.flatnonzero(raw == k)
    part = raw.copy()
    part[sep_ids] = 0
    return part, sep_ids
