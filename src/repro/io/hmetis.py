"""hMETIS text hypergraph format (the format KaHyPar/hMetis consume).

Header: ``m n [fmt]`` — number of nets FIRST, then vertices.  ``fmt`` is
``1`` (net weights), ``10`` (vertex weights) or ``11`` (both).  Each of the
next m lines lists one net: ``[w] pin pin ...`` with 1-indexed pins.  When
vertex weights are present they follow as n single-number lines.  ``%``
lines are comments.
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph.container import Hypergraph, HypergraphFormatError


def read_hmetis(path: str) -> Hypergraph:
    with open(path, "r") as f:
        lines = [l.strip() for l in f
                 if l.strip() and not l.strip().startswith("%")]
    if not lines:
        raise HypergraphFormatError("empty hypergraph file")
    head = lines[0].split()
    if len(head) not in (2, 3):
        raise HypergraphFormatError(f"bad header: {lines[0]!r}")
    m, n = int(head[0]), int(head[1])
    fmt = head[2] if len(head) == 3 else "0"
    has_ew = fmt.endswith("1")
    has_vw = len(fmt) >= 2 and fmt[-2] == "1"
    want = 1 + m + (n if has_vw else 0)
    if len(lines) != want:
        raise HypergraphFormatError(
            f"expected {want} non-comment lines, got {len(lines)}")
    ewgt = np.ones(m, dtype=np.int64)
    nets = []
    for e in range(m):
        tok = [int(t) for t in lines[1 + e].split()]
        if has_ew:
            if len(tok) < 2:
                raise HypergraphFormatError(f"net {e + 1}: missing weight/pins")
            ewgt[e] = tok[0]
            tok = tok[1:]
        if not tok:
            raise HypergraphFormatError(f"net {e + 1}: empty net")
        nets.append([t - 1 for t in tok])
    vwgt = None
    if has_vw:
        vwgt = np.asarray([int(lines[1 + m + v]) for v in range(n)],
                          dtype=np.int64)
    hg = Hypergraph.from_nets(n, nets, ewgt=ewgt, vwgt=vwgt,
                              dedup_pins=False)
    hg.check()
    return hg


def write_hmetis(hg: Hypergraph, path: str) -> None:
    has_vw = not np.all(hg.vwgt == 1)
    has_ew = not np.all(hg.ewgt == 1)
    fmt = f"{int(has_vw)}{int(has_ew)}"
    with open(path, "w") as f:
        head = f"{hg.m} {hg.n}"
        if fmt != "00":
            head += f" {fmt.lstrip('0')}"
        f.write(head + "\n")
        for e in range(hg.m):
            parts = []
            if has_ew:
                parts.append(str(int(hg.ewgt[e])))
            parts.extend(str(int(p) + 1) for p in hg.net_pins(e))
            f.write(" ".join(parts) + "\n")
        if has_vw:
            for v in range(hg.n):
                f.write(f"{int(hg.vwgt[v])}\n")


def hypergraphchecker(path: str) -> list:
    """Returns [] iff the file parses and validates cleanly."""
    try:
        hg = read_hmetis(path)
    except (HypergraphFormatError, ValueError) as e:
        return [str(e)]
    return hg.check(raise_on_error=False)
