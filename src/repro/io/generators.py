"""Synthetic graph families used by tests and benchmarks.

Two regimes mirror the paper's preset split: *mesh-like* (grids, tori,
geometric graphs — what fast/eco/strong target) and *social/web-like*
(power-law RMAT, Barabási–Albert, Watts–Strogatz — what the ``*social``
presets and ParHIP target).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import Graph


def grid2d(rows: int, cols: int, wrap: bool = False, seed: int = 0) -> Graph:
    """2-D grid (torus if wrap) — the canonical 'mesh' instance (Fig. 1)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    us, vs = [], []
    # horizontal
    us.append(idx[:, :-1].ravel()); vs.append(idx[:, 1:].ravel())
    us.append(idx[:-1, :].ravel()); vs.append(idx[1:, :].ravel())
    if wrap and cols > 2:
        us.append(idx[:, -1].ravel()); vs.append(idx[:, 0].ravel())
    if wrap and rows > 2:
        us.append(idx[-1, :].ravel()); vs.append(idx[0, :].ravel())
    u = np.concatenate(us); v = np.concatenate(vs)
    return Graph.from_edges(rows * cols, u, v)


def grid3d(nx: int, ny: int, nz: int) -> Graph:
    idx = np.arange(nx * ny * nz).reshape(nx, ny, nz)
    us, vs = [], []
    us.append(idx[:-1].ravel()); vs.append(idx[1:].ravel())
    us.append(idx[:, :-1].ravel()); vs.append(idx[:, 1:].ravel())
    us.append(idx[:, :, :-1].ravel()); vs.append(idx[:, :, 1:].ravel())
    return Graph.from_edges(nx * ny * nz, np.concatenate(us), np.concatenate(vs))


def rmat(scale: int, edge_factor: int = 8, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """Kronecker/RMAT power-law generator (Graph500 parameters)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    u = np.zeros(m, dtype=np.int64)
    v = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for _ in range(scale):
        r = rng.random(m)
        ubit = (r >= ab).astype(np.int64)                       # rows c+d
        vbit = np.where(ubit == 1, (r >= abc), (r >= a)).astype(np.int64)
        u = (u << 1) | ubit
        v = (v << 1) | vbit
    # permute ids to kill locality
    perm = rng.permutation(n)
    return Graph.from_edges(n, perm[u], perm[v])


def barabasi_albert(n: int, m_attach: int = 3, seed: int = 0) -> Graph:
    """Preferential attachment — social-like degree distribution."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list = list(range(m_attach))
    us, vs = [], []
    for v in range(m_attach, n):
        picks = rng.choice(len(repeated), size=m_attach, replace=False)
        chosen = {repeated[p] for p in picks}
        for t in chosen:
            us.append(v); vs.append(t)
        repeated.extend(chosen)
        repeated.extend([v] * len(chosen))
    return Graph.from_edges(n, np.asarray(us), np.asarray(vs))


def watts_strogatz(n: int, k: int = 6, p: float = 0.1, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    us, vs = [], []
    for j in range(1, k // 2 + 1):
        u = np.arange(n)
        v = (u + j) % n
        rewire = rng.random(n) < p
        v = np.where(rewire, rng.integers(0, n, n), v)
        us.append(u); vs.append(v)
    return Graph.from_edges(n, np.concatenate(us), np.concatenate(vs))


def random_geometric(n: int, radius: float | None = None, seed: int = 0) -> Graph:
    """Unit-square geometric graph — mesh-like, used by DIMACS instances."""
    rng = np.random.default_rng(seed)
    if radius is None:
        radius = 1.8 * np.sqrt(1.0 / n)
    pts = rng.random((n, 2))
    # grid binning for near-linear neighbour search
    nb = max(1, int(1.0 / radius))
    cell = (pts // (1.0 / nb)).astype(np.int64)
    cid = cell[:, 0] * nb + cell[:, 1]
    order = np.argsort(cid)
    us, vs = [], []
    r2 = radius * radius
    # brute force within 3x3 neighbourhood via sorted cells
    from collections import defaultdict
    buckets = defaultdict(list)
    for i in range(n):
        buckets[(int(cell[i, 0]), int(cell[i, 1]))].append(i)
    for (cx, cy), members in buckets.items():
        cand = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                cand.extend(buckets.get((cx + dx, cy + dy), []))
        cand = np.asarray(cand)
        for i in members:
            d = pts[cand] - pts[i]
            close = cand[(d * d).sum(1) < r2]
            close = close[close > i]
            us.extend([i] * len(close)); vs.extend(close.tolist())
    return Graph.from_edges(n, np.asarray(us, dtype=np.int64),
                            np.asarray(vs, dtype=np.int64))


def erdos_renyi(n: int, avg_deg: float = 8.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    u = rng.integers(0, n, m * 2)
    v = rng.integers(0, n, m * 2)
    return Graph.from_edges(n, u, v)


def weighted_grid(rows: int, cols: int, seed: int = 0, wmax: int = 10) -> Graph:
    g = grid2d(rows, cols)
    rng = np.random.default_rng(seed)
    # symmetric random weights: assign per undirected edge then mirror
    n = g.n
    src = g.edge_sources()
    lo = np.minimum(src, g.adjncy)
    hi = np.maximum(src, g.adjncy)
    key = lo * np.int64(n) + hi
    uniq, inv = np.unique(key, return_inverse=True)
    w_und = rng.integers(1, wmax + 1, size=len(uniq))
    return Graph(g.xadj, g.adjncy, g.vwgt, w_und[inv].astype(np.int64))


# ---------------------------------------------------------------------------
# hypergraph families (repro.core.hypergraph workloads)
# ---------------------------------------------------------------------------

def random_hypergraph(n: int, m: int, min_pins: int = 2, max_pins: int = 8,
                      seed: int = 0, wmax: int = 1):
    """Uniform random hypergraph: each net draws 2..max_pins distinct pins."""
    from repro.core.hypergraph.container import Hypergraph
    rng = np.random.default_rng(seed)
    nets = []
    for _ in range(m):
        sz = int(rng.integers(min_pins, max_pins + 1))
        nets.append(rng.choice(n, size=min(sz, n), replace=False))
    ewgt = rng.integers(1, wmax + 1, size=m) if wmax > 1 else None
    return Hypergraph.from_nets(n, nets, ewgt=ewgt)


def planted_hypergraph(n: int, m: int, blocks: int = 4,
                       p_cross: float = 0.1, min_pins: int = 2,
                       max_pins: int = 8, seed: int = 0, wmax: int = 1):
    """Planted-partition hypergraph: most nets draw all pins from one of
    ``blocks`` ground-truth groups; a ``p_cross`` fraction spans the whole
    vertex set.  The planted assignment is a near-optimal (λ−1) partition —
    the standard quality benchmark for data-placement workloads."""
    from repro.core.hypergraph.container import Hypergraph
    rng = np.random.default_rng(seed)
    home = np.arange(n) % blocks           # planted group of each vertex
    members = [np.flatnonzero(home == b) for b in range(blocks)]
    nets = []
    for _ in range(m):
        sz = int(rng.integers(min_pins, max_pins + 1))
        if rng.random() < p_cross:
            pool = np.arange(n)
        else:
            pool = members[int(rng.integers(0, blocks))]
        nets.append(rng.choice(pool, size=min(sz, len(pool)), replace=False))
    ewgt = rng.integers(1, wmax + 1, size=m) if wmax > 1 else None
    return Hypergraph.from_nets(n, nets, ewgt=ewgt)


def rmat_hypergraph(scale: int, net_factor: float = 1.0,
                    avg_pins: float = 6.0, max_pins: int = 64,
                    seed: int = 0, a: float = 0.57,
                    chunk: int = 1 << 18):
    """Streaming RMAT-style power-law hypergraph (the million-vertex
    ``parhyp_scale`` instance family): ``n = 2^scale`` vertices and
    ``~net_factor·n`` nets whose sizes follow a clipped Pareto tail and
    whose pins are drawn by 1-D bit-recursive skewed sampling (the RMAT
    recursion applied to a single id), so vertex degrees are heavy-tailed
    too.  Nets are generated in bounded chunks of ``chunk`` so peak
    transient memory stays O(chunk·avg_pins) over the final arrays —
    host-RSS-friendly at 1M+ nets."""
    from repro.core.hypergraph.container import Hypergraph
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = int(round(net_factor * n))
    perm = rng.permutation(n)
    eind_parts, size_parts = [], []
    done = 0
    while done < m:
        b = int(min(chunk, m - done))
        # clipped-Pareto net sizes with mean ~avg_pins
        sz = 2 + np.floor(1.5 * (avg_pins - 2.0)
                          * rng.pareto(2.5, b)).astype(np.int64)
        sz = np.minimum(sz, max_pins)
        total = int(sz.sum())
        v = np.zeros(total, dtype=np.int64)
        for _ in range(scale):
            v = (v << 1) | (rng.random(total) >= a)
        net = np.repeat(np.arange(b, dtype=np.int64), sz)
        # dedup pins within each net (sort on the flat (net, vertex) key)
        flat = np.sort(net * n + v, kind="stable")
        keep = np.concatenate([[True], flat[1:] != flat[:-1]])
        flat = flat[keep]
        net_k, v_k = flat // n, flat % n
        cnt = np.bincount(net_k, minlength=b)
        # single-pin nets carry no objective — drop them
        ok = cnt >= 2
        keep_pin = ok[net_k]
        eind_parts.append(perm[v_k[keep_pin]])
        size_parts.append(cnt[ok])
        done += b
    sizes = np.concatenate(size_parts)
    eptr = np.zeros(len(sizes) + 1, dtype=np.int64)
    np.cumsum(sizes, out=eptr[1:])
    return Hypergraph.from_arrays(n, eptr, np.concatenate(eind_parts))


def grid_hypergraph(rows: int, cols: int):
    """Each 2×2 window of a grid becomes a 4-pin net — mesh-like, low λ."""
    from repro.core.hypergraph.container import Hypergraph
    idx = np.arange(rows * cols).reshape(rows, cols)
    nets = []
    for i in range(rows - 1):
        for j in range(cols - 1):
            nets.append([idx[i, j], idx[i, j + 1],
                         idx[i + 1, j], idx[i + 1, j + 1]])
    return Hypergraph.from_nets(rows * cols, nets)


FAMILIES_H = {
    "hrand": lambda seed=0: random_hypergraph(2048, 3072, seed=seed),
    "hplant": lambda seed=0: planted_hypergraph(2048, 3072, blocks=8,
                                                seed=seed),
    "hgrid": lambda seed=0: grid_hypergraph(40, 40),
    "hrmat": lambda seed=0: rmat_hypergraph(11, seed=seed),
}


FAMILIES = {
    "grid2d": lambda seed=0: grid2d(64, 64),
    "grid3d": lambda seed=0: grid3d(16, 16, 16),
    "geometric": lambda seed=0: random_geometric(4096, seed=seed),
    "ba": lambda seed=0: barabasi_albert(4096, 4, seed=seed),
    "ws": lambda seed=0: watts_strogatz(4096, 6, 0.1, seed=seed),
    "er": lambda seed=0: erdos_renyi(4096, 8.0, seed=seed),
    "wgrid": lambda seed=0: weighted_grid(64, 64, seed=seed),
}
