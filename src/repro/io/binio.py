"""ParHIP binary graph format (paper §3.1.2).

Layout (all 64-bit unsigned little-endian longs):
  [version=3][n][m_directed]                      -- 3 words
  [off_0 .. off_n]                                -- n+1 BYTE offsets; off_i is
                                                     the file position where the
                                                     edge targets of vertex i
                                                     start; off_n marks EOF
  [targets...]                                    -- one u64 per directed edge

Node ids start at 0. ``graph2binary`` / ``graph2binary_external`` convert the
Metis text format; the external variant streams row-by-row without holding the
adjacency in memory (paper §4.3.2). ``toolbox`` helpers live in metis.py.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import Graph, GraphFormatError

_VERSION = 3
_W = 8  # bytes per word


def write_binary(g: Graph, path: str) -> None:
    n, e = g.n, len(g.adjncy)
    header = np.array([_VERSION, n, e], dtype=np.uint64)
    base = (3 + n + 1) * _W
    offsets = (base + g.xadj.astype(np.uint64) * _W).astype(np.uint64)
    with open(path, "wb") as f:
        f.write(header.tobytes())
        f.write(offsets.tobytes())
        f.write(g.adjncy.astype(np.uint64).tobytes())


def read_binary(path: str) -> Graph:
    with open(path, "rb") as f:
        head = np.frombuffer(f.read(3 * _W), dtype=np.uint64)
        if len(head) != 3:
            raise GraphFormatError("truncated binary header")
        version, n, e = int(head[0]), int(head[1]), int(head[2])
        if version != _VERSION:
            raise GraphFormatError(f"unsupported binary version {version}")
        offsets = np.frombuffer(f.read((n + 1) * _W), dtype=np.uint64).astype(np.int64)
        targets = np.frombuffer(f.read(e * _W), dtype=np.uint64).astype(np.int64)
    base = (3 + n + 1) * _W
    xadj = (offsets - base) // _W
    if xadj[0] != 0 or xadj[-1] != e:
        raise GraphFormatError("inconsistent binary offsets")
    return Graph.from_arrays(xadj, targets)


def graph2binary(metis_path: str, out_path: str) -> None:
    from repro.io.metis import read_metis
    write_binary(read_metis(metis_path), out_path)


def graph2binary_external(metis_path: str, out_path: str) -> None:
    """External-memory converter: two streaming passes, O(n) resident."""
    # pass 1: degrees only
    degs = []
    with open(metis_path) as f:
        lines = (l.strip() for l in f)
        body = (l for l in lines if l and not l.startswith("%"))
        head = next(body).split()
        n, m = int(head[0]), int(head[1])
        fmt = head[2] if len(head) == 3 else "0"
        has_ew = fmt.endswith("1")
        has_vw = len(fmt) >= 2 and fmt[-2] == "1"
        for _ in range(n):
            tok = next(body).split()
            cnt = len(tok) - (1 if has_vw else 0)
            degs.append(cnt // 2 if has_ew else cnt)
    degs = np.asarray(degs, dtype=np.uint64)
    e = int(degs.sum())
    base = (3 + n + 1) * _W
    offsets = base + np.concatenate([[0], np.cumsum(degs)]).astype(np.uint64) * _W
    # pass 2: stream targets
    with open(out_path, "wb") as out, open(metis_path) as f:
        out.write(np.array([_VERSION, n, e], dtype=np.uint64).tobytes())
        out.write(offsets.astype(np.uint64).tobytes())
        lines = (l.strip() for l in f)
        body = (l for l in lines if l and not l.startswith("%"))
        next(body)  # header
        for _ in range(n):
            tok = [int(t) for t in next(body).split()]
            if has_vw:
                tok = tok[1:]
            tgts = tok[0::2] if has_ew else tok
            out.write((np.asarray(tgts, dtype=np.uint64) - 1).tobytes())


def write_partition_binary(part: np.ndarray, path: str) -> None:
    part = np.asarray(part, dtype=np.uint64)
    with open(path, "wb") as f:
        f.write(np.array([len(part)], dtype=np.uint64).tobytes())
        f.write(part.tobytes())


def read_partition_binary(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        n = int(np.frombuffer(f.read(_W), dtype=np.uint64)[0])
        return np.frombuffer(f.read(n * _W), dtype=np.uint64).astype(np.int64)
