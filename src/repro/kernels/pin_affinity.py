"""Pallas TPU kernel: per-net pin-count histogram — the hot loop of
hypergraph LP refinement and clustering (core/hypergraph/refine.py).

Computes, for every net e and block b over the net→pin ELL layout:

  cnt[e, b]   = Σ_j  mask[e, j] · [pin_lab[e, j] == b]      (pin count)
  score[e, b] = w(e) · cnt[e, b]                            (weighted)

The vertex-side pin affinity ``aff[v, b] = Σ_{e ∋ v} w(e)·|{u ∈ e :
lab[u] = b}|`` is then one XLA gather+sum of ``score`` rows over the
vertex→nets ELL (kernels/ops.py) — irregular gathers stay outside the
kernel exactly as in lp_affinity.py.

Same design as lp_affinity (128-row tiles, one-hot contraction on the VPU,
dmax walked in chunks of DC); the differences are the per-row net-weight
scaling fused into the kernel and the dual (cnt, score) output, which the
refinement gain formulas both need (λ−1 gains want raw counts, absorption
affinities want weighted scores).

Grid: (e_pad/BN, k_pad/BK); net weights ride along as a (BN, 1) column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lp_affinity import BN, BK, DC


def _pin_affinity_kernel(pin_lab_ref, mask_ref, netw_ref, cnt_ref, score_ref):
    """One (BN nets × BK blocks) tile of (cnt, score)."""
    j = pl.program_id(1)
    lab = pin_lab_ref[...]          # (BN, pmax) int32
    mask = mask_ref[...]            # (BN, pmax) f32
    netw = netw_ref[...]            # (BN, 1) f32
    pmax = lab.shape[1]
    base = j * BK
    kids = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, BK), 2)

    # strong-typed counter scan (fori_loop would seed a weak-int32 carry
    # from its python bounds — the repro.analysis hygiene contract)
    def step(carry, _):
        d, acc = carry
        lab_c = jax.lax.dynamic_slice(lab, (0, d * DC), (BN, DC))
        msk_c = jax.lax.dynamic_slice(mask, (0, d * DC), (BN, DC))
        hit = (lab_c[:, :, None] == kids).astype(jnp.float32)  # (BN, DC, BK)
        return (d + 1, acc + jnp.sum(hit * msk_c[:, :, None], axis=1)), None

    carry0 = (jnp.int32(0), jnp.zeros((BN, BK), jnp.float32))
    (_, cnt), _ = jax.lax.scan(step, carry0, None, length=pmax // DC)
    cnt_ref[...] = cnt
    score_ref[...] = cnt * netw


@functools.partial(jax.jit, static_argnames=("k_pad", "interpret"))
def pin_affinity_pallas(pin_lab: jax.Array, mask: jax.Array,
                        netw: jax.Array, k_pad: int,
                        interpret: bool = False):
    """(e_pad, pmax) pin labels/mask + (e_pad,) net weights →
    ((e_pad, k_pad) counts, (e_pad, k_pad) weighted scores).

    Requires e_pad % BN == 0, k_pad % BK == 0, pmax % DC == 0.
    """
    e_pad, pmax = pin_lab.shape
    assert e_pad % BN == 0 and k_pad % BK == 0 and pmax % DC == 0, (
        e_pad, k_pad, pmax)
    grid = (e_pad // BN, k_pad // BK)
    return pl.pallas_call(
        _pin_affinity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, pmax), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, pmax), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BN, BK), lambda i, j: (i, j)),
            pl.BlockSpec((BN, BK), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_pad, k_pad), jnp.float32),
            jax.ShapeDtypeStruct((e_pad, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(pin_lab, mask, netw.reshape(e_pad, 1))
