"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they run in
``interpret=True`` mode — the kernel body executes in Python with the same
block decomposition, validating tiling and semantics.

Masking contract (DESIGN.md §12): every input dimension may arrive padded
to its pow2 shape bucket, and tile correctness relies ONLY on weight masks
— ``wgt == 0`` for ELL slots, ``pin_mask == 0`` for pin slots, ``netw ==
0`` for padding nets, zero capacity for bucket-padding blocks (k_pad > k).
Index sentinels (slot id n_pad-1 etc.) are never trusted as masks: a
padded slot may alias a real row when a dim lands exactly on its bucket.
Affinity columns for capacity-zero padding blocks are computed but can
never win a gain comparison, so k-bucketed calls share one tile program
with the larger-k calls they pad up to.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import lp_affinity as _lpk
from repro.kernels import pin_affinity as _pink
from repro.kernels import ssd_scan as _ssdk
from repro.kernels import ref as _ref


#: Machine-readable form of the masking contract above, keyed by public op:
#: ``mask`` is the argument whose zeros mark padding slots, ``garbage`` the
#: index arguments whose padded slots are unconstrained (any valid id).  The
#: `repro.analysis` padding-inertness checker perturbs exactly the garbage
#: slots and requires bit-identical real outputs.
PADDING_CONTRACT = {
    "lp_affinity": {"mask": "wgt", "garbage": ("nbr",)},
    "sep_affinity": {"mask": "wgt", "garbage": ("nbr",)},
    "pin_count": {"mask": "pin_mask", "garbage": ("pins",)},
    "pin_affinity": {"mask": "pin_mask", "garbage": ("pins", "vnets")},
}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def lp_affinity(nbr: jax.Array, wgt: jax.Array, labels: jax.Array,
                k: int, use_pallas: bool = True) -> jax.Array:
    """ELL graph + labels → (n_pad, k) block affinities.

    The neighbour-label gather runs in XLA (memory-bound); the one-hot
    contraction runs in the Pallas kernel (compute-bound).  Padded ELL slots
    carry wgt == 0, so their (valid) gathered labels contribute nothing.
    """
    nbr_lab = labels[nbr]                         # XLA gather
    if not use_pallas:
        return _ref.affinity_ref(nbr_lab, wgt, k)
    n_pad, dmax = nbr.shape
    k_pad = _round_up(k, _lpk.BK)
    d_pad = _round_up(dmax, _lpk.DC)
    if d_pad != dmax:
        pad = d_pad - dmax
        nbr_lab = jnp.pad(nbr_lab, ((0, 0), (0, pad)), constant_values=0)
        wgt = jnp.pad(wgt, ((0, 0), (0, pad)))
    aff = _lpk.affinity_pallas(nbr_lab, wgt, k_pad, interpret=_interpret())
    return aff[:, :k]


def sep_affinity(nbr: jax.Array, wgt: jax.Array, vwgt: jax.Array,
                 labels: jax.Array, use_pallas: bool = True) -> jax.Array:
    """ELL graph + 3-labels → (n_pad, 3) neighbour *vertex-weight* histogram
    — the separator-gain contraction (DESIGN.md §8).

    Same kernel as ``lp_affinity`` with k=3 and the edge weights replaced by
    gathered neighbour vertex weights; ``wgt > 0`` is the invariant mask (a
    padded ELL slot may alias a real vertex when n == n_pad, so the edge
    weight — zero exactly on padding — gates the gather, not the slot id).
    """
    vw_nbr = jnp.where(wgt > 0, vwgt[nbr], 0.0)
    return lp_affinity(nbr, vw_nbr, labels, 3, use_pallas=use_pallas)


def pin_count(pins: jax.Array, pin_mask: jax.Array, netw: jax.Array,
              labels: jax.Array, k: int, use_pallas: bool = True):
    """Net→pin ELL + labels → ((e_pad, k) pin counts, weighted scores).

    The pin-label gather runs in XLA (memory-bound); the one-hot contraction
    and net-weight scaling run in the Pallas kernel (compute-bound).  Padded
    pin slots carry pin_mask == 0 and contribute nothing.
    """
    pin_lab = labels[pins]                        # XLA gather
    if not use_pallas:
        cnt, score = _ref.pin_count_ref(pin_lab, pin_mask, netw, k)
        return cnt, score
    e_pad, pmax = pins.shape
    k_pad = _round_up(k, _lpk.BK)
    p_pad = _round_up(pmax, _lpk.DC)
    if p_pad != pmax:
        pad = p_pad - pmax
        pin_lab = jnp.pad(pin_lab, ((0, 0), (0, pad)), constant_values=0)
        pin_mask = jnp.pad(pin_mask, ((0, 0), (0, pad)))
    cnt, score = _pink.pin_affinity_pallas(pin_lab, pin_mask, netw, k_pad,
                                           interpret=_interpret())
    return cnt[:, :k], score[:, :k]


def pin_affinity(vnets: jax.Array, pins: jax.Array, pin_mask: jax.Array,
                 netw: jax.Array, labels: jax.Array, k: int,
                 use_pallas: bool = True) -> jax.Array:
    """Dual-ELL hypergraph + labels → (n_pad, k) pin affinities:

        aff[v, b] = Σ_{e ∋ v} w(e) · |{pins of e with label b}|

    Per-net scores come from the Pallas kernel; the irregular vertex-side
    accumulation is an XLA gather+sum over ``vnets`` rows (padding slots
    point at a zero-weight net)."""
    _, score = pin_count(pins, pin_mask, netw, labels, k,
                         use_pallas=use_pallas)
    return jnp.sum(score[vnets], axis=1)


def ssd_scan(x: jax.Array, logdecay: jax.Array, b: jax.Array, c: jax.Array,
             chunk: int = 128, use_pallas: bool = True) -> jax.Array:
    """Mamba2 SSD scan: (BH, L, P) × (BH, L) × (BH, L, N)² → (BH, L, P)."""
    if not use_pallas:
        return _ref.ssd_scan_ref(x, logdecay, b, c)
    l = x.shape[1]
    if l % chunk != 0:
        pad = _round_up(l, chunk) - l
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        logdecay = jnp.pad(logdecay, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y = _ssdk.ssd_scan_pallas(x, logdecay, b, c, chunk=chunk,
                              interpret=_interpret())
    return y[:, :l]
