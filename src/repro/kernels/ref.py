"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def affinity_ref(nbr_lab: jax.Array, wgt: jax.Array, k_pad: int) -> jax.Array:
    """aff[v, b] = Σ_j wgt[v, j] · [nbr_lab[v, j] == b]  — (n_pad, k_pad)."""
    hit = jax.nn.one_hot(nbr_lab, k_pad, dtype=jnp.float32)   # (n, d, k)
    return jnp.einsum("nd,ndk->nk", wgt.astype(jnp.float32), hit)


def pin_count_ref(pin_lab: jax.Array, mask: jax.Array, netw: jax.Array,
                  k_pad: int):
    """(cnt, score) oracle for the pin-affinity kernel.

    cnt[e, b] = Σ_j mask[e, j]·[pin_lab[e, j] == b];  score = netw·cnt.
    Counts are small integers in f32, so sums are exact and both outputs
    match the Pallas kernel bit-for-bit (for integer-valued net weights).
    """
    hit = jax.nn.one_hot(pin_lab, k_pad, dtype=jnp.float32)   # (e, p, k)
    cnt = jnp.einsum("ep,epk->ek", mask.astype(jnp.float32), hit)
    return cnt, cnt * netw[:, None]


def pin_affinity_ref(vnets: jax.Array, pin_lab: jax.Array, mask: jax.Array,
                     netw: jax.Array, k_pad: int) -> jax.Array:
    """aff[v, b] = Σ_{e ∈ vnets[v]} netw[e] · cnt[e, b]  — (n_pad, k_pad).

    Padding slots of ``vnets`` point at a padding net (netw == 0)."""
    _, score = pin_count_ref(pin_lab, mask, netw, k_pad)
    return jnp.sum(score[vnets], axis=1)


def ssd_scan_ref(x: jax.Array, logdecay: jax.Array, b: jax.Array,
                 c: jax.Array) -> jax.Array:
    """Exact sequential SSD recurrence.

    h_t = exp(logdecay_t) · h_{t-1} + b_t ⊗ x_t ;  y_t = h_tᵀ c_t
    x: (BH, L, P), logdecay: (BH, L), b/c: (BH, L, N) → y: (BH, L, P)
    """
    bh, l, p = x.shape
    n = b.shape[-1]

    def step(h, inp):
        xt, ldt, bt, ct = inp
        h = jnp.exp(ldt)[:, None, None] * h + bt[:, :, None] * xt[:, None, :]
        y = jnp.einsum("znp,zn->zp", h, ct)
        return h, y

    h0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (jnp.swapaxes(x, 0, 1), jnp.swapaxes(logdecay, 0, 1),
          jnp.swapaxes(b, 0, 1), jnp.swapaxes(c, 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1)
