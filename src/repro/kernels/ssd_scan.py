"""Pallas TPU kernel: Mamba2 SSD chunked scan (zamba2's mixer; DESIGN.md §6).

State-space recurrence  h_t = a_t · h_{t-1} + B_t xᵀ_t ,  y_t = C_t · h_t
with a_t = exp(A·dt_t) scalar per head.  The chunked formulation turns the
sequential recurrence into per-chunk MXU matmuls (Dao & Gu, 2024), TPU-native:

  per chunk c (length Q), with log-decay cumsum s_t:
    L[t,u]   = exp(s_t - s_u)   for u ≤ t           (Q × Q, causal)
    Y_intra  = ((C Bᵀ) ⊙ L) X                       (Q×N)(N×Q)(Q×P)
    Y_inter  = diag(exp(s)) C h_prev                (Q×N)(N×P)
    h_next   = exp(s_Q) h_prev + Bᵀ diag(exp(s_Q - s)) X

Grid: (BH, n_chunks) — the chunk axis is innermost and TPU grids execute
sequentially per core, so the (N, P) state lives in a VMEM scratch carried
across chunk steps (reset at chunk 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, logdecay_ref, b_ref, c_ref, y_ref, h_scratch):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0]                      # (Q, P)
    ld = logdecay_ref[0]              # (Q,)
    bm = b_ref[0]                     # (Q, N)
    cm = c_ref[0]                     # (Q, N)
    q = x.shape[0]

    s = jnp.cumsum(ld)                                    # (Q,)
    # causal decay matrix  L[t, u] = exp(s_t - s_u) · [u <= t]
    diff = s[:, None] - s[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # (Q, Q)
    y_intra = jnp.dot(cb * lmat, x, preferred_element_type=jnp.float32)

    h_prev = h_scratch[...]                               # (N, P)
    y_inter = jnp.exp(s)[:, None] * jnp.dot(
        cm, h_prev, preferred_element_type=jnp.float32)   # (Q, P)

    total = s[q - 1]
    wlast = jnp.exp(total - s)                            # (Q,)
    h_new = jnp.exp(total) * h_prev + jnp.dot(
        bm.T * wlast[None, :], x, preferred_element_type=jnp.float32)
    h_scratch[...] = h_new
    y_ref[0] = y_intra + y_inter


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x: jax.Array, logdecay: jax.Array, b: jax.Array,
                    c: jax.Array, chunk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Chunked SSD scan.

    x        : (BH, L, P)   inputs (already multiplied by dt where needed)
    logdecay : (BH, L)      A·dt per step (negative)
    b, c     : (BH, L, N)   input/output projections
    returns  : (BH, L, P)
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    grid = (bh, l // chunk)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), jnp.float32),
        # (N, P) state carried across the sequential chunk axis
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, logdecay, b, c)
