"""Pallas TPU kernel: block-affinity histogram — the hot loop of every
LP-based phase (coarsening clustering, LP refinement, ParHIP rounds).

Computes  aff[v, b] = Σ_j  wgt[v, j] · [nbr_lab[v, j] == b]
i.e. ``A_ELL @ onehot(labels)`` — an (n × dmax × k) contraction.

TPU adaptation (DESIGN.md §2/§6): the irregular CSR gather (labels of
neighbours) is done by XLA outside the kernel (memory-bound, gather engine);
the FLOP-dense one-hot contraction runs here on 128-row tiles resident in
VMEM, accumulating a (128, k_tile) affinity tile on the VPU.  dmax is walked
in chunks of 8 so the expanded (128, 8, 128) compare cube stays ~0.5 MB.

Grid: (n_pad/BN, k_pad/BK); BlockSpecs pin rows to tiles, labels/weights
blocks are re-streamed per k-tile (k_pad/BK is almost always 1 for
partitioning workloads: k ≤ 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 128          # rows per tile (sublane-aligned)
BK = 128          # blocks per tile (lane-aligned)
DC = 8            # dmax chunk walked per inner step


def _affinity_kernel(nbr_lab_ref, wgt_ref, out_ref):
    """One (BN rows × BK labels) output tile."""
    j = pl.program_id(1)
    lab = nbr_lab_ref[...]          # (BN, dmax) int32
    wgt = wgt_ref[...]              # (BN, dmax) f32
    dmax = lab.shape[1]
    base = j * BK
    kids = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, BK), 2)

    # strong-typed counter scan (fori_loop would seed a weak-int32 carry
    # from its python bounds — the repro.analysis hygiene contract)
    def step(carry, _):
        d, acc = carry
        lab_c = jax.lax.dynamic_slice(lab, (0, d * DC), (BN, DC))
        wgt_c = jax.lax.dynamic_slice(wgt, (0, d * DC), (BN, DC))
        hit = (lab_c[:, :, None] == kids).astype(jnp.float32)   # (BN, DC, BK)
        return (d + 1, acc + jnp.sum(hit * wgt_c[:, :, None], axis=1)), None

    carry0 = (jnp.int32(0), jnp.zeros((BN, BK), jnp.float32))
    (_, acc), _ = jax.lax.scan(step, carry0, None, length=dmax // DC)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("k_pad", "interpret"))
def affinity_pallas(nbr_lab: jax.Array, wgt: jax.Array, k_pad: int,
                    interpret: bool = False) -> jax.Array:
    """(n_pad, dmax) neighbour labels/weights → (n_pad, k_pad) affinities.

    Requires n_pad % BN == 0, k_pad % BK == 0, dmax % DC == 0.
    """
    n_pad, dmax = nbr_lab.shape
    assert n_pad % BN == 0 and k_pad % BK == 0 and dmax % DC == 0, (
        n_pad, k_pad, dmax)
    grid = (n_pad // BN, k_pad // BK)
    return pl.pallas_call(
        _affinity_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BN, dmax), lambda i, j: (i, 0)),
            pl.BlockSpec((BN, dmax), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BN, BK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(nbr_lab, wgt)
