"""Version-compatibility shims for the pinned container toolchain.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace around 0.4.35/0.5, and its replication-check kwarg was
renamed ``check_rep`` -> ``check_vma`` along the way.  Import it from here so
every call site works on both sides of the move, using the new-style
``check_vma`` spelling.
"""
from __future__ import annotations

import inspect

try:                                    # jax >= 0.5-ish
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                     # older: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kwargs):
    """``jax.shard_map`` with the kwarg spelling the local jax understands."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    if f is None:                       # decorator-style partial application
        return lambda fn: _shard_map(fn, **kwargs)
    return _shard_map(f, **kwargs)
