"""Training step: next-token loss, grads, AdamW — with optional int8
error-feedback gradient compression on the slow (inter-pod) axis.

The step function is pure and jit/pjit-able; dryrun.py lowers exactly this
function for every (arch × train shape × mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


def next_token_loss(params, cfg: ArchConfig, batch, remat: str = "full"):
    """batch: tokens (B, S+1) [+ prefix_embeds / enc_frames stubs]."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    kw = {}
    if cfg.n_prefix_embeds:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if cfg.enc_layers:
        kw["enc_frames"] = batch["enc_frames"]
    logits, _ = T.forward(params, cfg, inputs, remat=remat, **kw)
    # modality prefixes don't predict tokens — score text positions only
    if cfg.n_prefix_embeds:
        logits = logits[:, cfg.n_prefix_embeds:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def _compress_int8(g: jax.Array, err: jax.Array):
    """Stochastic-free int8 quantization with error feedback (1-bit-Adam
    style).  Models inter-pod gradient compression: the all-reduce of the
    quantized tensor moves 4× fewer bytes on the slowest links."""
    g = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g - deq


def make_train_step(cfg: ArchConfig, opt_cfg: OptConfig,
                    remat: str = "full", grad_compress: bool = False,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    ``microbatches`` > 1 → gradient accumulation over a scan: peak
    activation memory shrinks ~linearly while FLOPs stay constant (the knob
    that fits the big train cells into HBM — EXPERIMENTS.md §Perf).
    opt_state carries an ``err`` pytree when grad_compress is on.
    """

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(next_token_loss)(
                params, cfg, batch, remat)

        mb_batch = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def micro(carry, mb):
            gacc, lacc = carry
            loss, g = jax.value_and_grad(next_token_loss)(
                params, cfg, mb, remat)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (gacc, lacc + loss), None

        init = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params), jnp.float32(0.0))
        (gsum, lsum), _ = jax.lax.scan(micro, init, mb_batch)
        scale = 1.0 / microbatches
        return lsum * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if grad_compress:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = jax.tree.leaves(opt_state["err"])
            pairs = [_compress_int8(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([p[0] for p in pairs])
            new_err = tdef.unflatten([p[1] for p in pairs])
        new_params, new_opt, metrics = adamw_update(
            grads, {k: opt_state[k] for k in ("mu", "nu", "step")},
            params, opt_cfg)
        if grad_compress:
            new_opt["err"] = new_err
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def init_opt_state(params, grad_compress: bool = False):
    st = adamw_init(params)
    if grad_compress:
        st["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return st
