"""Fault tolerance & straggler mitigation around the training loop.

* ``Watchdog`` — per-step wall-time tracking; a step slower than
  ``straggler_factor`` × rolling median flags a straggler (at multi-host
  scale the runner would evict/replace that host and trigger elastic
  resume; here the signal is surfaced + logged).
* ``run_resilient`` — checkpoint every N steps, restart from the latest
  checkpoint after an (injected or real) failure, replaying the data stream
  deterministically from the restored step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class Watchdog:
    straggler_factor: float = 3.0
    window: int = 32
    _times: deque = dataclasses.field(default_factory=lambda: deque(maxlen=64))
    stragglers: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self._times) >= 8:
            med = float(np.median(self._times))
            if dt > self.straggler_factor * med:
                self.stragglers += 1
                is_straggler = True
        self._times.append(dt)
        return is_straggler


def run_resilient(train_step: Callable, params, opt_state, data_iter_fn,
                  n_steps: int, ckpt_dir: str, ckpt_every: int = 20,
                  fail_at: Optional[int] = None, max_restarts: int = 3,
                  log: Optional[Callable] = None):
    """Run ``n_steps`` with checkpoint/restart.  ``fail_at`` injects a crash
    once (tests the recovery path).  data_iter_fn(start_step) must replay
    deterministically."""
    state = (params, opt_state)
    start = ckpt.latest_step(ckpt_dir) or 0
    if start:
        (params, opt_state), _ = ckpt.restore(ckpt_dir, state, step=start)
    restarts = 0
    failed_once = False
    wd = Watchdog()
    step = start
    while step < n_steps:
        try:
            it = data_iter_fn(step)
            while step < n_steps:
                batch = next(it)
                if fail_at is not None and step == fail_at and not failed_once:
                    failed_once = True
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.monotonic()
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                dt = time.monotonic() - t0
                if wd.observe(dt) and log:
                    log(f"straggler at step {step}: {dt:.3f}s")
                step += 1
                if step % ckpt_every == 0 or step == n_steps:
                    ckpt.save(ckpt_dir, step, (params, opt_state),
                              extra={"metrics": {k: float(v) for k, v in
                                                 metrics.items()}})
                if log:
                    log(f"step {step} loss {float(metrics['loss']):.4f}")
        except RuntimeError as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if log:
                log(f"FAILURE ({e}); restart {restarts} from latest ckpt")
            last = ckpt.latest_step(ckpt_dir)
            if last:
                (params, opt_state), _ = ckpt.restore(
                    ckpt_dir, (params, opt_state), step=last)
                step = last
            else:
                step = 0
    return params, opt_state, {"restarts": restarts,
                               "stragglers": wd.stragglers}
