"""Data pipeline: deterministic synthetic token streams (per-shard seeded,
restart-reproducible) plus a byte-level corpus reader.

At 1000+-node scale each data shard derives its stream from
(global step, shard index) alone — no coordination, elastic by construction:
resharding after a failure only changes the (deterministic) assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None


def synthetic_tokens(step: int, shard: int, n_shards: int,
                     cfg: DataConfig) -> np.ndarray:
    """(local_batch, seq_len+1) int32 — a Markov-ish stream so loss can
    actually fall (token t+1 depends on token t)."""
    local = cfg.global_batch // n_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))
    base = rng.integers(0, cfg.vocab, (local, 1))
    steps = rng.integers(1, 17, (local, cfg.seq_len))
    toks = (np.cumsum(np.concatenate([base, steps], 1), axis=1)) % cfg.vocab
    return toks.astype(np.int32)


class CorpusReader:
    """Byte-level corpus with deterministic random access (vocab ≤ 256+)."""

    def __init__(self, path: str, cfg: DataConfig):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        self.cfg = cfg

    def batch(self, step: int, shard: int, n_shards: int) -> np.ndarray:
        cfg = self.cfg
        local = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard, 7]))
        max_start = max(1, len(self.data) - cfg.seq_len - 2)
        starts = rng.integers(0, max_start, local)
        rows = [self.data[s:s + cfg.seq_len + 1] for s in starts]
        return np.stack(rows).astype(np.int32) % cfg.vocab


def batches(cfg: DataConfig, shard: int = 0, n_shards: int = 1,
            start_step: int = 0) -> Iterator[dict]:
    reader = CorpusReader(cfg.corpus_path, cfg) if cfg.corpus_path else None
    step = start_step
    while True:
        if reader is not None:
            toks = reader.batch(step, shard, n_shards)
        else:
            toks = synthetic_tokens(step, shard, n_shards, cfg)
        yield {"tokens": jnp.asarray(toks)}
        step += 1
