"""Pipeline-stage assignment via the paper's partitioner (DESIGN.md §3).

The layer dependency graph is a weighted chain: node weight = per-layer
FLOPs, edge weight = activation bytes crossing the stage boundary.  KaFFPa
with enforce_balance (ε→0, KaBaPE feasibility guarantee) yields
FLOP-balanced stages that cut the cheapest activation edges; contiguity is
restored by a monotone sweep (chains partition into intervals optimally
among contiguous solutions).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.csr import Graph
from repro.core.kaffpa import kaffpa


def layer_costs(cfg: ArchConfig, seq_len: int) -> tuple:
    """(flops_per_layer, act_bytes_between_layers) — per token simplified."""
    d = cfg.d_model
    if cfg.is_moe:
        dff = (cfg.d_ff_expert or cfg.d_ff)
        ff = 6 * d * dff * (cfg.top_k + cfg.n_shared_experts)
    else:
        ff = 6 * d * cfg.d_ff
    attn = 8 * d * d + 4 * d * seq_len        # proj + scores (causal avg)
    fl = np.full(cfg.n_layers, ff + attn, dtype=np.float64)
    act = np.full(cfg.n_layers - 1, 2 * d, dtype=np.float64)  # bf16 resid
    return fl, act


def partition_layers(cfg: ArchConfig, n_stages: int, seq_len: int = 4096,
                     seed: int = 0) -> np.ndarray:
    """stage[i] = pipeline stage of layer i (contiguous, balanced)."""
    fl, act = layer_costs(cfg, seq_len)
    l = cfg.n_layers
    if n_stages <= 1:
        return np.zeros(l, dtype=np.int64)
    scale = max(1.0, fl.max() / 10_000)
    g = Graph.from_edges(l, np.arange(l - 1), np.arange(1, l),
                         np.maximum((act / act.max() * 100), 1).astype(np.int64),
                         vwgt=np.maximum(fl / scale, 1).astype(np.int64))
    part = kaffpa(g, n_stages, 0.03, "fast", seed=seed,
                  enforce_balance=True)
    # contiguity: sweep layers in order, open a new stage when the balanced
    # budget is used up; stage ids follow layer order
    budget = fl.sum() / n_stages
    stage = np.zeros(l, dtype=np.int64)
    acc, s = 0.0, 0
    for i in range(l):
        if acc + fl[i] > budget * 1.05 and s < n_stages - 1:
            s += 1
            acc = 0.0
        stage[i] = s
        acc += fl[i]
    # keep whichever of (kaffpa-projected, sweep) balances better after
    # making kaffpa's solution contiguous by majority vote per interval
    return stage
