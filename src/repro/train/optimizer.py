"""Hand-rolled AdamW + LR schedules (no optax offline).

Includes the WSD (warmup–stable–decay) schedule minicpm trains with
(arXiv:2404.06395) and standard cosine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "wsd"            # wsd | cosine | const
    warmup_steps: int = 100
    stable_steps: int = 1000
    decay_steps: int = 100
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.schedule == "const":
        return cfg.peak_lr * warm
    if cfg.schedule == "cosine":
        total = cfg.stable_steps + cfg.decay_steps
        t = jnp.clip((s - cfg.warmup_steps) / total, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.peak_lr * warm * (cfg.min_lr_frac
                                     + (1 - cfg.min_lr_frac) * cos)
    # WSD: warmup → stable plateau → sharp decay (minicpm)
    in_decay = s > (cfg.warmup_steps + cfg.stable_steps)
    t = jnp.clip((s - cfg.warmup_steps - cfg.stable_steps) / cfg.decay_steps,
                 0.0, 1.0)
    decay = cfg.min_lr_frac ** t
    return cfg.peak_lr * warm * jnp.where(in_decay, decay, 1.0)


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)  # noqa: E731
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype), \
            mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"lr": lr, "grad_norm": gn}
