"""Fault-tolerant checkpointing.

* atomic: write to ``<dir>/tmp.<step>`` then rename to ``<dir>/step_<step>``
  — a crash mid-write never corrupts the latest checkpoint;
* manifest.json records step, pytree structure, shapes, dtypes and a config
  fingerprint — restore refuses silently-mismatched trees;
* elastic: arrays are saved unsharded (host-gathered); restore re-shards onto
  whatever mesh the restarted job has (device count may differ);
* retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree of NamedSharding) re-shards for elastic resume."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, tree expects "
            f"{len(leaves_like)} — config mismatch?")
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    for i, (ref, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = data[f"leaf_{i}"]
        if list(arr.shape) != list(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
        arr = arr.astype(np.asarray(ref).dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
