"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are up-projected from a shared compressed latent c_kv (kv_lora wide) plus
one shared RoPE key head; Q comes through its own low-rank path (q_lora).
The decode cache stores ONLY (c_kv, k_rope) — (kv_lora + rope_hd) floats per
token per layer instead of 2·H·hd — which is why a 500k-token MLA cache is
small (DESIGN.md §4 notes this, though the cell is still skipped per the
assignment rule).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, normal, rope_freqs


def init_mla(key, cfg, dtype):
    d = cfg.d_model
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": normal(ks[0], (d, cfg.q_lora), 0.02, dtype),
        "q_gamma": jnp.zeros((cfg.q_lora,), dtype),
        "wuq": normal(ks[1], (cfg.q_lora, cfg.n_heads * qk), 0.02, dtype),
        "wdkv": normal(ks[2], (d, cfg.kv_lora), 0.02, dtype),
        "kv_gamma": jnp.zeros((cfg.kv_lora,), dtype),
        "wkr": normal(ks[3], (d, cfg.rope_head_dim), 0.02, dtype),
        "wuk": normal(ks[4], (cfg.kv_lora, cfg.n_heads * cfg.nope_head_dim),
                      0.02, dtype),
        "wuv": normal(ks[5], (cfg.kv_lora, cfg.n_heads * cfg.v_head_dim),
                      0.02, dtype),
        "wo": normal(ks[6], (cfg.n_heads * cfg.v_head_dim, d), 0.02, dtype),
    }


def mla_attention(params, x, cfg, positions, cache=None, cache_pos=None):
    """Returns (out, new_cache); cache = dict(ckv=(B,Smax,kv_lora),
    kr=(B,Smax,rope_hd))."""
    from repro.models.layers import rmsnorm
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    cq = rmsnorm(x @ params["wdq"], params["q_gamma"], cfg.norm_eps)
    q = (cq @ params["wuq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = rmsnorm(x @ params["wdkv"], params["kv_gamma"], cfg.norm_eps)
    kr = (x @ params["wkr"]).reshape(b, s, 1, dr)
    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr = apply_rope(kr, cos, sin)
    new_cache = None
    if cache is not None:
        ckv_c = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_pos, 0))
        kr_c = jax.lax.dynamic_update_slice(
            cache["kr"], kr[:, :, 0].astype(cache["kr"].dtype),
            (0, cache_pos, 0))
        new_cache = {"ckv": ckv_c, "kr": kr_c}
        ckv_all, kr_all = ckv_c, kr_c[:, :, None]
        kv_len = ckv_all.shape[1]
        kidx = jnp.arange(kv_len)[None, :]
        qidx = cache_pos + jnp.arange(s)[:, None]
        mask = kidx <= qidx
    else:
        ckv_all, kr_all = ckv, kr
        kv_len = s
        mask = jnp.tril(jnp.ones((s, kv_len), bool))
    scale = 1.0 / jnp.sqrt(dn + dr)
    if s == 1 and cache is not None:
        # DECODE: weight absorption (DeepSeek-V2 §"low-rank KV") — attention
        # runs entirely in the compressed kv_lora space; the (S, h, dn) and
        # (S, h, dv) up-projections are NEVER materialized for the cache.
        wuk = params["wuk"].reshape(cfg.kv_lora, h, dn)
        wuv = params["wuv"].reshape(cfg.kv_lora, h, dv)
        q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, wuk)     # (b,1,h,lora)
        logits = (jnp.einsum("bqhl,bkl->bhqk", q_abs, ckv_all)
                  + jnp.einsum("bqhd,bkod->bhqk", q_rope, kr_all)
                  ).astype(jnp.float32) * scale
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1).astype(ckv_all.dtype)
        ctx = jnp.einsum("bhqk,bkl->bqhl", p, ckv_all)        # (b,1,h,lora)
        out = jnp.einsum("bqhl,lhd->bqhd", ctx, wuv).reshape(b, s, h * dv)
        return out @ params["wo"], new_cache
    k_nope = (ckv_all @ params["wuk"]).reshape(b, kv_len, h, dn)
    v = (ckv_all @ params["wuv"]).reshape(b, kv_len, h, dv)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkod->bhqk", q_rope, kr_all)
              ).astype(jnp.float32) * scale
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, h * dv)
    return out @ params["wo"], new_cache
