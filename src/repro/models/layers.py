"""Shared neural net layers (pure functions over param pytrees; no flax).

Sharding is expressed through ``logical`` axis names resolved against the
mesh by models/shardings.py; activations use with_sharding_constraint at the
few places that matter (post-projection residual stream).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def rope_freqs(positions: jax.Array, dim: int, theta: float) -> tuple:
    """positions: (...,) int32 → cos/sin of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, dim); cos/sin: (..., seq, dim//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, window: Optional[int] = None,
                q_offset: int = 0) -> jax.Array:
    """(q_len, kv_len) bool mask; True = attend."""
    q = jnp.arange(q_len)[:, None] + q_offset
    k = jnp.arange(kv_len)[None, :]
    m = k <= q
    if window is not None:
        m = m & (k > q - window)
    return m
