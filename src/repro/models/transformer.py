"""The model zoo assembler: one config-driven decoder (+optional encoder)
covering all ten assigned architectures.

Structure: scan-over-layers with stacked params (compile-size O(1) in depth),
optional remat on the block body, KV/SSM caches threaded through the scan.
Families:
  dense                  — pre-norm GQA + SwiGLU (starcoder2, mistral-large,
                           minicpm, internvl2 backbone)
  dense + local/global   — gemma2 (alternating window mask, softcaps, post-norms)
  moe                    — llama4-scout (top-1 + shared), deepseek-v2 (MLA +
                           2 shared + 160 routed top-6)
  hybrid                 — zamba2: Mamba2 stack with ONE weight-shared
                           attention+MLP block applied every `attn_every`
                           layers (its KV caches are per *application*)
  ssm                    — rwkv6 (time-mix + channel-mix)
  audio enc-dec          — whisper (stub frame embeddings → encoder; decoder
                           with cross-attention)
  vlm                    — internvl2 (stub patch embeddings prefix)
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models import shardings as SH
from repro.models.layers import normal, rmsnorm, softcap, swiglu


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_gelu:            # starcoder2: 2-matrix GELU MLP
        return {
            "w_up": normal(k2, (cfg.d_model, cfg.d_ff), 0.02, dtype),
            "w_down": normal(k3, (cfg.d_ff, cfg.d_model), 0.02, dtype),
        }
    return {
        "w_gate": normal(k1, (cfg.d_model, cfg.d_ff), 0.02, dtype),
        "w_up": normal(k2, (cfg.d_model, cfg.d_ff), 0.02, dtype),
        "w_down": normal(k3, (cfg.d_ff, cfg.d_model), 0.02, dtype),
    }


def _mlp(p, x, cfg):
    if cfg.mlp_gelu:
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def _init_block(key, cfg, dtype, cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"ln1": jnp.zeros((d,), dtype)}
    if cfg.family == "ssm" and cfg.rwkv:
        p["tmix"] = R6.init_rwkv6(ks[0], cfg, dtype)
        p["ln2"] = jnp.zeros((d,), dtype)
        p["cmix"] = R6.init_rwkv6_channel_mix(ks[1], cfg, dtype)
        return p
    if cfg.is_mla:
        p["attn"] = MLA.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = A.init_attn(ks[0], cfg, dtype)
    p["ln2"] = jnp.zeros((d,), dtype)
    if cfg.is_moe:
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    if cross:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xattn"] = A.init_attn(ks[2], cfg, dtype)
    if cfg.local_global_alternate:      # gemma2 post-norms
        p["post1"] = jnp.zeros((d,), dtype)
        p["post2"] = jnp.zeros((d,), dtype)
    return p


def _init_mamba_block(key, cfg, dtype):
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "mamba": M2.init_mamba2(key, cfg, dtype)}


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.n_layers + cfg.enc_layers + 4)
    params = {
        "embed": normal(keys[0], (cfg.vocab_pad, cfg.d_model), 0.02, dtype),
        "final_gamma": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal(keys[1], (cfg.d_model, cfg.vocab_pad),
                                   0.02, dtype)
    if cfg.family == "hybrid":
        blocks = [_init_mamba_block(keys[2 + i], cfg, dtype)
                  for i in range(cfg.n_layers)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        shared_key = keys[2 + cfg.n_layers]
        sk = jax.random.split(shared_key, 3)
        params["shared"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": A.init_attn(sk[0], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": _init_mlp(sk[1], cfg, dtype),
        }
        return params
    blocks = [_init_block(keys[2 + i], cfg, dtype,
                          cross=cfg.enc_layers > 0)
              for i in range(cfg.n_layers)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    if cfg.enc_layers:
        enc = [_init_block(keys[2 + cfg.n_layers + i], cfg, dtype)
               for i in range(cfg.enc_layers)]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_final_gamma"] = jnp.zeros((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.float32, enc_len: Optional[int] = None) -> dict:
    hd = cfg.hd
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        return {
            "attn": {
                "k": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, hd),
                               dtype),
            },
            "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                              cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_state), dtype),
        }
    if cfg.family == "ssm" and cfg.rwkv:
        h = cfg.d_model // cfg.ssm_head_dim
        l = cfg.n_layers
        return {
            "prev": jnp.zeros((l, batch, cfg.d_model), jnp.float32),
            "wkv": jnp.zeros((l, batch, h, cfg.ssm_head_dim,
                              cfg.ssm_head_dim), jnp.float32),
            "prev_cm": jnp.zeros((l, batch, cfg.d_model), jnp.float32),
        }
    if cfg.is_mla:
        return {
            "ckv": jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora),
                             dtype),
            "kr": jnp.zeros((cfg.n_layers, batch, max_len,
                             cfg.rope_head_dim), dtype),
        }
    out = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                       dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                       dtype),
    }
    if cfg.enc_layers:          # cross-attention K/V, filled at prefill
        el = enc_len if enc_len is not None else cfg.enc_positions
        out["xk"] = jnp.zeros((cfg.n_layers, batch, el, cfg.n_kv_heads, hd),
                              dtype)
        out["xv"] = jnp.zeros((cfg.n_layers, batch, el, cfg.n_kv_heads, hd),
                              dtype)
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

# layer-scan unroll control: dryrun's cost-correction variants fully unroll
# the (1- or 2-layer) scans so XLA cost_analysis sees every trip
_SCAN_UNROLL = 1


@contextlib.contextmanager
def layer_unroll(n: int):
    global _SCAN_UNROLL
    prev = _SCAN_UNROLL
    _SCAN_UNROLL = n
    try:
        yield
    finally:
        _SCAN_UNROLL = prev


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _dense_block(p, x, cfg, positions, window, cache, cache_pos, enc_out):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.is_mla:
        a, new_cache = MLA.mla_attention(p["attn"], h, cfg, positions,
                                         cache=cache, cache_pos=cache_pos)
    else:
        a, new_cache = A.attention(p["attn"], h, cfg, positions,
                                   window=window, cache=cache,
                                   cache_pos=cache_pos)
    if cfg.local_global_alternate:
        a = rmsnorm(a, p["post1"], cfg.norm_eps)
    x = x + a
    cross_kv_out = None
    if enc_out is not None or (cache is not None and "xk" in cache):
        hx = rmsnorm(x, p["ln_x"], cfg.norm_eps)
        if enc_out is not None:
            kv = A.init_cross_kv(p["xattn"], enc_out, cfg)
            cross_kv_out = kv                  # prefill: store in the cache
        else:
            kv = (cache["xk"], cache["xv"])    # decode: reuse cached K/V
        cx, _ = A.attention(p["xattn"], hx, cfg, positions, is_causal=False,
                            kv_override=kv)
        x = x + cx
    h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        f = MOE.moe_ffn_a2a(p["moe"], h2, cfg)  # falls back off-mesh
    else:
        f = _mlp(p["mlp"], h2, cfg)
    if cfg.local_global_alternate:
        f = rmsnorm(f, p["post2"], cfg.norm_eps)
    if new_cache is not None and cache is not None and "xk" in cache:
        new_cache["xk"] = (cross_kv_out[0].astype(cache["xk"].dtype)
                           if cross_kv_out is not None else cache["xk"])
        new_cache["xv"] = (cross_kv_out[1].astype(cache["xv"].dtype)
                           if cross_kv_out is not None else cache["xv"])
    return x + f, new_cache


def _run_decoder(params, cfg, x, positions, caches, cache_pos, enc_out,
                 remat: str):
    """Scan the (stacked) decoder blocks; returns (x, new_caches)."""
    l = cfg.n_layers
    if cfg.family == "hybrid":
        return _run_hybrid(params, cfg, x, positions, caches, cache_pos,
                           remat)
    layer_ids = jnp.arange(l)
    if cfg.local_global_alternate and cfg.window:
        windows = jnp.where(layer_ids % 2 == 0, cfg.window, 1 << 30)
    elif cfg.window:
        windows = jnp.full((l,), cfg.window)
    else:
        windows = jnp.full((l,), 1 << 30)

    def body(x, inp):
        p, win, cache = inp
        if cfg.family == "ssm" and cfg.rwkv:
            st = None if cache is None else {"prev": cache["prev"],
                                             "wkv": cache["wkv"]}
            h = rmsnorm(x, p["ln1"], cfg.norm_eps)
            t, new_t = R6.rwkv6_time_mix(p["tmix"], h, cfg, st)
            x = x + t
            h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
            cm_state = None if cache is None else cache["prev_cm"]
            c, new_cm = R6.rwkv6_channel_mix(p["cmix"], h2, cm_state)
            x = x + c
            new_cache = None if cache is None else {
                "prev": new_t["prev"], "wkv": new_t["wkv"],
                "prev_cm": new_cm}
            return SH.constrain_residual(x), new_cache
        x, new_cache = _dense_block(p, x, cfg, positions, win, cache,
                                    cache_pos, enc_out)
        x = SH.constrain_residual(x)
        return x, (new_cache if cache is not None else None)

    body = _maybe_remat(body, remat)
    if caches is None:
        x, _ = jax.lax.scan(lambda c, i: body(c, (i[0], i[1], None)),
                            x, (params["blocks"], windows),
                            unroll=min(_SCAN_UNROLL, l))
        return x, None
    x, new_caches = jax.lax.scan(
        lambda c, i: body(c, i), x, (params["blocks"], windows, caches),
        unroll=min(_SCAN_UNROLL, l))
    return x, new_caches


def _run_hybrid(params, cfg, x, positions, caches, cache_pos, remat: str):
    """zamba2: groups of `attn_every` mamba layers + one shared attn block."""
    every = cfg.attn_every
    groups = cfg.n_layers // every
    gp = jax.tree.map(
        lambda a: a.reshape((groups, every) + a.shape[1:]), params["blocks"])
    shared = params["shared"]

    def mamba_one(x, inp):
        p, st = inp
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, new_st = M2.mamba2_mixer(p["mamba"], h, cfg, state=st)
        return x + y, new_st

    mamba_one = _maybe_remat(mamba_one, remat)

    def group_body(x, inp):
        p_grp, attn_cache, ssm_grp = inp
        # unroll: every mamba layer appears in the HLO (cost-analysis truth)
        if ssm_grp is None:
            x, _ = jax.lax.scan(lambda c, i: mamba_one(c, (i, None)),
                                x, p_grp, unroll=every)
            new_ssm = None
        else:
            x, new_ssm = jax.lax.scan(mamba_one, x, (p_grp, ssm_grp),
                                      unroll=every)
        h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
        a, new_kv = A.attention(shared["attn"], h, cfg, positions,
                                cache=attn_cache, cache_pos=cache_pos)
        x = x + a
        h2 = rmsnorm(x, shared["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                       shared["mlp"]["w_down"])
        return SH.constrain_residual(x), (new_kv, new_ssm)

    if caches is None:
        x, _ = jax.lax.scan(lambda c, i: group_body(c, (i, None, None)),
                            x, gp, unroll=min(_SCAN_UNROLL, groups))
        return x, None
    ssm_g = jax.tree.map(
        lambda a: a.reshape((groups, every) + a.shape[1:]),
        {"ssm": caches["ssm"], "conv": caches["conv"]})
    x, (new_kv, new_ssm) = jax.lax.scan(
        group_body, x, (gp, caches["attn"], ssm_g),
        unroll=min(_SCAN_UNROLL, groups))
    new_caches = {
        "attn": new_kv,
        "ssm": new_ssm["ssm"].reshape(caches["ssm"].shape),
        "conv": new_ssm["conv"].reshape(caches["conv"].shape),
    }
    return x, new_caches


def _run_encoder(params, cfg, frames, remat: str):
    x = frames
    pos = jnp.arange(frames.shape[1])

    def body(x, p):
        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
        a, _ = A.attention(p["attn"], h, cfg, pos, is_causal=False)
        x = x + a
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + _mlp(p["mlp"], h2, cfg)
        return x, None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=min(_SCAN_UNROLL, params["enc_blocks"][
                            "ln1"].shape[0]))
    return rmsnorm(x, params["enc_final_gamma"], cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, *, prefix_embeds=None,
            enc_frames=None, caches=None, cache_pos=None,
            remat: str = "none"):
    """Returns (logits, new_caches).

    tokens: (B, S) int32.  prefix_embeds: (B, P, d) stub modality embeddings
    prepended to the token embeddings (vlm).  enc_frames: (B, F, d) stub
    audio frames (whisper encoder input).  caches + cache_pos → decode /
    prefill-with-cache mode.
    """
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(
        params["embed"].dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = SH.constrain_residual(x)
    s = x.shape[1]
    pos0 = 0 if cache_pos is None else cache_pos
    positions = pos0 + jnp.arange(s)
    enc_out = None
    if cfg.enc_layers and enc_frames is not None:
        # prefill/train: run the encoder; decode reuses cached cross-K/V
        enc_out = _run_encoder(params, cfg, enc_frames, remat)
    x, new_caches = _run_decoder(params, cfg, x, positions, caches,
                                 cache_pos, enc_out, remat)
    x = rmsnorm(x, params["final_gamma"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = SH.constrain_logits(logits)
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits, new_caches
