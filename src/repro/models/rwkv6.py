"""RWKV6 "Finch" mixer (attention-free, data-dependent decay; arXiv:2404.05892).

Time-mix:   S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ ;  y_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ)
with per-channel decay w_t = exp(−exp(w₀ + tanh(x W₁) W₂)) — the
data-dependent ("Finch") part.  Chunked evaluation: intra-chunk pairwise
terms as einsums, inter-chunk state carried by a scan (O(L·N·P) like SSD).

Decode carries (B, H, N, P) state — O(1)/token, no KV cache: this is why
rwkv6 runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal, rmsnorm


def init_rwkv6(key, cfg, dtype):
    d = cfg.d_model
    lora = 64
    ks = jax.random.split(key, 12)
    return {
        "mu_r": 0.5 * jnp.ones((d,), dtype), "mu_k": 0.5 * jnp.ones((d,), dtype),
        "mu_v": 0.5 * jnp.ones((d,), dtype), "mu_w": 0.5 * jnp.ones((d,), dtype),
        "mu_g": 0.5 * jnp.ones((d,), dtype),
        "wr": normal(ks[0], (d, d), 0.02, dtype),
        "wk": normal(ks[1], (d, d), 0.02, dtype),
        "wv": normal(ks[2], (d, d), 0.02, dtype),
        "wg": normal(ks[3], (d, d), 0.02, dtype),
        "wo": normal(ks[4], (d, d), 0.02, dtype),
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w1": normal(ks[5], (d, lora), 0.02, dtype),
        "w2": normal(ks[6], (lora, d), 0.02, dtype),
        "u": normal(ks[7], (d,), 0.5, jnp.float32),
        "ln_gamma": jnp.zeros((d,), dtype),
    }


LOGW_MIN = -4.0        # decay clip: keeps exp(±chunk·|logw|) inside f32


def _wkv_chunked(r, k, v, logw, u, head_dim: int, chunk: int = 16):
    """r,k,v,logw: (B,L,d); u: (d,).  Per-head linear recurrence.

    The per-channel decay exp(s_{t-1} − s_j) FACTORIZES across the channel
    contraction: A[t,j] = Σ_n (r⊙e^{s_shift})[t,n]·(k⊙e^{−s})[j,n] — a plain
    matmul, no (Q,Q,N) cube.  Cumsums are chunk-relative and logw is clipped
    at LOGW_MIN so neither factor overflows f32 (chunk·|LOGW_MIN| = 64).
    """
    b, l, d = r.shape
    h = d // head_dim
    pad = (-l) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))  # noqa: E731
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    lc = r.shape[1]
    nc = lc // chunk

    def split(a):     # (B,L,d) -> (B*H, NC, Q, hd)
        return (a.reshape(b, nc, chunk, h, head_dim)
                 .transpose(0, 3, 1, 2, 4).reshape(b * h, nc, chunk, head_dim))
    rr, kk, vv, ww = split(r), split(k), split(v), split(logw)
    uu = u.reshape(h, head_dim)
    uu = jnp.tile(uu, (b, 1)).reshape(b * h, head_dim)
    s = jnp.cumsum(ww, axis=2)                 # (BH,NC,Q,hd), chunk-relative
    # contribution of step j<t:  (r_t ⊙ Π_{i=j+1..t-1} w_i ⊙ k_j) · v_j
    # Π_{j+1..t-1} = exp(s_{t-1} − s_j) — shifted cumsum, factorized
    s_shift = jnp.concatenate([jnp.zeros_like(s[:, :, :1]), s[:, :, :-1]],
                              axis=2)          # s_{t-1}
    amat = jnp.einsum("zctn,zcjn->zctj",
                      rr * jnp.exp(s_shift), kk * jnp.exp(-s))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    amat = jnp.where(tri[None, None], amat, 0.0)
    y_intra = jnp.einsum("zctj,zcjp->zctp", amat, vv)
    # current-token bonus:  (Σ_n r_t·u·k_t) · v_t
    dot = jnp.sum(rr * uu[:, None, None, :] * kk, axis=-1, keepdims=True)
    y_bonus = dot * vv
    # chunk summaries: ΔS_c = Σ_j exp(s_Q − s_j) k_j v_jᵀ ; decay_c = exp(s_Q)
    total = s[:, :, -1:, :]                        # (BH,NC,1,hd)
    summ = jnp.einsum("zcjn,zcjp->zcnp", kk * jnp.exp(total - s), vv)
    decay_c = jnp.exp(total[:, :, 0, :])           # (BH,NC,hd)

    def op(a, bb):
        (da, ha) = a
        (db, hb) = bb
        return (da * db, db[..., :, None] * ha + hb)
    ds, hs = jax.lax.associative_scan(op, (decay_c, summ), axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(hs[:, :1]), hs[:, :-1]], axis=1)
    y_inter = jnp.einsum("zctn,zcnp->zctp", rr * jnp.exp(s_shift), h_prev)
    y = y_intra + y_bonus + y_inter
    y = (y.reshape(b, h, nc, chunk, head_dim).transpose(0, 2, 3, 1, 4)
          .reshape(b, lc, d))
    return y[:, :l] if pad else y


def rwkv6_time_mix(params, x, cfg, state=None):
    """x: (B,L,d).  state: dict(prev=(B,d), wkv=(B,H,N,P)) for decode."""
    b, l, d = x.shape
    hd = cfg.ssm_head_dim
    prev_tok = None if state is None else state["prev"]
    if prev_tok is None:
        xs = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        xs = prev_tok[:, None, :].astype(x.dtype)
    mix = lambda mu: x + (xs - x) * mu  # noqa: E731
    r = mix(params["mu_r"]) @ params["wr"]
    k = mix(params["mu_k"]) @ params["wk"]
    v = mix(params["mu_v"]) @ params["wv"]
    g = jax.nn.silu(mix(params["mu_g"]) @ params["wg"])
    xw = mix(params["mu_w"])
    logw = -jnp.exp(params["w0"]
                    + jnp.tanh(xw @ params["w1"]) @ params["w2"]
                    .astype(jnp.float32))            # (B,L,d), negative
    logw = jnp.clip(logw, LOGW_MIN, -1e-4)
    if state is None:
        y = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), logw,
                         params["u"], hd)
        new_state = None
    else:
        h = cfg.d_model // hd
        rr = r[:, 0].reshape(b, h, hd).astype(jnp.float32)
        kk = k[:, 0].reshape(b, h, hd).astype(jnp.float32)
        vv = v[:, 0].reshape(b, h, hd).astype(jnp.float32)
        ww = jnp.exp(logw[:, 0]).reshape(b, h, hd)
        uu = params["u"].reshape(h, hd)
        S = state["wkv"]                              # (B,H,N=hd,P=hd)
        kv = jnp.einsum("bhn,bhp->bhnp", kk, vv)
        out = jnp.einsum("bhn,bhnp->bhp", rr, S + uu[None, :, :, None] * kv)
        S = ww[..., None] * S + kv
        y = out.reshape(b, 1, d)
        new_state = {"prev": x[:, 0].astype(jnp.float32), "wkv": S}
    y = rmsnorm(y.astype(x.dtype), params["ln_gamma"], cfg.norm_eps) * g
    return y @ params["wo"], new_state


def init_rwkv6_channel_mix(key, cfg, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_r": 0.5 * jnp.ones((d,), dtype), "mu_k": 0.5 * jnp.ones((d,), dtype),
        "wr": normal(k1, (d, d), 0.02, dtype),
        "wk": normal(k2, (d, dff), 0.02, dtype),
        "wv": normal(k3, (dff, d), 0.02, dtype),
    }


def rwkv6_channel_mix(params, x, state=None):
    b, l, d = x.shape
    prev_tok = None if state is None else state
    if prev_tok is None:
        xs = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        xs = prev_tok[:, None, :].astype(x.dtype)
    xr = x + (xs - x) * params["mu_r"]
    xk = x + (xs - x) * params["mu_k"]
    r = jax.nn.sigmoid(xr @ params["wr"])
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return r * (h @ params["wv"]), \
        (None if state is None else x[:, 0].astype(jnp.float32))


def init_rwkv6_state(cfg, batch):
    h = cfg.d_model // cfg.ssm_head_dim
    return {
        "prev": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, h, cfg.ssm_head_dim, cfg.ssm_head_dim),
                         jnp.float32),
        "prev_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }
