"""Mamba2 mixer (zamba2's backbone): SSD state-space recurrence.

Three interchangeable scan engines (tests assert equivalence):
  * ``ssd_chunked``     — parallel chunked formulation in jnp: all intra-chunk
    terms as batched matmuls + one associative_scan over chunk summaries.
    This is what the train/dry-run graphs use (MXU-dense, FLOPs-faithful).
  * kernels.ops.ssd_scan — the Pallas TPU kernel (same chunk math, VMEM-tiled).
  * kernels.ref.ssd_scan_ref — exact sequential oracle.

Decode keeps an (nheads, N, P) state + a conv tail; one step is O(1) in
sequence length — this is what makes zamba2 eligible for long_500k.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import normal, rmsnorm


def ssd_chunked_grouped(x, logdecay, b, c, chunk: int = 128):
    """Parallel SSD with head-shared B/C (Mamba2's single group): avoids the
    (B,H,L,N) broadcast entirely (EXPERIMENTS.md §Perf, zamba2 iteration).

    x (B,H,L,P), logdecay (B,H,L), b/c (B,L,N) → (B,H,L,P).
    """
    bsz, h, l, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        logdecay = jnp.pad(logdecay, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lc = x.shape[2]
    nc = lc // chunk
    xr = x.reshape(bsz, h, nc, chunk, p)
    ldr = logdecay.reshape(bsz, h, nc, chunk)
    br = b.reshape(bsz, nc, chunk, n)
    cr = c.reshape(bsz, nc, chunk, n)
    s = jnp.cumsum(ldr, axis=-1)                       # (B,H,NC,Q)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(tri[None, None, None],
                     s[..., :, None] - s[..., None, :], -jnp.inf)
    lmat = jnp.exp(diff)                               # (B,H,NC,Q,Q)
    cb = jnp.einsum("zctn,zcun->zctu", cr, br)         # shared across heads
    y_intra = jnp.einsum("zctu,zhctu,zhcup->zhctp", cb, lmat, xr)
    total = s[..., -1:]
    wlast = jnp.exp(total - s)                         # (B,H,NC,Q)
    summ = jnp.einsum("zcun,zhcu,zhcup->zhcnp", br, wlast, xr)
    decay_c = jnp.exp(total[..., 0])                   # (B,H,NC)

    def op(a, bb):
        (da, ha) = a
        (db, hb) = bb
        return (da * db, db[..., None, None] * ha + hb)
    ds, hs = jax.lax.associative_scan(op, (decay_c, summ), axis=2)
    h_prev = jnp.concatenate([jnp.zeros_like(hs[:, :, :1]), hs[:, :, :-1]],
                             axis=2)
    y_inter = jnp.einsum("zctn,zhct,zhcnp->zhctp", cr, jnp.exp(s), h_prev)
    y = (y_intra + y_inter).reshape(bsz, h, lc, p)
    return y[:, :, :l] if pad else y


def ssd_chunked(x, logdecay, b, c, chunk: int = 128):
    """Parallel SSD: x (BH,L,P), logdecay (BH,L), b/c (BH,L,N) → (BH,L,P)."""
    bh, l, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        logdecay = jnp.pad(logdecay, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lc = x.shape[1]
    nc = lc // chunk
    xr = x.reshape(bh, nc, chunk, p)
    ldr = logdecay.reshape(bh, nc, chunk)
    br = b.reshape(bh, nc, chunk, n)
    cr = c.reshape(bh, nc, chunk, n)
    s = jnp.cumsum(ldr, axis=-1)                        # (BH,NC,Q)
    # intra-chunk: Y = ((C Bᵀ) ⊙ L) X with L[t,u] = exp(s_t - s_u)·[u ≤ t]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(tri[None, None], s[..., :, None] - s[..., None, :],
                     -jnp.inf)          # mask BEFORE exp: no inf/overflow
    lmat = jnp.exp(diff)
    cb = jnp.einsum("zctn,zcun->zctu", cr, br)
    y_intra = jnp.einsum("zctu,zcup->zctp", cb * lmat, xr)
    # chunk summaries: S_c = Bᵀ diag(exp(s_Q − s)) X   (BH,NC,N,P)
    total = s[..., -1:]                                 # (BH,NC,1)
    wlast = jnp.exp(total - s)                          # (BH,NC,Q)
    summ = jnp.einsum("zcun,zcu,zcup->zcnp", br, wlast, xr)
    decay_c = jnp.exp(total[..., 0])                    # (BH,NC)
    # inter-chunk prefix states via associative linear-recurrence scan
    def op(a, bb):
        (da, ha) = a
        (db, hb) = bb
        return (da * db, db[..., None, None] * ha + hb)
    ds, hs = jax.lax.associative_scan(op, (decay_c, summ), axis=1)
    # h_prev for chunk c = state after chunk c-1
    h_prev = jnp.concatenate([jnp.zeros_like(hs[:, :1]), hs[:, :-1]], axis=1)
    y_inter = jnp.einsum("zctn,zcnp->zctp", cr * jnp.exp(s)[..., None], h_prev)
    y = (y_intra + y_inter).reshape(bh, lc, p)
    return y[:, :l] if pad else y


def init_mamba2(key, cfg, dtype):
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal(ks[0], (d, 2 * di + 2 * n + nh), 0.02, dtype),
        "conv_w": normal(ks[1], (cfg.ssm_conv, conv_dim), 0.2, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_gamma": jnp.zeros((di,), dtype),
        "out_proj": normal(ks[2], (di, d), 0.02, dtype),
    }


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, width K.  xbc: (B,L,C), w: (K,C).

    state: (B, K-1, C) tail of previous tokens (decode); returns (y, tail).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : k - 1])
    else:
        pad = state
    full = jnp.concatenate([pad, xbc], axis=1)
    y = sum(full[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    tail = full[:, -(k - 1):]
    return jax.nn.silu(y + b), tail


def mamba2_mixer(params, x, cfg, state=None, engine: str = "chunked"):
    """x: (B,L,d) → (B,L,d).  state: dict(ssm=(B,nh,N,P), conv=(B,K-1,C))
    for decode (L == 1); returns (y, new_state)."""
    b_sz, l, d = x.shape
    di, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  conv_state)
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])            # (B,L,nh)
    a = -jnp.exp(params["a_log"])                        # (nh,)
    logdecay = (a * dt)                                  # (B,L,nh)
    xh = xs.reshape(b_sz, l, nh, hd)
    x_eff = (xh.astype(jnp.float32) * dt[..., None])
    if state is None:
        if engine == "chunked":
            # head-shared B/C: no (B,H,L,N) broadcast materialized.
            # (A head-parallel resharding of the SSD interior was tried and
            # REFUTED — GSPMD's transient reshard copies under remat cost
            # more than the sharded lmat saved; see EXPERIMENTS.md §Perf.)
            y = ssd_chunked_grouped(
                x_eff.transpose(0, 2, 1, 3),            # (B,H,L,P)
                logdecay.transpose(0, 2, 1),            # (B,H,L)
                bmat.astype(jnp.float32), cmat.astype(jnp.float32))
        else:
            # merge batch and heads (oracle / Pallas paths)
            xe = x_eff.transpose(0, 2, 1, 3).reshape(b_sz * nh, l, hd)
            ld = logdecay.transpose(0, 2, 1).reshape(b_sz * nh, l)
            bm = jnp.broadcast_to(bmat.astype(jnp.float32)[:, None],
                                  (b_sz, nh, l, n)).reshape(b_sz * nh, l, n)
            cm = jnp.broadcast_to(cmat.astype(jnp.float32)[:, None],
                                  (b_sz, nh, l, n)).reshape(b_sz * nh, l, n)
            if engine == "pallas":
                from repro.kernels import ops
                y = ops.ssd_scan(xe, ld, bm, cm)
            else:
                from repro.kernels import ref
                y = ref.ssd_scan_ref(xe, ld, bm, cm)
            y = y.reshape(b_sz, nh, l, hd)
        y = y.transpose(0, 2, 1, 3)
        new_state = None
    else:
        # single-step recurrence: h = e^{a·dt} h + dt·B xᵀ ; y = C h
        h = state["ssm"]                                 # (B,nh,N,P)
        dec = jnp.exp(logdecay[:, 0])                    # (B,nh)
        upd = jnp.einsum("bn,bhp->bhnp", bmat[:, 0].astype(jnp.float32),
                         x_eff[:, 0])
        h = dec[..., None, None] * h + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), h)
        y = y[:, None].transpose(0, 1, 2, 3).reshape(b_sz, 1, nh, hd)
        new_state = {"ssm": h, "conv": conv_tail}
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b_sz, l, di)
    y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), params["gate_gamma"],
                cfg.norm_eps)
    return y @ params["out_proj"], new_state


def init_mamba2_state(cfg, batch, dtype):
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }
