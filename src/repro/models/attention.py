"""GQA attention with every flavour the assigned archs need: RoPE, sliding
windows (gemma2 local layers), logit softcapping, cross-attention (whisper),
and a KV-cache decode path."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, causal_mask, normal, rope_freqs,
                                 softcap)


def init_attn(key, cfg, dtype, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 0.02
    return {
        "wq": normal(k1, (d, cfg.n_heads * hd), s, dtype),
        "wk": normal(k2, (d, cfg.n_kv_heads * hd), s, dtype),
        "wv": normal(k3, (d, cfg.n_kv_heads * hd), s, dtype),
        "wo": normal(k4, (cfg.n_heads * hd, d), s, dtype),
    }


def _sdpa(q, k, v, mask, cap, scale):
    """q: (B,Sq,H,hd) k/v: (B,Skv,KV,hd) with GQA broadcast."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v)
    return out.reshape(b, sq, h, hd)


ONLINE_THRESHOLD = 2048      # use online softmax when Sq·Skv exceeds this²
KV_BLOCK = 1024


def _sdpa_online(q, k, v, cap, scale, *, q_offset, window, is_causal):
    """Flash-style online-softmax attention: scan over KV blocks carrying
    (running max, normalizer, weighted accumulator).  Peak live buffer is
    O(Sq · KV_BLOCK) instead of O(Sq · Skv) — this is what keeps the 32k
    prefill and 500k-cache cells memory-sane (DESIGN.md §5)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, hd)
    # block size adapts so the scan fully unrolls at ≤ 8 steps: the compiled
    # HLO then carries every step (XLA cost_analysis counts loop bodies once)
    kv_block = max(KV_BLOCK, ((skv // 8) + 127) // 128 * 128)
    nb = -(-skv // kv_block)
    unroll = nb if nb <= 8 else 1
    pad = nb * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    qidx = q_offset + jnp.arange(sq)[:, None]

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, bi = blk
        kidx = bi * kv_block + jnp.arange(kv_block)[None, :]
        msk = kidx < skv
        if is_causal:
            msk = msk & (kidx <= qidx)
        if window is not None:
            msk = msk & (kidx > qidx - window)
        s_blk = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kblk)
        s_blk = s_blk.astype(jnp.float32) * scale
        s_blk = softcap(s_blk, cap)
        s_blk = jnp.where(msk[None, None, None], s_blk, -1e30)
        m_new = jnp.maximum(m, s_blk.max(-1))
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vblk.dtype), vblk)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)), unroll=unroll)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(v.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


def attention(params, x, cfg, positions, *, window=None, is_causal=True,
              cache=None, cache_pos=None, kv_override=None):
    """Returns (out, new_cache).

    cache: dict(k=(B,Smax,KV,hd), v=…) — decode writes at ``cache_pos``.
    kv_override: (k, v) precomputed (cross-attention).
    """
    b, s, d = x.shape
    hd = cfg.hd
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv_override is None:
        k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        k, v = kv_override
    new_cache = None
    q_offset = 0
    causal = is_causal and kv_override is None
    if cache is not None and kv_override is None:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        q_offset = cache_pos
    scale = 1.0 / jnp.sqrt(hd)
    if s * k.shape[1] > ONLINE_THRESHOLD ** 2:
        out = _sdpa_online(q, k, v, cfg.attn_logit_softcap, scale,
                           q_offset=q_offset, window=window,
                           is_causal=causal)
    else:
        kidx = jnp.arange(k.shape[1])[None, :]
        qidx = q_offset + jnp.arange(s)[:, None]
        mask = (kidx <= qidx) if causal else jnp.ones((s, k.shape[1]), bool)
        if window is not None and causal:
            mask = mask & (kidx > qidx - window)
        out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap, scale)
    return out.reshape(b, s, -1) @ params["wo"], new_cache


def init_cross_kv(params, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    b, se, d = enc_out.shape
    k = (enc_out @ params["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ params["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
    return k, v
