"""Sharding rules: map param/activation pytrees onto the production mesh.

Layout (MaxText-style 2-D/3-D):
  * ``model`` axis — tensor parallelism: attention heads, FFN hidden, the
    expert axis of MoE stacks, SSM inner channels.
  * ``data`` (+ ``pod``) axes — DP + FSDP: the contracting/d_model side of
    every projection and the vocab axis of the embedding are sharded here, so
    parameters and optimizer state are *fully* sharded (ZeRO-3); GSPMD then
    materializes per-layer all-gathers that overlap with the scan-over-layers
    compute (hillclimbed in EXPERIMENTS.md §Perf).
  * batch shards over (pod, data); for batch < data-axis (long-context
    decode) the KV-cache *sequence* axis shards over data instead (context
    parallelism) — see cache_specs.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# current-mesh registry: model code calls constrain_* which no-op outside a
# mesh context (CPU smoke tests) and emit with_sharding_constraint inside one
# ---------------------------------------------------------------------------

_CURRENT_MESH = None


@contextlib.contextmanager
def use_mesh(mesh):
    global _CURRENT_MESH
    prev = _CURRENT_MESH
    _CURRENT_MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _CURRENT_MESH = prev


def current_mesh():
    return _CURRENT_MESH


def _constrain(x, spec_dims):
    """spec_dims: tuple of (axis-name | tuple | None) per dim; any axis whose
    size doesn't divide the dim is dropped."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    out = []
    for dim, want in zip(x.shape, spec_dims):
        if want is None:
            out.append(None)
            continue
        axes = want if isinstance(want, tuple) else (want,)
        axes = tuple(a for a in axes if a in sizes)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        while axes and dim % prod != 0:
            prod //= sizes[axes[-1]]
            axes = axes[:-1]
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))


def constrain_residual(x):
    """Residual stream (B, S, d): batch over (pod,data); sequence over model
    (Megatron-style sequence parallelism) with d_model fallback."""
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    model = sizes.get("model", 1)
    b, s, d = x.shape
    if s > 1 and s % model == 0:
        return _constrain(x, (("pod", "data"), "model", None))
    return _constrain(x, (("pod", "data"), None, "model"))


def constrain_logits(x):
    """(B, S, V): vocab over model (weights already put it there)."""
    return _constrain(x, (("pod", "data"), None, "model"))


def constrain_moe_buffers(x):
    """(E, cap, d) / (E, cap, ff): experts over model, capacity over data."""
    return _constrain(x, ("model", ("pod", "data"), None))


def fsdp_axes(mesh_axes) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def batch_spec(mesh_axes) -> P:
    fs = fsdp_axes(mesh_axes)
    return P(fs if len(fs) > 1 else (fs[0] if fs else None))


def batch_axes_for(mesh, batch: int):
    """Largest prefix of (pod, data) whose product divides ``batch``
    (None when even 'data' alone doesn't divide — e.g. batch 1)."""
    sizes = dict(mesh.shape)
    fs = fsdp_axes(mesh.axis_names)
    full = 1
    for a in fs:
        full *= sizes[a]
    if batch % full == 0:
        return fs if len(fs) > 1 else fs[0]
    if "data" in fs and batch % sizes["data"] == 0:
        return "data"
    return None


def _rules(name: str, fs) -> Optional[tuple]:
    """Base (unstacked) partition for a leaf by param name."""
    table = {
        # embeddings / head
        "embed": ("model", fs),
        "lm_head": (fs, "model"),
        "pos_embed": (None, None),
        # attention
        "wq": (fs, "model"), "wk": (fs, "model"), "wv": (fs, "model"),
        "wo": ("model", fs),
        # mlp
        "w_gate": (fs, "model"), "w_up": (fs, "model"), "w_down": ("model", fs),
        # moe (leading expert axis → EP over model)
        "router": (fs, None),
        "moe_w_gate": ("model", fs, None), "moe_w_up": ("model", fs, None),
        "moe_w_down": ("model", None, fs),
        "ws_gate": (fs, "model"), "ws_up": (fs, "model"), "ws_down": ("model", fs),
        # mamba2
        "in_proj": (fs, "model"), "out_proj": ("model", fs),
        "conv_w": (None, "model"), "conv_b": ("model",),
        "a_log": ("model",), "dt_bias": ("model",), "d_skip": ("model",),
        "gate_gamma": ("model",),
        # rwkv6
        "wr": (fs, "model"), "wg": (fs, "model"),
        "w0": (None,), "w1": (fs, None), "w2": (None, None), "u": (None,),
        "mu_r": (None,), "mu_k": (None,), "mu_v": (None,), "mu_w": (None,),
        "mu_g": (None,),
        # mla
        "wdq": (fs, None), "wuq": (None, "model"),
        "wdkv": (fs, None), "wkr": (fs, None),
        "wuk": (None, "model"), "wuv": (None, "model"),
        "q_gamma": (None,), "kv_gamma": (None,),
    }
    if name in table:
        return table[name]
    if name.endswith("gamma") or name.startswith("ln") or name.startswith("mu_"):
        return (None,)
    return None


def param_specs(params, mesh_axes, moe_names=("w_gate", "w_up", "w_down")):
    """PartitionSpec pytree matching ``params``; stacked leading layer axes
    get None."""
    fs = fsdp_axes(mesh_axes)
    fs = fs if len(fs) > 1 else (fs[0] if fs else None)

    def spec_of(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        in_moe = any("moe" in k for k in keys)
        lookup = f"moe_{name}" if in_moe and name in moe_names else name
        base = _rules(lookup, fs)
        if base is None:
            base = (None,) * leaf.ndim
            return P(*base)
        extra = leaf.ndim - len(base)
        return P(*((None,) * extra + tuple(base)))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def cache_specs(caches, mesh, batch: int):
    """KV caches: batch over (pod,data) when divisible, else the *sequence*
    axis shards over data (context parallelism, long-context decode).

    Head axes that don't divide the model axis (GQA kv ∈ {4, 8}) fall back
    to sharding head_dim — the contraction then produces partial sums that
    GSPMD closes with an all-reduce."""
    sizes = dict(mesh.shape)
    model = sizes.get("model", 1)
    dsize = sizes.get("data", 1)
    bspec = batch_axes_for(mesh, batch)
    seq_par = bspec is None

    def hd_fallback(heads_dim, hd_dim):
        """Pick (heads_spec, hd_spec) respecting divisibility."""
        if heads_dim % model == 0:
            return "model", None
        if hd_dim % model == 0:
            return None, "model"
        return None, None

    def spec_of(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        nd = leaf.ndim
        shp = leaf.shape
        if name in ("k", "v", "xk", "xv"):   # (L?, B, S, KV, hd)
            h_sp, d_sp = hd_fallback(shp[-2], shp[-1])
            seq_sp = "data" if (seq_par and shp[-3] % dsize == 0) else None
            base = ((None, seq_sp, h_sp, d_sp) if seq_par
                    else (bspec, None, h_sp, d_sp))
        elif name in ("ckv",):          # (L?, B, S, kv_lora)
            l_sp = "model" if shp[-1] % model == 0 else None
            seq_sp = "data" if (seq_par and shp[-2] % dsize == 0) else None
            base = ((None, seq_sp, l_sp) if seq_par else (bspec, None, l_sp))
        elif name in ("kr",):           # (L?, B, S, rope_hd)
            seq_sp = "data" if (seq_par and shp[-2] % dsize == 0) else None
            base = ((None, seq_sp, None) if seq_par else (bspec, None, None))
        elif name == "ssm":             # (L?, B, nh, N, P)
            h_sp = "model" if shp[-3] % model == 0 else None
            base = (bspec, h_sp, None, None)
        elif name == "conv":            # (L?, B, K-1, C)
            c_sp = "model" if shp[-1] % model == 0 else None
            base = (bspec, None, c_sp)
        elif name == "wkv":             # (L?, B, H, N, P)
            h_sp = "model" if shp[-3] % model == 0 else None
            base = (bspec, h_sp, None, None)
        elif name in ("prev", "prev_cm"):   # (L?, B, d)
            d_sp = "model" if shp[-1] % model == 0 else None
            base = (bspec, d_sp)
        else:
            base = (None,) * nd
        extra = nd - len(base)
        return P(*((None,) * extra + tuple(base)))

    return jax.tree_util.tree_map_with_path(spec_of, caches)
