"""Mixture-of-Experts FFN (deepseek-v2: 2 shared + 160 routed top-6;
llama4-scout: shared + 16 routed top-1).

GShard-style capacity dispatch: einsum one-hot dispatch/combine tensors keep
the graph static-shaped and shardable; expert weight stacks shard over the
``model`` mesh axis (expert parallelism inside the TP plane).

KaHIP integration (DESIGN.md §3): ``expert_placement`` partitions the expert
co-activation graph (node weight = expert load, edge weight = co-routing
frequency) with the *node+edge balanced* objective, yielding a permutation
that places co-activated experts on the same shard — ``place_experts``
applies it to the weight stacks, minimizing cross-shard all-to-all traffic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.layers import normal


def _buffers(x):
    from repro.models import shardings as SH
    return SH.constrain_moe_buffers(x)


# ---------------------------------------------------------------------------
# gate observation (serve-path telemetry, DESIGN.md §13)
# ---------------------------------------------------------------------------

#: When set, every ``moe_ffn`` forward reports its routed expert indices
#: (host numpy, shape (..., k)) — the live signal `obs.live`'s
#: `TrafficAccumulator` folds into the traffic hypergraph.
_gate_observer = None


def _dispatch_gates(gate_idx) -> None:
    fn = _gate_observer
    if fn is not None:
        fn(np.asarray(gate_idx))


def _emit_gates(gate_idx) -> None:
    """Tap the routing decision.  With no observer installed at trace time
    this is a pure no-op (nothing is staged into the computation); with
    one installed, a `jax.debug.callback` ships the indices to the host.
    The runtime double-check in `_dispatch_gates` makes *clearing* the
    observer effective even for already-compiled programs; *installing*
    one only affects computations traced afterwards (e.g. a fresh
    `ContinuousBatcher`, whose jitted steps are per-instance)."""
    if _gate_observer is not None:
        jax.debug.callback(_dispatch_gates, gate_idx)


@contextlib.contextmanager
def observe_gates(sink):
    """Install a gate observer for the duration of the context.

    ``sink`` is either a callable taking an (..., k) int array or an
    object with an ``observe`` method (`obs.live.TrafficAccumulator`).
    """
    global _gate_observer
    fn = sink.observe if hasattr(sink, "observe") else sink
    prev = _gate_observer
    _gate_observer = fn
    try:
        yield sink
    finally:
        _gate_observer = prev


def init_moe(key, cfg, dtype):
    d = cfg.d_model
    dff = cfg.d_ff_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": normal(ks[0], (d, e), 0.02, jnp.float32),
        "w_gate": normal(ks[1], (e, d, dff), 0.02, dtype),
        "w_up": normal(ks[2], (e, d, dff), 0.02, dtype),
        "w_down": normal(ks[3], (e, dff, d), 0.02, dtype),
    }
    if cfg.n_shared_experts:
        sdff = cfg.n_shared_experts * dff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p.update({
            "ws_gate": normal(k1, (d, sdff), 0.02, dtype),
            "ws_up": normal(k2, (d, sdff), 0.02, dtype),
            "ws_down": normal(k3, (sdff, d), 0.02, dtype),
        })
    return p


def moe_ffn(params, x, cfg):
    """x: (B,S,d) → (B,S,d).

    Sort-based grouped dispatch (memory O(T·k·d), FLOPs ∝ active experts):
    (token, choice) pairs are sorted by expert id, scattered into per-expert
    capacity buffers (E, cap, d) — sharded over the ``model`` axis, so the
    scatter lowers to the expert-parallel all-to-all — then three batched
    expert matmuls, then a weighted gather back.  Tokens beyond an expert's
    capacity are dropped (capacity_factor headroom), as in GShard/Switch.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt @ params["router"]).astype(jnp.float32)        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                # (T,k)
    _emit_gates(gate_idx)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(np.ceil(t * k * cfg.capacity_factor / e)))
    if t >= 4096:       # shardability: capacity divisible by (pod,data)
        cap = int(np.ceil(cap / 512) * 512)
    # flatten (token, choice) pairs and sort by expert
    pair_e = gate_idx.reshape(-1)                                # (T*k,)
    pair_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pair_g = gate_vals.reshape(-1)
    order = jnp.argsort(pair_e)
    pe, pt, pg = pair_e[order], pair_t[order], pair_g[order]
    # position within expert group = index − first index of that expert
    first = jnp.searchsorted(pe, jnp.arange(e), side="left")     # (E,)
    pos = jnp.arange(t * k) - first[pe]
    keep = pos < cap
    slot = jnp.where(keep, pe * cap + pos, 0)                    # drop → w=0
    val = jnp.where(keep[:, None], xt[pt], 0.0)
    buf = jnp.zeros((e * cap, d), xt.dtype).at[slot].add(val)
    expert_in = _buffers(buf.reshape(e, cap, d))
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"]))
    expert_out = _buffers(jnp.einsum("ecf,efd->ecd", _buffers(h),
                                     params["w_down"]))
    flat_out = expert_out.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None],
                        flat_out[slot] * pg[:, None].astype(xt.dtype), 0.0)
    y = jnp.zeros((t, d), xt.dtype).at[pt].add(contrib)
    if cfg.n_shared_experts:
        y = y + (jax.nn.silu(xt @ params["ws_gate"])
                 * (xt @ params["ws_up"])) @ params["ws_down"]
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# expert-parallel dispatch via explicit all_to_all (hillclimb, EXPERIMENTS.md
# §Perf): the jnp scatter above lets GSPMD close the token→expert movement
# with full-buffer all-reduces over the model axis (O(E·cap·d) per layer!).
# The shard_map form moves exactly the routed tokens twice: send + return.
# ---------------------------------------------------------------------------

def moe_ffn_a2a(params, x, cfg):
    """Expert-parallel MoE with manual all_to_all over the ``model`` axis.

    Requires an active mesh with E % model == 0; falls back to moe_ffn
    otherwise (CPU tests).  Tokens stay sharded (pod, data)×batch and
    model×sequence exactly like the residual stream, so entering/leaving the
    shard_map needs no resharding.  Gate observation (`observe_gates`)
    covers only the fallback path — callbacks inside the shard_map body
    would serialise the all-to-all.
    """
    from repro.models import shardings as SH
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = SH.current_mesh()
    if mesh is None:
        return moe_ffn(params, x, cfg)
    sizes = dict(mesh.shape)
    m = sizes.get("model", 1)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    fs = SH.fsdp_axes(mesh.axis_names)
    dsz = 1
    for a in fs:
        dsz *= sizes[a]
    if m == 1 or e % m or s % m or b % dsz:
        return moe_ffn(params, x, cfg)
    e_loc = e // m
    t_loc = (b // dsz) * (s // m)
    # per-(source-shard → dest-shard) expert capacity
    cap = max(8, int(np.ceil(t_loc * k * cfg.capacity_factor / e)))

    def body(xb, router, w_gate, w_up, w_down):
        # xb: (b/dsz, s/m, d); expert stacks: (e_loc, d, f)
        bl, sl, _ = xb.shape
        xt = xb.reshape(bl * sl, d)
        logits = (xt @ router).astype(jnp.float32)            # (t_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True),
                                         1e-9)
        pair_e = gate_idx.reshape(-1)
        pair_t = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        pair_g = gate_vals.reshape(-1)
        order = jnp.argsort(pair_e)
        pe, pt, pg = pair_e[order], pair_t[order], pair_g[order]
        first = jnp.searchsorted(pe, jnp.arange(e), side="left")
        pos = jnp.arange(t_loc * k) - first[pe]
        keep = pos < cap
        slot = jnp.where(keep, pe * cap + pos, 0)
        val = jnp.where(keep[:, None], xt[pt], 0.0)
        send = jnp.zeros((e * cap, d), xt.dtype).at[slot].add(val)
        send = send.reshape(m, e_loc * cap, d)
        # exchange: dest shard j receives every source's block j
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (m_src, e_loc*cap, d) → experts see m_src×cap rows each
        expert_in = (recv.reshape(m, e_loc, cap, d)
                     .transpose(1, 0, 2, 3).reshape(e_loc, m * cap, d))
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, w_gate))
             * jnp.einsum("ecd,edf->ecf", expert_in, w_up))
        out = jnp.einsum("ecf,efd->ecd", h, w_down)
        back = (out.reshape(e_loc, m, cap, d)
                .transpose(1, 0, 2, 3).reshape(m, e_loc * cap, d))
        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        flat = ret.reshape(e * cap, d)
        contrib = jnp.where(keep[:, None],
                            flat[slot] * pg[:, None].astype(xt.dtype), 0.0)
        y = jnp.zeros((t_loc, d), xt.dtype).at[pt].add(contrib)
        return y.reshape(bl, sl, d)

    bspec = fs if len(fs) > 1 else fs[0]
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(bspec, "model", None), P(None, None),
                             P("model", None, None), P("model", None, None),
                             P("model", None, None)),
                   out_specs=P(bspec, "model", None),
                   check_vma=False)
    y = fn(x, params["router"].astype(x.dtype), params["w_gate"],
           params["w_up"], params["w_down"])
    if cfg.n_shared_experts:
        xt = x.reshape(b * s, d)
        y = y + ((jax.nn.silu(xt @ params["ws_gate"])
                  * (xt @ params["ws_up"])) @ params["ws_down"]) \
            .reshape(b, s, d)
    return y


# ---------------------------------------------------------------------------
# KaHIP-driven expert placement
# ---------------------------------------------------------------------------

def coactivation_graph(gate_idx: np.ndarray, n_experts: int,
                       load: Optional[np.ndarray] = None):
    """Build the expert co-activation graph from routing decisions.

    gate_idx: (T, k) int — per token, its routed experts.  Edge (a, b) weight
    = number of tokens routed to both a and b; node weight = expert load.
    """
    from repro.core.csr import Graph
    t, k = gate_idx.shape
    cnt = np.zeros((n_experts, n_experts), dtype=np.int64)
    for i in range(k):
        for j in range(i + 1, k):
            np.add.at(cnt, (gate_idx[:, i], gate_idx[:, j]), 1)
    cnt = cnt + cnt.T
    if load is None:
        load = np.bincount(gate_idx.reshape(-1), minlength=n_experts)
    u, v = np.triu_indices(n_experts, 1)
    w = cnt[u, v]
    keep = w > 0
    return Graph.from_edges(n_experts, u[keep], v[keep], w[keep],
                            vwgt=np.maximum(load, 1))


def expert_placement(gate_idx: np.ndarray, n_experts: int, n_shards: int,
                     seed: int = 0) -> np.ndarray:
    """Partition experts into shards (node+edge balanced KaFFPa, §1) and
    return a permutation: perm[new_slot] = old_expert_id, where slots are
    contiguous per shard."""
    from repro.core.kaffpa import kaffpa
    g = coactivation_graph(gate_idx, n_experts)
    part = kaffpa(g, n_shards, 0.03, "fast", seed=seed, balance_edges=True,
                  enforce_balance=False)
    per = n_experts // n_shards
    # exact-size packing: overflow experts spill to underfull shards
    order = []
    buckets = [list(np.flatnonzero(part == s)) for s in range(n_shards)]
    spill = []
    for s in range(n_shards):
        if len(buckets[s]) > per:
            spill.extend(buckets[s][per:])
            buckets[s] = buckets[s][:per]
    for s in range(n_shards):
        while len(buckets[s]) < per and spill:
            buckets[s].append(spill.pop())
        order.extend(buckets[s])
    return np.asarray(order, dtype=np.int64)


def place_experts(params: dict, perm: np.ndarray) -> dict:
    """Apply a placement permutation to the stacked expert weights + router."""
    out = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        out[k] = params[k][perm]
    out["router"] = params["router"][:, perm]
    return out
