"""Trace recorder: hierarchical spans, counters, quality trajectories.

A `Recorder` journals everything as flat event dicts (one JSON object per
line on export, Chrome-trace convertible via obs.trace):

  * ``ph: "B"/"E"`` — span begin/end.  Timestamps are wall-anchored
    microseconds (``time.time()`` anchor + ``perf_counter`` deltas), so
    events from several recorders merge into one consistent timeline.
    Nesting is tracked per thread; every event carries the thread id.
  * ``ph: "C"`` — a counter increment (also applied to the global
    ``registry.metrics``).
  * ``ph: "P"`` — a quality-trajectory point: objective / imbalance per
    level, V-cycle, generation or restart, also kept structured in
    ``Recorder.trajectories[series]`` so "never-worse" guarantees are
    inspectable curves.

The disabled path is `NULL` (a `NullRecorder` singleton): every method is
a no-op and ``span`` returns one shared reusable context manager, so hot
paths pay a function call, never an allocation, a trace or a device sync.
Engine code guards any extra objective evaluation behind
``recorder.enabled``.

``annotate_xprof=True`` additionally wraps every span in a
``jax.profiler.TraceAnnotation`` so engine spans line up with XLA traces
in a profiler session.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.registry import install_jax_compile_listener, metrics


class _NullSpan:
    """Reusable no-op context manager (one instance for the process)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The disabled recorder: every operation is a no-op."""

    __slots__ = ()
    enabled = False

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def count(self, name: str, value: float = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def point(self, series: str, **values) -> None:
        pass

    def begin(self, name: str, track=None, **attrs) -> None:
        pass

    def end(self, name: str, track=None) -> None:
        pass

    def instant(self, name: str, track=None, **attrs) -> None:
        pass


#: The shared disabled recorder (also the default ambient recorder).
NULL = NullRecorder()


class _Span:
    __slots__ = ("rec", "name", "attrs", "_ann")

    def __init__(self, rec: "Recorder", name: str, attrs: dict):
        self.rec = rec
        self.name = name
        self.attrs = attrs
        self._ann = None

    def __enter__(self):
        rec = self.rec
        depth = rec._push(self.name)
        ev = {"ph": "B", "name": self.name, "ts": rec._now_us(),
              "tid": threading.get_ident(), "depth": depth}
        if self.attrs:
            ev["args"] = self.attrs
        rec._emit(ev)
        if rec._xprof is not None:
            self._ann = rec._xprof(self.name)
            self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        rec = self.rec
        if self._ann is not None:
            self._ann.__exit__(*exc)
        depth = rec._pop()
        rec._emit({"ph": "E", "name": self.name, "ts": rec._now_us(),
                   "tid": threading.get_ident(), "depth": depth})
        return False


class Recorder:
    """An enabled observability context for one run (or one bench cell).

    Counters written through ``count`` land in the global registry too;
    ``counters()`` returns this run's deltas (including ``jax/compiles``
    from the process-wide compile listener), so ``compile_count`` is the
    number of XLA backend compiles attributable to this recorder's
    lifetime.
    """

    enabled = True

    def __init__(self, name: str = "run", compile_counters: bool = True,
                 annotate_xprof: bool = False):
        self.name = name
        self._lock = threading.RLock()
        self.events: List[Dict[str, Any]] = []
        self.trajectories: Dict[str, List[Dict[str, Any]]] = {}
        self._local = threading.local()
        self._t0 = time.perf_counter()
        self._ts0_us = time.time() * 1e6
        self._xprof = None
        if annotate_xprof:
            try:
                from jax.profiler import TraceAnnotation
                self._xprof = TraceAnnotation
            except ImportError:  # pragma: no cover - jax is a hard dep
                self._xprof = None
        if compile_counters:
            install_jax_compile_listener()
        self._snap0 = metrics.snapshot()

    # -- internals ----------------------------------------------------------
    def _now_us(self) -> float:
        return self._ts0_us + (time.perf_counter() - self._t0) * 1e6

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, name: str) -> int:
        st = self._stack()
        st.append(name)
        return len(st) - 1

    def _pop(self) -> int:
        st = self._stack()
        if st:
            st.pop()
        return len(st)

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(ev)

    # -- public API ---------------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        """Hierarchical trace span: ``with rec.span("coarsen", level=3):``"""
        return _Span(self, name, attrs)

    def span_path(self) -> str:
        """Slash-joined names of the open spans on this thread."""
        return "/".join(self._stack())

    def count(self, name: str, value: float = 1) -> None:
        metrics.inc(name, value)
        self._emit({"ph": "C", "name": name, "ts": self._now_us(),
                    "tid": threading.get_ident(), "value": value})

    def gauge(self, name: str, value: float) -> None:
        metrics.set_gauge(name, value)
        self._emit({"ph": "G", "name": name, "ts": self._now_us(),
                    "tid": threading.get_ident(), "value": value})

    def point(self, series: str, **values) -> None:
        """Append a quality-trajectory point (objective, imbalance, …)."""
        row = dict(values)
        with self._lock:
            self.trajectories.setdefault(series, []).append(row)
        self._emit({"ph": "P", "name": series, "ts": self._now_us(),
                    "tid": threading.get_ident(), "values": row})

    # -- explicit-track events (serve tracing, DESIGN.md §13) ---------------
    # Unlike ``span``, these do not ride the per-thread nesting stack: the
    # caller owns the track (a named Chrome/Perfetto row, e.g. one per serve
    # slot) and guarantees B/E matching.  A request's lifetime can then span
    # many host calls (enqueue → slot-assign → decode ticks → finish)
    # without ever holding a Python context manager open.

    def begin(self, name: str, track=None, **attrs) -> None:
        """Open an event on an explicitly named track."""
        ev = {"ph": "B", "name": name, "ts": self._now_us(),
              "tid": threading.get_ident()}
        if track is not None:
            ev["track"] = str(track)
        if attrs:
            ev["args"] = attrs
        self._emit(ev)

    def end(self, name: str, track=None) -> None:
        """Close the matching ``begin`` on the same track."""
        ev = {"ph": "E", "name": name, "ts": self._now_us(),
              "tid": threading.get_ident()}
        if track is not None:
            ev["track"] = str(track)
        self._emit(ev)

    def instant(self, name: str, track=None, **attrs) -> None:
        """A zero-duration marker (Chrome "i" instant event)."""
        ev = {"ph": "I", "name": name, "ts": self._now_us(),
              "tid": threading.get_ident()}
        if track is not None:
            ev["track"] = str(track)
        if attrs:
            ev["args"] = attrs
        self._emit(ev)

    def counters(self) -> Dict[str, float]:
        """Counter deltas since this recorder was created."""
        base = self._snap0
        return {k: v - base.get(k, 0) for k, v in metrics.snapshot().items()
                if v != base.get(k, 0)}

    @property
    def compile_count(self) -> int:
        """XLA backend compiles observed during this recorder's lifetime."""
        return int(self.counters().get("jax/compiles", 0))

    def trajectory(self, series: str, key: str = "objective") -> List[float]:
        """One trajectory series flattened to a list of ``key`` values."""
        return [p[key] for p in self.trajectories.get(series, ())
                if key in p]

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            n_spans = sum(1 for e in self.events if e["ph"] == "B")
        return {"name": self.name, "spans": n_spans,
                "compile_count": self.compile_count,
                "counters": self.counters(),
                "trajectories": {k: len(v)
                                 for k, v in self.trajectories.items()}}
