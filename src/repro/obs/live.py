"""repro.obs.live — streaming serve-path telemetry (DESIGN.md §13).

Three layers on top of the PR-6 recorder:

  * **Streaming metric primitives** — `WindowedCounter` (time-bucketed
    totals with exact rollover), `EwmaRate` (exponentially-decayed rate
    gauge) and `QuantileSketch` (Greenwald–Khanna ε-approximate quantiles,
    deterministic worst-case rank error ≤ εn).  All host-only, no jax.
  * **`ServeTelemetry`** — the per-run aggregation object the serving
    stack threads through: per-request queue/prefill/decode/end-to-end
    latency sketches, tokens-per-second throughput, queue-depth /
    slot-occupancy gauges, and per-slot request span emission onto the
    recorder's named tracks (Chrome-trace export → a Perfetto timeline
    with one row per slot).
  * **`TrafficAccumulator`** — the live traffic hypergraph: observed MoE
    gate indices (and KV co-access sets) fold incrementally into decayed
    co-activation pin weights; `snapshot()` materialises the window as a
    `Hypergraph` ((λ−1) == replication / all-to-all traffic) ready for
    ``kahypar``, and `drift()` scores the live window against the
    partition-time baseline so a serving loop knows when the incumbent
    partition has gone stale (`advise()` flips the
    ``serve/repartition_advised`` gauge).

Disabled path: `NULL_TELEMETRY` follows the NULL-recorder contract — every
method is a no-op, so an uninstrumented serve run never takes a clock
reading, allocates an event, or syncs the device.
"""
from __future__ import annotations

import bisect
import math
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.recorder import NULL, Recorder


# ---------------------------------------------------------------------------
# streaming metric primitives
# ---------------------------------------------------------------------------

class WindowedCounter:
    """A sliding-window counter over fixed time buckets.

    The window is bucket-aligned: ``total(now)`` is the exact sum of every
    ``add(value, t)`` whose bucket index lies in the last ``buckets``
    bucket epochs ending at ``now``'s bucket (inclusive).  Rollover is
    exact — a bucket is zeroed the moment it is reused for a new epoch, so
    stale values can never leak back into the window.
    """

    def __init__(self, window_s: float = 10.0, buckets: int = 20,
                 clock=time.monotonic):
        if window_s <= 0 or buckets <= 0:
            raise ValueError("window_s and buckets must be positive")
        self.window_s = float(window_s)
        self.buckets = int(buckets)
        self.bucket_w = self.window_s / self.buckets
        self._clock = clock
        self._vals = [0.0] * self.buckets
        self._epoch = [-1] * self.buckets        # bucket index each slot holds

    def _idx(self, now: float) -> int:
        return int(math.floor(now / self.bucket_w))

    def add(self, value: float = 1.0, now: Optional[float] = None) -> None:
        now = self._clock() if now is None else now
        idx = self._idx(now)
        slot = idx % self.buckets
        if self._epoch[slot] != idx:
            self._vals[slot] = 0.0
            self._epoch[slot] = idx
        self._vals[slot] += value

    def total(self, now: Optional[float] = None) -> float:
        now = self._clock() if now is None else now
        idx = self._idx(now)
        lo = idx - self.buckets
        return sum(v for v, e in zip(self._vals, self._epoch)
                   if lo < e <= idx)

    def rate(self, now: Optional[float] = None) -> float:
        """Events per second over the window."""
        return self.total(now) / self.window_s


class EwmaRate:
    """Exponentially-weighted rate gauge (events/sec, halflife-decayed).

    Each ``update(value, now)`` folds the instantaneous rate
    ``value / dt`` in with weight ``1 − exp(−dt/τ)``; from a cold start
    under a constant event rate the estimate converges monotonically to
    the true rate.  ``value(now)`` additionally decays toward zero while
    no events arrive, so it is safe to export as a live gauge.
    """

    def __init__(self, halflife_s: float = 5.0, clock=time.monotonic):
        if halflife_s <= 0:
            raise ValueError("halflife_s must be positive")
        self.tau = halflife_s / math.log(2.0)
        self._clock = clock
        self._rate = 0.0
        self._last: Optional[float] = None

    def update(self, value: float = 1.0,
               now: Optional[float] = None) -> float:
        now = self._clock() if now is None else now
        if self._last is None:
            self._last = now
            return self._rate
        dt = now - self._last
        self._last = now
        if dt <= 0:
            # coincident events: fold into the current estimate as a burst
            self._rate += value / self.tau
            return self._rate
        alpha = math.exp(-dt / self.tau)
        self._rate = self._rate * alpha + (value / dt) * (1.0 - alpha)
        return self._rate

    def value(self, now: Optional[float] = None) -> float:
        if self._last is None:
            return 0.0
        now = self._clock() if now is None else now
        dt = max(now - self._last, 0.0)
        return self._rate * math.exp(-dt / self.tau)


class QuantileSketch:
    """Greenwald–Khanna ε-approximate streaming quantiles.

    Deterministic worst-case guarantee: ``query(q)`` returns a value whose
    rank in the observed stream is within ``eps * n + 1`` of ``q * n``,
    using O((1/ε)·log(εn)) space.  This is the bounded-error sketch behind the
    serve path's p50/p95/p99 latency gauges.
    """

    def __init__(self, eps: float = 0.01):
        if not (0 < eps < 0.5):
            raise ValueError("eps must be in (0, 0.5)")
        self.eps = eps
        self.n = 0
        # parallel arrays: values (sorted), g (rank gap), delta (uncertainty)
        self._v: List[float] = []
        self._g: List[int] = []
        self._d: List[int] = []
        self._since_compress = 0
        self._compress_every = max(1, int(1.0 / (2.0 * eps)))
        self._min = math.inf
        self._max = -math.inf

    def add(self, x: float) -> None:
        x = float(x)
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        i = bisect.bisect_left(self._v, x)
        if i == 0 or i == len(self._v):
            delta = 0
        else:
            delta = int(math.floor(2.0 * self.eps * self.n))
        self._v.insert(i, x)
        self._g.insert(i, 1)
        self._d.insert(i, delta)
        self.n += 1
        self._since_compress += 1
        if self._since_compress >= self._compress_every:
            self._compress()
            self._since_compress = 0

    def _compress(self) -> None:
        thresh = int(math.floor(2.0 * self.eps * self.n))
        v, g, d = self._v, self._g, self._d
        i = len(v) - 2
        while i >= 1:
            if g[i] + g[i + 1] + d[i + 1] <= thresh:
                g[i + 1] += g[i]
                del v[i], g[i], d[i]
            i -= 1

    def query(self, q: float) -> float:
        """The ε-approximate q-quantile of everything added so far."""
        if self.n == 0:
            return math.nan
        if q <= 0:
            return self._min
        if q >= 1:
            return self._max
        r = max(1, int(math.ceil(q * self.n)))
        bound = r + self.eps * self.n
        rmin = 0
        prev = self._v[0]
        for v, g, d in zip(self._v, self._g, self._d):
            rmin += g
            if rmin + d > bound:
                return prev
            prev = v
        return self._v[-1]

    def quantiles(self, qs: Sequence[float] = (0.5, 0.95, 0.99)
                  ) -> Dict[str, float]:
        return {f"p{int(round(q * 100))}": self.query(q) for q in qs}


# ---------------------------------------------------------------------------
# the live traffic hypergraph
# ---------------------------------------------------------------------------

class TrafficAccumulator:
    """Decayed co-activation pin weights from observed routing traffic.

    `observe(gate_idx)` folds one batch of MoE routing decisions
    ``(..., k)`` into a pairwise co-activation matrix and a per-expert
    load vector; `observe_sets(sets)` folds KV co-access sets (any pin
    cardinality ≥ 2) into a bounded decayed net dictionary.  Every fold
    first multiplies the standing weights by ``decay`` — at ``decay=1``
    the accumulator is exactly the batch-mode
    ``moe.coactivation_graph`` over the concatenated stream (`to_graph`
    is constructed identically); at ``decay<1`` it is the exponentially
    weighted live window.

    `snapshot()` materialises the window as a `Hypergraph`
    (``Hypergraph.from_coactivation``), `set_baseline()` freezes the
    partition-time traffic histogram, and `drift()` is the total-variation
    distance between the baseline and the live window (max over the load
    and co-activation distributions), in [0, 1].
    """

    def __init__(self, n_items: int, decay: float = 0.95,
                 max_sets: int = 4096):
        if not (0 < decay <= 1):
            raise ValueError("decay must be in (0, 1]")
        self.n_items = int(n_items)
        self.decay = float(decay)
        self.max_sets = int(max_sets)
        self.pair = np.zeros((n_items, n_items), dtype=np.float64)
        self.load = np.zeros(n_items, dtype=np.float64)
        self.sets: Dict[Tuple[int, ...], float] = {}
        self.updates = 0
        self.events = 0
        self._base_load: Optional[np.ndarray] = None
        self._base_pair: Optional[np.ndarray] = None

    # -- folding ------------------------------------------------------------
    def _decay_all(self) -> None:
        if self.decay < 1.0:
            self.pair *= self.decay
            self.load *= self.decay
            if self.sets:
                dead = []
                for key in self.sets:
                    w = self.sets[key] * self.decay
                    if w < 1e-6:
                        dead.append(key)
                    else:
                        self.sets[key] = w
                for key in dead:
                    del self.sets[key]
        self.updates += 1

    def observe(self, gate_idx) -> None:
        """Fold one batch of routing decisions, shape (..., k) int."""
        idx = np.asarray(gate_idx)
        if idx.size == 0:
            return
        idx = idx.reshape(-1, idx.shape[-1]).astype(np.int64)
        self._decay_all()
        t, k = idx.shape
        self.events += t
        for i in range(k):
            for j in range(i + 1, k):
                np.add.at(self.pair, (idx[:, i], idx[:, j]), 1.0)
        self.load += np.bincount(idx.reshape(-1),
                                 minlength=self.n_items).astype(np.float64)
        if self.decay == 1.0 and self._base_load is None:
            pass    # cheap path: baselines are snapshots, nothing to do

    def observe_sets(self, sets: Iterable[Sequence[int]]) -> None:
        """Fold co-access sets (e.g. KV pages touched by one request)."""
        self._decay_all()
        for s in sets:
            key = tuple(sorted(set(int(x) for x in s)))
            if len(key) < 2:
                continue
            self.events += 1
            self.sets[key] = self.sets.get(key, 0.0) + 1.0
            for v in key:
                self.load[v] += 1.0
        if len(self.sets) > self.max_sets:
            keep = sorted(self.sets.items(), key=lambda kv: -kv[1])
            self.sets = dict(keep[:self.max_sets])

    # -- materialisation ----------------------------------------------------
    def to_graph(self):
        """The co-activation `Graph` (identical construction to the batch
        ``moe.coactivation_graph`` when ``decay=1``)."""
        from repro.core.csr import Graph
        n = self.n_items
        cnt = self.pair + self.pair.T
        u, v = np.triu_indices(n, 1)
        w = np.rint(cnt[u, v]).astype(np.int64)
        keep = w > 0
        load = np.rint(self.load).astype(np.int64)
        return Graph.from_edges(n, u[keep], v[keep], w[keep],
                                vwgt=np.maximum(load, 1))

    def snapshot(self, min_weight: float = 0.5):
        """The live traffic window as a `Hypergraph` (pins = items)."""
        from repro.core.hypergraph.container import Hypergraph
        return Hypergraph.from_coactivation(
            self.pair + self.pair.T, load=self.load, sets=self.sets,
            min_weight=min_weight)

    # -- drift --------------------------------------------------------------
    @staticmethod
    def _normalize(x: np.ndarray) -> Optional[np.ndarray]:
        s = x.sum()
        return None if s <= 0 else x / s

    def _histograms(self):
        pair = self.pair + self.pair.T
        tri = pair[np.triu_indices(self.n_items, 1)]
        return self._normalize(self.load.copy()), self._normalize(tri)

    def set_baseline(self) -> None:
        """Freeze the current window as the partition-time histogram."""
        self._base_load, self._base_pair = self._histograms()

    def drift(self) -> float:
        """Total-variation distance live vs. baseline, in [0, 1]."""
        load, pair = self._histograms()
        d = 0.0
        for base, cur in ((self._base_load, load), (self._base_pair, pair)):
            if base is not None and cur is not None:
                d = max(d, 0.5 * float(np.abs(base - cur).sum()))
        return d

    def advise(self, recorder: Recorder = NULL,
               threshold: float = 0.3) -> bool:
        """Export drift gauges; True when repartitioning looks worthwhile."""
        d = self.drift()
        advised = d > threshold
        recorder.gauge("serve/traffic_drift", d)
        recorder.gauge("serve/repartition_advised", float(advised))
        return advised


# ---------------------------------------------------------------------------
# serve-path telemetry
# ---------------------------------------------------------------------------

class _NullTelemetry:
    """No-op telemetry (the default): the serve path pays one attribute
    access per hook, never a clock read or an allocation."""

    __slots__ = ()
    enabled = False
    traffic = None

    def enqueued(self, rid, queue_depth=0):
        pass

    def started(self, rid, slot, prompt_len, active=0):
        pass

    def prefilled(self, rid, slot, prompt_len=0):
        pass

    def step(self, new_tokens, active, queue_depth=0, step_s=None):
        pass

    def tick(self, rid, slot, token):
        pass

    def finished(self, rid, slot, n_out=0):
        pass

    def snapshot(self):
        return {}


NULL_TELEMETRY = _NullTelemetry()


class ServeTelemetry:
    """Streaming serve metrics + per-slot request tracing.

    Hooks (called by `ContinuousBatcher` / `serve_stream`):
      ``enqueued → started → prefilled → step* → finished``.

    Each request becomes a span on the named track ``slot <s>`` (visible
    as one Perfetto row per slot), with nested prefill/decode phases and
    per-tick token instants; queue depth, active slots and throughput are
    exported as counter tracks.  Latency distributions ride
    `QuantileSketch` (bounded rank error), throughput rides
    `WindowedCounter` + `EwmaRate`.

    ``traffic`` optionally carries a `TrafficAccumulator`; the serve loop
    calls ``advise()`` on it periodically via ``maybe_advise``.
    """

    enabled = True

    def __init__(self, recorder: Recorder = NULL,
                 traffic: Optional[TrafficAccumulator] = None,
                 window_s: float = 10.0, sketch_eps: float = 0.01,
                 ewma_halflife_s: float = 2.0, clock=time.perf_counter,
                 advise_every: int = 16, drift_threshold: float = 0.3):
        self.rec = recorder
        self.traffic = traffic
        self._clock = clock
        self.sketches: Dict[str, QuantileSketch] = {
            "queue_us": QuantileSketch(sketch_eps),
            "prefill_us": QuantileSketch(sketch_eps),
            "decode_us": QuantileSketch(sketch_eps),
            "e2e_us": QuantileSketch(sketch_eps),
        }
        self.tokens = WindowedCounter(window_s, clock=clock)
        self.requests = WindowedCounter(window_s, clock=clock)
        self.tok_rate = EwmaRate(ewma_halflife_s, clock=clock)
        self.advise_every = advise_every
        self.drift_threshold = drift_threshold
        self._t_enq: Dict[Any, float] = {}
        self._t_start: Dict[Any, float] = {}
        self._t_prefilled: Dict[Any, float] = {}
        self._steps = 0
        self.total_tokens = 0
        self.total_requests = 0

    # -- request lifecycle ---------------------------------------------------
    def enqueued(self, rid, queue_depth: int = 0) -> None:
        now = self._clock()
        self._t_enq[rid] = now
        self.rec.instant("enqueue", track="queue", rid=rid)
        self.rec.gauge("serve/queue_depth", queue_depth)

    def started(self, rid, slot, prompt_len: int, active: int = 0) -> None:
        now = self._clock()
        t_enq = self._t_enq.pop(rid, now)
        wait_us = (now - t_enq) * 1e6
        self.sketches["queue_us"].add(wait_us)
        self._t_start[rid] = t_enq          # e2e is enqueue → finish
        self.rec.begin(f"req {rid}", track=f"slot {slot}", rid=rid,
                       prompt_len=prompt_len, queue_us=round(wait_us, 1))
        self.rec.begin("prefill", track=f"slot {slot}", rid=rid)
        self.rec.gauge("serve/slots_active", active)
        self._t_prefilled[rid] = now

    def prefilled(self, rid, slot, prompt_len: int = 0) -> None:
        now = self._clock()
        t0 = self._t_prefilled.pop(rid, now)
        self.sketches["prefill_us"].add((now - t0) * 1e6)
        self.rec.end("prefill", track=f"slot {slot}")
        self.rec.begin("decode", track=f"slot {slot}", rid=rid)
        if prompt_len:
            self.rec.count("serve/prefill_tokens", prompt_len)
        # prefill yields the request's first generated token (the argmax
        # over the last prompt position) — count it with the output stream
        self.total_tokens += 1
        self.tokens.add(1.0, now=now)
        self.rec.count("serve/tokens", 1)

    def step(self, new_tokens: int, active: int, queue_depth: int = 0,
             step_s: Optional[float] = None) -> None:
        """One batched decode tick: ``new_tokens`` over ``active`` slots."""
        now = self._clock()
        self._steps += 1
        self.total_tokens += new_tokens
        if step_s is not None and new_tokens:
            per_tok_us = step_s * 1e6 / max(new_tokens, 1)
            self.sketches["decode_us"].add(per_tok_us)
        self.tokens.add(new_tokens, now=now)
        rate = self.tok_rate.update(new_tokens, now=now)
        self.rec.count("serve/tokens", new_tokens)
        self.rec.gauge("serve/slots_active", active)
        self.rec.gauge("serve/queue_depth", queue_depth)
        self.rec.gauge("serve/tok_per_s", rate)
        if self.traffic is not None and self.advise_every and \
                self._steps % self.advise_every == 0:
            self.traffic.advise(self.rec, self.drift_threshold)

    def tick(self, rid, slot, token: int) -> None:
        """Per-slot token instant (one marker per decode tick per slot)."""
        self.rec.instant("tok", track=f"slot {slot}", rid=rid, token=token)

    def finished(self, rid, slot, n_out: int = 0) -> None:
        now = self._clock()
        t0 = self._t_start.pop(rid, now)
        self.sketches["e2e_us"].add((now - t0) * 1e6)
        self.total_requests += 1
        self.requests.add(1.0, now=now)
        self.rec.end("decode", track=f"slot {slot}")
        self.rec.end(f"req {rid}", track=f"slot {slot}")
        self.rec.count("serve/requests_finished")
        if n_out:
            self.rec.count("serve/tokens_out", n_out)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Everything the bench / dashboard wants, as plain floats."""
        now = self._clock()
        return {
            "latency_us": {name: sk.quantiles()
                           for name, sk in self.sketches.items()
                           if sk.n},
            "tok_per_s_window": self.tokens.rate(now),
            "tok_per_s_ewma": self.tok_rate.value(now),
            "req_per_s_window": self.requests.rate(now),
            "total_tokens": self.total_tokens,
            "total_requests": self.total_requests,
            "steps": self._steps,
            "drift": (self.traffic.drift()
                      if self.traffic is not None else None),
            "traffic_events": (self.traffic.events
                               if self.traffic is not None else 0),
        }
