"""repro.obs — zero-dependency observability for the partitioning engine
(DESIGN.md §11): hierarchical trace spans with a JSONL journal and Chrome
trace export, a thread-safe counter/gauge registry (including XLA compile
counts via ``jax.monitoring``), and per-level / per-cycle / per-generation
quality trajectories.

Everything is opt-in behind a recorder object:

    from repro import obs

    rec = obs.Recorder("kaffpa")
    with obs.use(rec):
        part = kaffpa(g, 4, 0.03, "eco", seed=1)
    print(rec.compile_count, rec.trajectory("cycles"))
    obs.write_chrome_trace(rec, "trace.json")   # open in ui.perfetto.dev

or through the library interface's ``report=`` kwarg
(``interface.kaffpa(..., report=rec)``).  With no recorder installed the
ambient recorder is `NULL`: every hook is a no-op that never allocates,
traces or syncs the device.
"""
from __future__ import annotations

import contextlib

from repro.obs.live import (NULL_TELEMETRY, EwmaRate, QuantileSketch,
                            ServeTelemetry, TrafficAccumulator,
                            WindowedCounter)
from repro.obs.recorder import NULL, NullRecorder, Recorder
from repro.obs.registry import (CounterRegistry, install_jax_compile_listener,
                                metrics)
from repro.obs.trace import (chrome_trace, read_jsonl, write_chrome_trace,
                             write_jsonl)

__all__ = [
    "NULL", "NullRecorder", "Recorder", "CounterRegistry", "metrics",
    "install_jax_compile_listener", "chrome_trace", "read_jsonl",
    "write_chrome_trace", "write_jsonl", "current", "use",
    "NULL_TELEMETRY", "EwmaRate", "QuantileSketch", "ServeTelemetry",
    "TrafficAccumulator", "WindowedCounter",
]

_current = NULL


def current():
    """The ambient recorder (`NULL` unless a ``use`` context is active)."""
    return _current


@contextlib.contextmanager
def use(recorder):
    """Install ``recorder`` as the ambient recorder for the duration.

    ``use(None)`` is a passthrough (the current ambient recorder stays
    active) so entry points can thread an optional ``report=`` kwarg
    without clobbering an enclosing context.
    """
    global _current
    if recorder is None:
        yield _current
        return
    prev = _current
    _current = recorder
    try:
        yield recorder
    finally:
        _current = prev
