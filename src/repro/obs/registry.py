"""The engine-wide counter/gauge registry (DESIGN.md §11).

One thread-safe registry is the single source of truth for every counter
the engine emits (view builds, LP/FM rounds, moves applied, feasibility
repairs, psum rounds, jax compiles).  It replaces the thread-unsafe
module global that ``multilevel.view_build_count()`` used to read: the
old functions are now thin aliases over ``metrics``.

Compile counting rides ``jax.monitoring``: `install_jax_compile_listener`
registers one process-wide duration listener that increments
``jax/compiles`` (and accumulates ``jax/compile_secs``) on every XLA
backend compile, plus an event listener for compilation-cache hits.  A
`Recorder` snapshots the registry at construction, so per-run deltas
(``Recorder.counters()``) give per-cell compile counts without ever
unregistering the listener (jax offers no per-listener removal).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional


class CounterRegistry:
    """Thread-safe monotonically increasing counters plus last-value
    gauges, keyed by slash-separated names (``"engine/view_builds"``)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> float:
        with self._lock:
            new = self._counters.get(name, 0) + value
            self._counters[name] = new
            return new

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def gauge(self, name: str, default: Optional[float] = None):
        with self._lock:
            return self._gauges.get(name, default)

    def reset(self, name: Optional[str] = None) -> None:
        """Reset one counter/gauge, or the whole registry with ``None``."""
        with self._lock:
            if name is None:
                self._counters.clear()
                self._gauges.clear()
            else:
                self._counters.pop(name, None)
                self._gauges.pop(name, None)

    def snapshot(self) -> Dict[str, float]:
        """Copy of the counter map (the per-run delta anchor)."""
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._gauges)


#: The process-wide registry every engine counter lands in.
metrics = CounterRegistry()


# ---------------------------------------------------------------------------
# jax.monitoring integration: compile counts
# ---------------------------------------------------------------------------

#: The duration event jax records around every XLA backend compile
#: (jax._src.dispatch.BACKEND_COMPILE_EVENT).
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"

_install_lock = threading.Lock()
_installed = False


def install_jax_compile_listener() -> bool:
    """Idempotently register the process-wide compile listeners.

    Returns True when the listeners are active (already or newly
    installed), False when jax is unavailable.  Listener cost off the
    compile path is zero — jax only invokes it while compiling.
    """
    global _installed
    with _install_lock:
        if _installed:
            return True
        try:
            from jax import monitoring
        except ImportError:  # pragma: no cover - jax is a hard dep here
            return False

        def _on_duration(event: str, duration: float, **kw) -> None:
            if event == COMPILE_EVENT:
                metrics.inc("jax/compiles")
                metrics.inc("jax/compile_secs", duration)

        def _on_event(event: str, **kw) -> None:
            if event == CACHE_HIT_EVENT:
                metrics.inc("jax/compile_cache_hits")

        monitoring.register_event_duration_secs_listener(_on_duration)
        monitoring.register_event_listener(_on_event)
        _installed = True
        return True
