"""Journal / trace export: JSONL round-trip and Chrome trace events.

The JSONL journal is the durable form: one ``{"kind": "recorder", ...}``
header line per recorder followed by its events (each stamped with the
recorder name), append-merged across recorders.  ``chrome_trace`` turns
the same events into the Chrome trace-event JSON that Perfetto
(https://ui.perfetto.dev) opens directly: spans as matched B/E duration
events, counters / gauges / trajectory points as "C" counter tracks,
explicit-track events (serve slots, the request queue) as named threads
via "M" thread_name metadata, and instants as "i" events.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Tuple, Union

from repro.obs.recorder import Recorder

Recorders = Union[Recorder, Iterable[Recorder]]

#: tid block where named tracks live — far above any real thread index the
#: remapper below assigns, so the two can never collide.
_TRACK_TID0 = 1_000_000


def _as_list(recs: Recorders) -> List[Recorder]:
    return [recs] if isinstance(recs, Recorder) else list(recs)


def write_jsonl(recs: Recorders, path: str) -> int:
    """Write the event journal(s) as JSON lines; returns lines written."""
    lines = 0
    with open(path, "w") as f:
        for rec in _as_list(recs):
            hdr = {"kind": "recorder", "name": rec.name,
                   "counters": rec.counters(),
                   "trajectories": rec.trajectories}
            f.write(json.dumps(hdr) + "\n")
            lines += 1
            with rec._lock:
                events = list(rec.events)
            for ev in events:
                f.write(json.dumps({"rec": rec.name, **ev}) + "\n")
                lines += 1
    return lines


def read_jsonl(path: str) -> Tuple[List[Dict[str, Any]],
                                   List[Dict[str, Any]]]:
    """Read a journal back → (recorder header dicts, event dicts).

    Crash-safe: a truncated trailing line (the partial write an interrupted
    run leaves behind) is dropped and the valid prefix returned.  Corrupt
    lines *before* the end of the file still raise — that is data loss, not
    an interrupted append.
    """
    headers, events = [], []
    with open(path) as f:
        lines = f.readlines()
    last = len(lines) - 1
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            if i == last:
                break               # interrupted final append: keep prefix
            raise
        (headers if obj.get("kind") == "recorder" else events).append(obj)
    return headers, events


def chrome_trace(recs: Recorders,
                 registry_gauges: bool = False) -> Dict[str, Any]:
    """Chrome trace-event JSON (the ``traceEvents`` array format).

    Spans become matched B/E duration events on (pid, tid) tracks; counter
    increments, gauges and trajectory points become "C" counter events;
    instants become "i" events.  Events carrying a ``track`` name (the
    serve path's per-slot request timelines) are mapped onto dedicated
    tids with "M" thread_name metadata, so Perfetto shows them as named
    rows ("slot 0", "queue", …).  ``registry_gauges=True`` additionally
    snapshots the process-wide ``obs.metrics`` gauges as one final counter
    sample per gauge — quality/queue-depth curves land next to the spans.
    """
    pid = os.getpid()
    tes: List[Dict[str, Any]] = []
    tracks: Dict[str, int] = {}
    last_ts = 0.0

    def _tid(ev) -> int:
        track = ev.get("track")
        if track is None:
            return ev.get("tid", 0)
        if track not in tracks:
            tid = _TRACK_TID0 + len(tracks)
            tracks[track] = tid
            tes.append({"name": "thread_name", "ph": "M", "pid": pid,
                        "tid": tid, "args": {"name": track}})
        return tracks[track]

    for rec in _as_list(recs):
        with rec._lock:
            events = list(rec.events)
        totals: Dict[str, float] = {}
        for ev in events:
            last_ts = max(last_ts, ev.get("ts", 0.0))
            if ev["ph"] in ("B", "E"):
                out = {"name": ev["name"], "ph": ev["ph"], "ts": ev["ts"],
                       "pid": pid, "tid": _tid(ev), "cat": rec.name}
                if "args" in ev:
                    out["args"] = ev["args"]
                tes.append(out)
            elif ev["ph"] == "I":
                out = {"name": ev["name"], "ph": "i", "ts": ev["ts"],
                       "pid": pid, "tid": _tid(ev), "cat": rec.name,
                       "s": "t"}
                if "args" in ev:
                    out["args"] = ev["args"]
                tes.append(out)
            elif ev["ph"] == "C":
                totals[ev["name"]] = totals.get(ev["name"], 0) + ev["value"]
                tes.append({"name": ev["name"], "ph": "C", "ts": ev["ts"],
                            "pid": pid, "tid": 0, "cat": rec.name,
                            "args": {"value": totals[ev["name"]]}})
            elif ev["ph"] == "G":
                tes.append({"name": ev["name"], "ph": "C", "ts": ev["ts"],
                            "pid": pid, "tid": 0, "cat": rec.name,
                            "args": {"value": ev["value"]}})
            elif ev["ph"] == "P":
                vals = {k: v for k, v in ev["values"].items()
                        if isinstance(v, (int, float))}
                if vals:
                    tes.append({"name": ev["name"], "ph": "C",
                                "ts": ev["ts"], "pid": pid, "tid": 0,
                                "cat": rec.name, "args": vals})
    if registry_gauges:
        from repro.obs.registry import metrics
        for name, value in sorted(metrics.gauges().items()):
            tes.append({"name": name, "ph": "C", "ts": last_ts,
                        "pid": pid, "tid": 0, "cat": "registry",
                        "args": {"value": value}})
    return {"traceEvents": tes, "displayTimeUnit": "ms"}


def write_chrome_trace(recs: Recorders, path: str,
                       registry_gauges: bool = False) -> int:
    """Write the Chrome trace JSON; returns the number of trace events."""
    trace = chrome_trace(recs, registry_gauges=registry_gauges)
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
