"""Journal / trace export: JSONL round-trip and Chrome trace events.

The JSONL journal is the durable form: one ``{"kind": "recorder", ...}``
header line per recorder followed by its events (each stamped with the
recorder name), append-merged across recorders.  ``chrome_trace`` turns
the same events into the Chrome trace-event JSON that Perfetto
(https://ui.perfetto.dev) opens directly: spans as matched B/E duration
events, counters and trajectory values as "C" counter tracks.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Tuple, Union

from repro.obs.recorder import Recorder

Recorders = Union[Recorder, Iterable[Recorder]]


def _as_list(recs: Recorders) -> List[Recorder]:
    return [recs] if isinstance(recs, Recorder) else list(recs)


def write_jsonl(recs: Recorders, path: str) -> int:
    """Write the event journal(s) as JSON lines; returns lines written."""
    lines = 0
    with open(path, "w") as f:
        for rec in _as_list(recs):
            hdr = {"kind": "recorder", "name": rec.name,
                   "counters": rec.counters(),
                   "trajectories": rec.trajectories}
            f.write(json.dumps(hdr) + "\n")
            lines += 1
            with rec._lock:
                events = list(rec.events)
            for ev in events:
                f.write(json.dumps({"rec": rec.name, **ev}) + "\n")
                lines += 1
    return lines


def read_jsonl(path: str) -> Tuple[List[Dict[str, Any]],
                                   List[Dict[str, Any]]]:
    """Read a journal back → (recorder header dicts, event dicts)."""
    headers, events = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            (headers if obj.get("kind") == "recorder" else events).append(obj)
    return headers, events


def chrome_trace(recs: Recorders) -> Dict[str, Any]:
    """Chrome trace-event JSON (the ``traceEvents`` array format).

    Spans become matched B/E duration events on (pid, tid) tracks,
    counter increments and trajectory points become "C" counter events —
    all directly viewable in Perfetto or chrome://tracing.
    """
    pid = os.getpid()
    tes: List[Dict[str, Any]] = []
    for rec in _as_list(recs):
        with rec._lock:
            events = list(rec.events)
        totals: Dict[str, float] = {}
        for ev in events:
            tid = ev.get("tid", 0)
            if ev["ph"] in ("B", "E"):
                out = {"name": ev["name"], "ph": ev["ph"], "ts": ev["ts"],
                       "pid": pid, "tid": tid, "cat": rec.name}
                if "args" in ev:
                    out["args"] = ev["args"]
                tes.append(out)
            elif ev["ph"] == "C":
                totals[ev["name"]] = totals.get(ev["name"], 0) + ev["value"]
                tes.append({"name": ev["name"], "ph": "C", "ts": ev["ts"],
                            "pid": pid, "tid": 0, "cat": rec.name,
                            "args": {"value": totals[ev["name"]]}})
            elif ev["ph"] == "G":
                tes.append({"name": ev["name"], "ph": "C", "ts": ev["ts"],
                            "pid": pid, "tid": 0, "cat": rec.name,
                            "args": {"value": ev["value"]}})
            elif ev["ph"] == "P":
                vals = {k: v for k, v in ev["values"].items()
                        if isinstance(v, (int, float))}
                if vals:
                    tes.append({"name": ev["name"], "ph": "C",
                                "ts": ev["ts"], "pid": pid, "tid": 0,
                                "cat": rec.name, "args": vals})
    return {"traceEvents": tes, "displayTimeUnit": "ms"}


def write_chrome_trace(recs: Recorders, path: str) -> int:
    """Write the Chrome trace JSON; returns the number of trace events."""
    trace = chrome_trace(recs)
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
