"""Node ordering for fill-in minimization (paper §2.9, §4.7).

``reduced_nd``: apply data-reduction rules exhaustively, then nested
dissection on the kernel; ``fast_reduced_nd`` uses the fast preset and fewer
ND levels.  Dissection separators come from the multilevel node-separator
engine (core/nodesep, DESIGN.md §8), which optimizes separator weight
directly at every hierarchy level; the post-hoc two-step construction
(core/separator.py) remains available as the seed-parity baseline.
Reduction numbers follow §4.7:

  0 simplicial node reduction (neighbourhood is a clique → eliminate first)
  1 indistinguishable nodes   (same closed neighbourhood → merge)
  2 twins                     (same open neighbourhood → merge)
  3 path compression          (chains of degree-2 nodes)
  4 degree-2 elimination
  5 triangle contraction

Simplicial detection is exact for degree ≤ 2 and clique-sampled above (the
full check is quadratic in degree); merged/eliminated nodes are re-inserted
into the ordering in reverse reduction order, which preserves fill quality.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.csr import Graph
from repro.core.nodesep import multilevel_node_separator


def _neighbor_sets(g: Graph):
    return [frozenset(g.neighbors(v).tolist()) for v in range(g.n)]


def _is_clique(g: Graph, nodes: np.ndarray, nbr_sets) -> bool:
    nodes = list(nodes)
    for i, u in enumerate(nodes):
        s = nbr_sets[u]
        for v in nodes[i + 1:]:
            if v not in s:
                return False
    return True


def apply_reductions(g: Graph, order_spec=(0, 1, 2, 3, 4),
                     max_clique_check: int = 8, max_passes: int = 30):
    """Exhaustive reduction on a dynamic elimination graph.

    Every elimination updates the quotient graph the way symbolic Cholesky
    would (degree-2 elimination adds the implied neighbour edge; simplicial
    elimination adds none), so the kernel is the true reduced instance.

    Returns (kernel graph, kernel_old_ids, prefix, follow):
      prefix — nodes safely eliminated *before* the kernel ordering;
      follow — representative → merged twins, re-inserted right after their
               representative (zero extra fill beyond the rep's clique).
    """
    n = g.n
    adj = [set(g.neighbors(v).tolist()) for v in range(n)]
    alive = np.ones(n, dtype=bool)
    prefix: list = []
    follow: dict = {}

    def eliminate(v, add_clique: bool):
        alive[v] = False
        nbrs = [u for u in adj[v] if alive[u]]
        for u in nbrs:
            adj[u].discard(v)
        if add_clique:
            for i, a in enumerate(nbrs):
                for b in nbrs[i + 1:]:
                    adj[a].add(b)
                    adj[b].add(a)

    for _ in range(max_passes):
        changed = False
        for rule in order_spec:
            if rule == 0:       # simplicial (exact up to max_clique_check)
                for v in range(n):
                    if not alive[v] or len(adj[v]) > max_clique_check:
                        continue
                    nbrs = list(adj[v])
                    if len(nbrs) <= 1 or all(
                            b in adj[a] for i, a in enumerate(nbrs)
                            for b in nbrs[i + 1:]):
                        prefix.append(v)
                        eliminate(v, add_clique=False)
                        changed = True
            elif rule in (1, 2):    # indistinguishable / twins
                buckets: dict = {}
                for v in range(n):
                    if not alive[v]:
                        continue
                    key = frozenset(adj[v] | {v}) if rule == 1 \
                        else frozenset(adj[v])
                    buckets.setdefault(key, []).append(v)
                for vs in buckets.values():
                    if len(vs) > 1:
                        rep = vs[0]
                        for v in vs[1:]:
                            follow.setdefault(rep, []).append(v)
                            eliminate(v, add_clique=False)
                            changed = True
            elif rule in (3, 4):    # degree-2 / path compression
                for v in range(n):
                    if not alive[v] or len(adj[v]) != 2:
                        continue
                    prefix.append(v)
                    eliminate(v, add_clique=True)   # connect the two nbrs
                    changed = True
            elif rule == 5:     # triangle tip (simplicial deg-2) contraction
                for v in range(n):
                    if not alive[v] or len(adj[v]) != 2:
                        continue
                    a, b = sorted(adj[v])
                    if b in adj[a]:
                        follow.setdefault(a, []).append(v)
                        eliminate(v, add_clique=False)
                        changed = True
        if not changed:
            break
    ids = np.flatnonzero(alive)
    remap = -np.ones(n, dtype=np.int64)
    remap[ids] = np.arange(len(ids))
    us, vs = [], []
    for v in ids:
        for u in adj[v]:
            if alive[u] and u > v:
                us.append(remap[v]); vs.append(remap[u])
    kernel = Graph.from_edges(len(ids), np.asarray(us, dtype=np.int64),
                              np.asarray(vs, dtype=np.int64),
                              vwgt=g.vwgt[ids])
    return kernel, ids, prefix, follow


def _min_degree_order(g: Graph) -> np.ndarray:
    """Dynamic minimum-degree (with elimination-graph updates) — base case."""
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    alive = np.ones(g.n, dtype=bool)
    order = []
    for _ in range(g.n):
        live = np.flatnonzero(alive)
        v = int(live[np.argmin([len(adj[u]) for u in live])])
        order.append(v)
        alive[v] = False
        nbrs = [u for u in adj[v] if alive[u]]
        for i, a in enumerate(nbrs):        # clique the neighbourhood
            adj[a].discard(v)
            for b in nbrs[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    return np.asarray(order, dtype=np.int64)


def _nested_dissection(g: Graph, ids: np.ndarray, out: list, seed: int,
                       preset: str, min_size: int = 64,
                       depth: int = 0, eps: float = 0.2) -> None:
    if g.n <= min_size or depth > 24:
        out.extend(ids[_min_degree_order(g)].tolist())
        return
    # each subproblem owns a distinct seed (2s+1 / 2s+2 recursion below), so
    # siblings never share a separator RNG stream
    sep, part = multilevel_node_separator(g, eps=eps, preset=preset,
                                          seed=seed)
    in_sep = np.zeros(g.n, dtype=bool)
    in_sep[sep] = True
    a_mask = (part == 0) & ~in_sep
    b_mask = (part == 1) & ~in_sep
    if not a_mask.any() or not b_mask.any():
        out.extend(ids[_min_degree_order(g)].tolist())
        return
    ga, ia = g.subgraph(a_mask)
    gb, ib = g.subgraph(b_mask)
    _nested_dissection(ga, ids[ia], out, seed * 2 + 1, preset, min_size,
                       depth + 1, eps)
    _nested_dissection(gb, ids[ib], out, seed * 2 + 2, preset, min_size,
                       depth + 1, eps)
    out.extend(ids[np.flatnonzero(in_sep)].tolist())


class _NDNode:
    """One nested-dissection subproblem in the wave tree."""
    __slots__ = ("g", "ids", "seed", "depth", "leaf", "a", "b", "sep_ids")

    def __init__(self, g: Graph, ids: np.ndarray, seed: int, depth: int):
        self.g, self.ids, self.seed, self.depth = g, ids, seed, depth
        self.leaf = None
        self.a = self.b = self.sep_ids = None


def _nested_dissection_wave(g: Graph, ids: np.ndarray, out: list, seed: int,
                            preset: str, min_size: int = 64,
                            eps: float = 0.2) -> None:
    """Wave-order nested dissection (DESIGN.md §12): all subproblems at one
    recursion depth solve their separators in a single batched call
    (`nodesep_labels_wave`), so same-shape-bucket siblings share one
    compiled tournament program.  Seeds (2s+1 / 2s+2) and the post-order
    emit are exactly those of `_nested_dissection`, so the resulting
    ordering is bit-identical to the sequential recursion."""
    from repro.core.nodesep.driver import nodesep_labels_wave, split_labels
    root = _NDNode(g, ids, seed, 0)
    wave = [root]
    while wave:
        solve = []
        for nd in wave:
            if nd.g.n <= min_size or nd.depth > 24:
                nd.leaf = nd.ids[_min_degree_order(nd.g)]
            else:
                solve.append(nd)
        labs = (nodesep_labels_wave([nd.g for nd in solve], eps=eps,
                                    preset=preset,
                                    seeds=[nd.seed for nd in solve])
                if solve else [])
        wave = []
        for nd, lab in zip(solve, labs):
            sep, part = split_labels(lab)
            in_sep = np.zeros(nd.g.n, dtype=bool)
            in_sep[sep] = True
            a_mask = (part == 0) & ~in_sep
            b_mask = (part == 1) & ~in_sep
            if not a_mask.any() or not b_mask.any():
                nd.leaf = nd.ids[_min_degree_order(nd.g)]
                continue
            ga, ia = nd.g.subgraph(a_mask)
            gb, ib = nd.g.subgraph(b_mask)
            nd.a = _NDNode(ga, nd.ids[ia], nd.seed * 2 + 1, nd.depth + 1)
            nd.b = _NDNode(gb, nd.ids[ib], nd.seed * 2 + 2, nd.depth + 1)
            nd.sep_ids = nd.ids[np.flatnonzero(in_sep)]
            wave.extend((nd.a, nd.b))

    def emit(nd: _NDNode) -> None:          # depth ≤ 25 → recursion is fine
        if nd.leaf is not None:
            out.extend(nd.leaf.tolist())
            return
        emit(nd.a)
        emit(nd.b)
        out.extend(nd.sep_ids.tolist())

    emit(root)


def reduced_nd(g: Graph, preset: str = "eco", seed: int = 0,
               reduction_order=(0, 1, 2, 3, 4),
               eps: float = 0.2, batch_siblings: bool = True) -> np.ndarray:
    """Returns permutation ``order`` with order[i] = i-th eliminated vertex.

    ``eps`` is the separator imbalance threaded through the whole nested
    dissection recursion.  ``batch_siblings`` (default) runs the recursion
    in wave order so same-bucket sibling subproblems share batched device
    calls; the ordering is identical either way.  (The library's
    `ordering` output array is the inverse permutation — see
    interface.reduced_nd.)
    """
    kernel, old_ids, prefix, follow = apply_reductions(g, reduction_order)
    out: list = []
    if kernel.n:
        if batch_siblings:
            _nested_dissection_wave(kernel, old_ids, out, seed, preset,
                                    eps=eps)
        else:
            _nested_dissection(kernel, old_ids, out, seed, preset, eps=eps)
    order = list(prefix)
    seen = set(prefix)
    for v in out:
        order.append(v)
        seen.add(v)
        for f in follow.get(v, []):
            if f not in seen:
                order.append(f)
                seen.add(f)
    # merged members whose representative was itself reduced
    for rep, vs in follow.items():
        for f in vs:
            if f not in seen:
                order.append(f)
                seen.add(f)
    for v in range(g.n):
        if v not in seen:
            order.append(v)
            seen.add(v)
    return np.asarray(order, dtype=np.int64)


def fast_reduced_nd(g: Graph, seed: int = 0, eps: float = 0.2) -> np.ndarray:
    return reduced_nd(g, preset="fast", seed=seed,
                      reduction_order=(0, 3, 4), eps=eps)


def fill_in(g: Graph, order: np.ndarray) -> int:
    """Symbolic Cholesky fill count under elimination ``order`` (benchmark
    metric; quadratic worst case — use on small graphs)."""
    pos = np.empty(g.n, dtype=np.int64)
    pos[order] = np.arange(g.n)
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    fill = 0
    for v in order:
        later = [u for u in adj[v] if pos[u] > pos[v]]
        for i, a in enumerate(later):
            for b in later[i + 1:]:
                if b not in adj[a]:
                    adj[a].add(b)
                    adj[b].add(a)
                    fill += 1
    return fill
