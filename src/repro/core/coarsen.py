"""Coarsening: graph contraction + the two cluster sources KaFFPa uses
(heavy-edge matching for mesh-like graphs, size-constrained LP clustering for
social graphs — paper §2.1/§2.4).

The level loop / contraction bookkeeping is host-side numpy (irregular), the
LP inner loop runs jitted on device (core/lp.py).  ``forbidden`` edge masks
implement the KaFFPaE combine operator's invariant: cut edges of the parent
partitions are never contracted (§2.2).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.csr import Graph, to_coo
from repro.core import lp as lp_mod


def contract(g: Graph, clusters: np.ndarray):
    """Contract clusters; returns (coarse graph, cluster->coarse-id map).

    Coarse node weight = sum of member weights; coarse edge weight = sum of
    inter-cluster edge weights; intra-cluster edges vanish.
    """
    clusters = np.asarray(clusters, dtype=np.int64)
    uniq, cl = np.unique(clusters, return_inverse=True)
    nc = len(uniq)
    cvw = np.zeros(nc, dtype=np.int64)
    np.add.at(cvw, cl, g.vwgt)
    src = g.edge_sources()
    cu, cv = cl[src], cl[g.adjncy]
    keep = cu < cv                       # each undirected inter-cluster edge once
    coarse = Graph.from_edges(nc, cu[keep], cv[keep], g.adjwgt[keep],
                              vwgt=cvw, dedup=True)
    return coarse, cl


def project(labels_coarse: np.ndarray, cl: np.ndarray) -> np.ndarray:
    """Lift a coarse partition back to the finer level."""
    return np.asarray(labels_coarse)[cl]


def heavy_edge_matching(g: Graph, seed: int = 0, rounds: int = 3,
                        max_cluster_weight: Optional[float] = None,
                        forbidden: Optional[np.ndarray] = None) -> np.ndarray:
    """Randomized parallel HEM: mutual heaviest-neighbour proposals match.

    Returns cluster ids (matched pairs share an id).  ``forbidden`` is a
    boolean mask over directed edges (aligned with adjncy) that must not be
    contracted.
    """
    rng = np.random.default_rng(seed)
    n = g.n
    match = -np.ones(n, dtype=np.int64)
    src = g.edge_sources()
    w = g.adjwgt.astype(np.float64)
    if forbidden is not None:
        w = np.where(forbidden, -np.inf, w)
    for _ in range(rounds):
        free = match < 0
        # candidate edges: both endpoints free, weight-eligible
        ok = free[src] & free[g.adjncy]
        if max_cluster_weight is not None:
            ok &= (g.vwgt[src] + g.vwgt[g.adjncy]) <= max_cluster_weight
        wr = np.where(ok, w + rng.random(len(w)), -np.inf)
        if not np.any(np.isfinite(wr)):
            break
        # per-node best proposal (segment argmax over CSR rows)
        prop = -np.ones(n, dtype=np.int64)
        best = np.full(n, -np.inf)
        np.maximum.at(best, src, wr)
        is_best = wr >= best[src] - 1e-12
        cand = np.where(is_best & np.isfinite(wr), g.adjncy, -1)
        np.maximum.at(prop, src, cand)
        # mutual?
        has = prop >= 0
        mutual = has & (prop[np.clip(prop, 0, n - 1)] == np.arange(n))
        a = np.flatnonzero(mutual)
        b = prop[a]
        lo = np.minimum(a, b)
        match[a] = lo
    clusters = np.where(match >= 0, match, np.arange(n))
    return clusters


def lp_clustering(g: Graph, max_cluster_weight: float, iters: int = 8,
                  seed: int = 0,
                  forbidden: Optional[np.ndarray] = None) -> np.ndarray:
    """Size-constrained LP clustering (social coarsening, §2.4).

    ``forbidden`` directed-edge mask: those edges' weights are zeroed for the
    clustering and any residual violation is split apart afterwards, so no
    forbidden edge is ever contracted.
    """
    if forbidden is None:
        clusters = lp_mod.size_constrained_lp(g, max_cluster_weight,
                                              iters=iters, seed=seed)
    else:
        g2 = Graph(g.xadj, g.adjncy, g.vwgt,
                   np.where(forbidden, 0, g.adjwgt).astype(np.int64))
        # w=0 edges contribute nothing; the LP may still merge endpoints via
        # other paths — split violators below.
        clusters = lp_mod.size_constrained_lp(g2, max_cluster_weight,
                                              iters=iters, seed=seed)
        src = g.edge_sources()
        bad = forbidden & (clusters[src] == clusters[g.adjncy])
        viol = np.unique(src[bad])
        # detach violating endpoints into singletons (stable: pick src side)
        clusters = clusters.copy()
        clusters[viol] = g.n + np.arange(len(viol))
    return clusters


def coarsen_level(g: Graph, mode: str, max_cluster_weight: float,
                  seed: int, forbidden: Optional[np.ndarray] = None):
    """One coarsening step; returns (coarse, cl) or None if it stalls."""
    if mode == "matching":
        clusters = heavy_edge_matching(g, seed=seed,
                                       max_cluster_weight=max_cluster_weight,
                                       forbidden=forbidden)
    elif mode == "lp":
        clusters = lp_clustering(g, max_cluster_weight, seed=seed,
                                 forbidden=forbidden)
    else:
        raise ValueError(f"unknown coarsening mode {mode!r}")
    coarse, cl = contract(g, clusters)
    if coarse.n >= g.n * 0.95:          # stalled — not shrinking
        return None
    return coarse, cl
