"""The medium-generic memetic island driver (DESIGN.md §10).

One island loop serves every incidence medium on the shared multilevel
engine: kaffpaE / KaBaPE on `GraphMedium`, kahyparE on `HypergraphMedium`
(both objectives) and the memetic separator mode on `SeparatorMedium`.
Per generation each island runs tournament selection, produces a child
with the engine's protected-coarsening ``combine`` (or a fresh-seed
V-cycle mutation), optionally applies a variant-specific polish (KaBaPE
negative cycles, the distributed parhyp round), and replaces its worst
member under the variant's replacement rule.  Migration is the seeded
ring exchange of `migrate.ring_roll` — collective_permute on a device
mesh, host roll otherwise, bit-identical either way.

Determinism contract: every stochastic choice island i makes is drawn
from its own RNG stream seeded by ``island_seed(seed, i)``, and every
engine call it issues is seeded from the same stream of stamps — so with
migration disabled the islands evolve *independently* and island i's
trajectory equals a solo run at ``seed + 1009·i`` (pinned by a test).
The driver-level RNG is used only for cross-island draws (quickstart
sharing, migration shifts).
"""
from __future__ import annotations

import dataclasses
import numbers
import time
from typing import Callable, List, Optional

import numpy as np

from repro.core import multilevel as ML
from repro.core.memetic.migrate import ring_roll
from repro.core.memetic.state import Individual, IslandState

STRIDE_ISLAND = 1009
STRIDE_MEMBER = 31
STRIDE_COMBINE = 7919
STRIDE_MUTATE = 104729
STRIDE_SWEEP = 2377


def island_seed(seed: int, isl: int) -> int:
    return seed + STRIDE_ISLAND * isl


def validate_memetic_params(n_islands, population, time_limit,
                            generations=None) -> None:
    """Shared entry-point validation: every memetic driver rejects
    zero/negative island counts and populations (which used to hang or
    index-error deep in the loop) and negative/non-finite time budgets.
    ``time_limit == 0`` stays valid — paper semantics: initial population
    only."""
    if not isinstance(n_islands, numbers.Integral) or n_islands < 1:
        raise ValueError(f"n_islands must be a positive int, got {n_islands!r}")
    if not isinstance(population, numbers.Integral) or population < 1:
        raise ValueError(
            f"population must be a positive int, got {population!r}")
    if (not isinstance(time_limit, numbers.Real)
            or not np.isfinite(float(time_limit)) or float(time_limit) < 0):
        raise ValueError(
            f"time_limit must be a finite number >= 0, got {time_limit!r}")
    if generations is not None and (
            not isinstance(generations, numbers.Integral) or generations < 0):
        raise ValueError(
            f"generations must be None or an int >= 0, got {generations!r}")


@dataclasses.dataclass
class MemeticConfig:
    """Medium-independent knobs of the island loop."""

    n_islands: int = 4
    population: int = 4
    time_limit: float = 10.0
    generations: Optional[int] = None   # deterministic alternative to time
    combine_prob: float = 0.9
    migrate: bool = True
    migration_interval: int = 1
    replacement: str = "worst"          # worst | balanced
    quickstart: bool = False
    batched_generations: bool = True    # one vmapped sweep for all islands


def _replace_key(cfg: MemeticConfig) -> Callable:
    """Replacement ranks feasibility first under every rule: an infeasible
    child never evicts a feasible incumbent.  Under the default "worst"
    rule the best feasible fitness per island is additionally monotone
    non-increasing — the structural never-worse-than-a-single-run
    guarantee the kaffpaE/kahyparE fronts advertise.  The "balanced" rule
    deliberately trades fitness for balance, so it carries no such
    fitness guarantee."""
    if cfg.replacement == "balanced":
        # KaBaPE rule: within a feasibility class the better-balanced
        # member survives regardless of fitness, so the population
        # converges to strictly balanced partitions
        return lambda ind: (not ind.feasible, ind.balance, ind.fitness,
                            ind.stamp)
    if cfg.replacement != "worst":
        raise ValueError(f"unknown replacement rule {cfg.replacement!r}")
    return lambda ind: (not ind.feasible, ind.fitness, ind.balance,
                        ind.stamp)


def _island_child(medium: ML.Medium, k: int, eps: float, cfg: MemeticConfig,
                  pop: List[Individual], rng: np.random.Generator,
                  iseed: int, gen: int, rec) -> tuple:
    """Produce one island's child for this generation: select then
    combine/mutate.  All randomness comes from the island's own stream.
    Returns (child, stamp)."""
    if rng.random() < cfg.combine_prob and len(pop) >= 2:
        ia, ib = (int(x) for x in rng.choice(len(pop), size=2, replace=False))
        pa = pop[ia] if pop[ia].key() <= pop[ib].key() else pop[ib]
        others = [p for j, p in enumerate(pop) if j not in (ia, ib)]
        pb = min(others, key=Individual.key) if others else pa
        stamp = iseed + STRIDE_COMBINE * gen
        child = ML.combine(medium, pa.part, pb.part, k, eps, stamp)
        rec.count("memetic/combines")
    else:
        src = pop[int(rng.integers(len(pop)))]
        stamp = iseed + STRIDE_MUTATE * gen
        child = ML.vcycle(medium, src.part, k, eps, stamp)
        rec.count("memetic/mutations")
    return child, stamp


def _island_accept(pop: List[Individual], ind: Individual, rkey: Callable,
                   rec) -> None:
    """Replace the island's worst member (under the variant rule) if the
    child is no worse."""
    w = max(range(len(pop)), key=lambda j: rkey(pop[j]))
    if rkey(ind) <= rkey(pop[w]):
        pop[w] = ind
        rec.count("memetic/replacements")


def _sweep_keys(seed: int, islands: List[int], gen: int) -> np.ndarray:
    """Per-island sweep keys (B, 2): island i's key depends only on
    (seed, i, gen), so the batched sweep row equals a solo island's —
    vmap row independence keeps the independence contract intact."""
    import jax
    return np.stack([np.asarray(jax.random.PRNGKey(
        island_seed(seed, isl) + STRIDE_SWEEP * gen)) for isl in islands])


def _generation_sweep(medium: ML.Medium, k: int, eps: float,
                      cfg: MemeticConfig, children: List[np.ndarray],
                      keys: np.ndarray) -> List[np.ndarray]:
    """The archipelago's generation step on the device: every island's
    child rides ONE vmapped refinement call (DESIGN.md §12) — the same
    shape-bucketed program as the initial tournaments, stepping all
    islands together.  ``batched_generations=False`` issues one call per
    island instead; per-island keys make the results identical, so the
    knob is purely a performance choice (pinned by a test)."""
    if cfg.batched_generations:
        return medium.refine_batch(children, k, eps, 0, keys=keys)
    return [medium.refine_batch([c], k, eps, 0, keys=keys[i:i + 1])[0]
            for i, c in enumerate(children)]


def _migration_round(state: IslandState, drv_rng: np.random.Generator,
                     mesh, rkey: Callable) -> None:
    """Ring rumor spreading: each island's best moves ``shift`` islands
    forward (collective_permute on a mesh, host roll otherwise); the
    receiver replaces its worst member — under the variant's replacement
    rule — on strict improvement."""
    n_isl = state.n_islands
    shift = 1 + int(drv_rng.integers(n_isl - 1))
    # the migrant is the best under the replacement rule (feasible members
    # first) — a fitness-only pick could ship an infeasible member that
    # every feasible receiver then rejects, silently disabling migration
    bests = [pop[min(range(len(pop)), key=lambda j: rkey(pop[j]))]
             for pop in state.islands]
    parts = np.stack([b.part for b in bests]).astype(np.int32)
    moved = ring_roll(parts, shift, mesh)
    for i, pop in enumerate(state.islands):
        src = bests[(i - shift) % n_isl]
        inc = Individual(moved[i].astype(np.int64), src.fitness,
                         src.balance, src.stamp, src.feasible)
        w = max(range(len(pop)), key=lambda j: rkey(pop[j]))
        if rkey(inc) < rkey(pop[w]):
            pop[w] = inc


def evolve_islands(medium: ML.Medium, k: int, eps: float,
                   cfg: MemeticConfig, seed: int, *,
                   fitness_fn: Optional[Callable] = None,
                   polish_fn: Optional[Callable] = None,
                   mesh=None,
                   on_generation: Optional[Callable] = None) -> IslandState:
    """Evolve an archipelago of populations over any multilevel medium.

    ``fitness_fn(part)`` defaults to the medium's objective;
    ``polish_fn(part, seed)`` is the variant hook applied to every child
    (KaBaPE negative-cycle polish, distributed parhyp local search).
    ``cfg.generations`` selects a deterministic generation count; with
    ``None`` the loop runs on the ``time_limit`` wall-clock budget
    (``time_limit == 0`` → initial populations only, paper semantics).
    Returns the final `IslandState`.
    """
    validate_memetic_params(cfg.n_islands, cfg.population, cfg.time_limit,
                            cfg.generations)
    if (not isinstance(cfg.migration_interval, numbers.Integral)
            or cfg.migration_interval < 1):
        raise ValueError(f"migration_interval must be a positive int, "
                         f"got {cfg.migration_interval!r}")
    if not 0.0 <= cfg.combine_prob <= 1.0:
        raise ValueError(
            f"combine_prob must be in [0, 1], got {cfg.combine_prob!r}")
    t0 = time.monotonic()
    fit = fitness_fn if fitness_fn is not None else (
        lambda p: medium.objective(p))

    def make(part, stamp: int) -> Individual:
        part = np.asarray(part, dtype=np.int64)
        return Individual(part, fit(part), medium.imbalance(part, k),
                          stamp, medium.is_feasible(part, k, eps))

    rkey = _replace_key(cfg)
    drv_rng = np.random.default_rng(seed)
    rec = ML.recorder_of(medium)

    pop0 = max(1, cfg.population // 2) if cfg.quickstart else cfg.population
    state = IslandState(islands=[])
    rngs: List[np.random.Generator] = []
    for isl in range(cfg.n_islands):
        iseed = island_seed(seed, isl)
        with rec.span("island_init", island=isl, size=pop0):
            parts = ML.population(medium, k, eps, iseed, pop0,
                                  stride=STRIDE_MEMBER)
        state.islands.append(
            [make(p, iseed + STRIDE_MEMBER * j)
             for j, p in enumerate(parts)])
        rngs.append(np.random.default_rng(iseed))
    if cfg.quickstart:
        # each island created a few; distribute copies among all islands
        # (the pool can be smaller than the draw — sample with replacement
        # then: the copies diverge under combine/mutation)
        every = state.individuals()
        need = cfg.population - pop0
        for pop in state.islands:
            extra = drv_rng.choice(len(every), size=need,
                                   replace=need > len(every))
            pop.extend(dataclasses.replace(every[e],
                                           part=every[e].part.copy())
                       for e in extra)

    def more(gen: int) -> bool:
        if cfg.generations is not None:
            return gen < cfg.generations
        return time.monotonic() - t0 < cfg.time_limit

    gen = 0
    while more(gen):
        gen += 1
        with rec.span("generation", gen=gen):
            children, stamps = [], []
            for isl in range(cfg.n_islands):
                with rec.span("island_step", island=isl):
                    child, stamp = _island_child(
                        medium, k, eps, cfg, state.islands[isl], rngs[isl],
                        island_seed(seed, isl), gen, rec)
                children.append(child)
                stamps.append(stamp)
            with rec.span("generation_sweep", gen=gen,
                          islands=cfg.n_islands):
                keys = _sweep_keys(seed, list(range(cfg.n_islands)), gen)
                children = _generation_sweep(medium, k, eps, cfg,
                                             children, keys)
            for isl in range(cfg.n_islands):
                child = children[isl]
                if polish_fn is not None:
                    child = polish_fn(child, stamps[isl])
                _island_accept(state.islands[isl], make(child, stamps[isl]),
                               rkey, rec)
            if (cfg.migrate and cfg.n_islands > 1
                    and gen % cfg.migration_interval == 0):
                with rec.span("migration", gen=gen):
                    _migration_round(state, drv_rng, mesh, rkey)
                rec.count("memetic/migrations")
        state.generations = gen
        if rec.enabled:
            best = state.best()
            rec.point("memetic", gen=gen, fitness=best.fitness,
                      balance=best.balance)
        if on_generation is not None:
            on_generation(gen, state.best().fitness)
    return state
