"""Population state for the memetic engine (DESIGN.md §10).

An `Individual` is a partition vector plus the scalars the engine ranks
by; an `IslandState` is the whole archipelago.  Ranking is everywhere the
*deterministic* total order ``key() = (fitness, balance, stamp)``: fitness
ties are broken by balance (the better-balanced individual wins — it has
more refinement headroom), and balance ties by the creation stamp (the
deterministic seed that produced the individual).  The old evolve loop
ranked by fitness alone, so tie order depended on population insertion
order and trajectories were not reproducible across runs — the regression
test pins the fix.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Individual:
    """One member of an island population.

    ``stamp`` is the deterministic seed that created the individual (initial
    multilevel seed, combine/V-cycle seed, or the source stamp for a
    migrated copy) — it doubles as the final tie-breaker, so the ranking is
    a total order independent of insertion order.  ``feasible`` is the
    medium's feasibility verdict, computed once at creation; replacement
    ranks it first so an infeasible child can never evict a feasible
    incumbent (combine children carry no feasibility guarantee).
    """

    part: np.ndarray
    fitness: float
    balance: float = 0.0
    stamp: int = 0
    feasible: bool = True

    def key(self) -> Tuple[float, float, int]:
        return (self.fitness, self.balance, self.stamp)


def best_index(pop: Sequence[Individual]) -> int:
    return min(range(len(pop)), key=lambda j: pop[j].key())


def worst_index(pop: Sequence[Individual]) -> int:
    return max(range(len(pop)), key=lambda j: pop[j].key())


@dataclasses.dataclass
class IslandState:
    """The archipelago: one population per island plus the generation
    counter the driver reached (wall-clock mode makes it data, not config)."""

    islands: List[List[Individual]]
    generations: int = 0

    @property
    def n_islands(self) -> int:
        return len(self.islands)

    def individuals(self) -> List[Individual]:
        return [ind for pop in self.islands for ind in pop]

    def best(self) -> Individual:
        allind = self.individuals()
        return allind[best_index(allind)]

    def best_part(self) -> np.ndarray:
        """Best feasible individual's partition (any-best fallback when the
        whole archipelago is infeasible) — the kaffpaE final-pick rule.
        Uses the feasibility verdicts cached at creation."""
        allind = self.individuals()
        feas = [i for i in allind if i.feasible]
        pool = feas if feas else allind
        return pool[best_index(pool)].part
