"""Rumor-spreading migration as a collective (DESIGN.md §10).

Every migration round each island pushes its best individual's partition
vector one ring step of ``shift`` islands: island i receives from island
(i - shift) mod I.  A seeded random shift per round is the randomized
rumor-spreading exchange of the paper's MPI formulation, restated as a
*static* permutation so it maps onto ``jax.lax.ppermute`` when the islands
are laid out as shards on a device mesh.

The stacked best-parts matrix (I, n) is sharded along the islands axis;
a global ring roll of island rows decomposes into at most two
``ppermute`` block exchanges plus an intra-shard reorder: with
``ipd = I / S`` islands per device and ``shift = q·ipd + r``, destination
device d needs rows from source devices (d-q) and (d-q-1) — block A
shifted q devices forward supplies local rows r.., block B shifted q+1
supplies rows ..r.  With one device both permutes are the identity and
the reorder is exactly the host ``np.roll`` — the mesh round is
bit-identical to the host-loop fallback (pinned by a regression test),
which also serves meshes whose device count does not divide the island
count.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

AXIS = "islands"


def islands_mesh(devices=None) -> Mesh:
    """A 1-D ``islands`` mesh over the given (default: all local) devices."""
    devs = np.asarray(jax.devices() if devices is None else devices)
    return Mesh(devs.reshape(-1), (AXIS,))


def ring_roll_host(parts: np.ndarray, shift: int) -> np.ndarray:
    """Host fallback: out[i] = parts[(i - shift) mod I]."""
    parts = np.asarray(parts)
    return np.roll(parts, shift % len(parts), axis=0)


@functools.partial(jax.jit, static_argnames=("mesh", "shift", "ipd", "n_sh"))
def _ring_roll_jit(mesh: Mesh, parts, shift: int, ipd: int, n_sh: int):
    q, r = divmod(shift, ipd)

    def local(block):
        a = jax.lax.ppermute(block, AXIS,
                             [(s, (s + q) % n_sh) for s in range(n_sh)])
        if r == 0:
            return a
        b = jax.lax.ppermute(block, AXIS,
                             [(s, (s + q + 1) % n_sh) for s in range(n_sh)])
        return jnp.concatenate([b[ipd - r:], a[:ipd - r]], axis=0)

    fn = shard_map(local, mesh=mesh, in_specs=P(AXIS, None),
                   out_specs=P(AXIS, None), check_vma=False)
    return fn(parts)


def ring_roll(parts: np.ndarray, shift: int, mesh=None) -> np.ndarray:
    """Ring-migrate the (I, n) best-parts matrix by ``shift`` islands.

    With a mesh whose device count divides I the roll runs as ppermute
    block exchanges on the ``islands`` sharding; otherwise (or with
    ``mesh=None``) the host fallback computes the identical result.
    """
    parts = np.asarray(parts, dtype=np.int32)
    n_isl = parts.shape[0]
    shift %= n_isl
    if shift == 0:
        return parts.copy()
    if mesh is None:
        return ring_roll_host(parts, shift)
    devs = np.asarray(mesh.devices).reshape(-1)
    if n_isl % len(devs) != 0:
        return ring_roll_host(parts, shift)
    m = Mesh(devs, (AXIS,))
    out = _ring_roll_jit(m, jnp.asarray(parts), shift, n_isl // len(devs),
                         len(devs))
    return np.asarray(out)
