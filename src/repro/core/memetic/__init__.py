"""The mesh-sharded memetic engine (DESIGN.md §10).

One island loop over any `multilevel.Medium`: kaffpaE / KaBaPE on graphs,
kahyparE on hypergraphs, the memetic separator mode on the 3-label
separator medium.  Children come from the engine's protected-coarsening
``combine`` and V-cycle mutation; migration is a seeded ring exchange of
each island's best partition vector — ``ppermute`` block exchanges when
the islands are laid out as shards on a device mesh, a bit-identical host
roll otherwise.
"""
from repro.core.memetic.driver import (MemeticConfig, evolve_islands,
                                       island_seed, validate_memetic_params)
from repro.core.memetic.migrate import (islands_mesh, ring_roll,
                                        ring_roll_host)
from repro.core.memetic.state import (Individual, IslandState, best_index,
                                      worst_index)

__all__ = [
    "Individual", "IslandState", "MemeticConfig",
    "best_index", "worst_index",
    "evolve_islands", "island_seed", "validate_memetic_params",
    "islands_mesh", "ring_roll", "ring_roll_host",
]
