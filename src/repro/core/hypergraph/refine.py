"""Size-constrained LP uncoarsening refinement for hypergraphs — device side.

Batch-synchronous k-way LP with exact move gains for both objectives:

  * connectivity (λ−1):  moving v from a to b removes w(e) for every net
    where v is a's sole pin, and adds w(e) for every net with no pin in b:
       gain(v, b) = R(v) − W(v) + A(v, b)
    with R(v) = Σ_{e∋v} w(e)·[cnt(e, a) = 1],  W(v) = Σ_{e∋v} w(e),
    A(v, b) = Σ_{e∋v} w(e)·[cnt(e, b) ≥ 1]  — so argmax_b A is the best
    target, exactly the pin-affinity the Pallas kernel computes.
  * cut-net:  gain(v, b) = Σ_{e∋v} w(e)·[cnt(e, b) = |e|−1]
                         − Σ_{e∋v} w(e)·[cnt(e, a) = |e|].

Moves are applied with the same capped acceptance (hard balance guarantee)
and undo-to-best semantics as the graph refiner (core/lp.py, core/refine.py).
Per-net pin counts come either from the Pallas pin-affinity kernel (ELL
path) or a COO scatter (oracle / CPU path); both views share pow2 padding so
jit caches hit across multilevel levels.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import lp as lp_mod
from repro.core.hypergraph import metrics as M
from repro.core.hypergraph.container import (EllHypergraph, Hypergraph,
                                             PinCoo, to_ell_h, to_pincoo)

_NEG = -1e30
_NOISE = 1e-4
_GAIN_EPS = 1e-3


def _hyper_refine_scan(hc: PinCoo, labels0: jax.Array, cap: jax.Array,
                       key: jax.Array, k: int, rounds: int,
                       objective: str, force_balance,
                       use_kernel: bool,
                       ell: Optional[EllHypergraph] = None):
    """One candidate's scan (unjitted; vmapped by `_hyper_refine_scan_batch`
    — single refines ride the batched program at the medium's batch floor,
    DESIGN.md §12)."""
    n = hc.n_pad
    vw = hc.vwgt
    w_pin = hc.mask * hc.netw[hc.pe]                      # (p_pad,)
    wtot = jnp.zeros((n,), jnp.float32).at[hc.pv].add(w_pin)

    if use_kernel and ell is not None:
        from repro.kernels import ops as kops

        def cnt_fn(labels):
            cnt, _ = kops.pin_count(ell.pins, ell.pin_mask, ell.netw,
                                    labels, k)
            return cnt
    else:
        def cnt_fn(labels):
            return M.pin_counts_device(hc, labels, k)

    obj_fn = M.km1_device if objective == "km1" else M.cut_net_device

    def gains(labels, cnt):
        cnt_e = cnt[hc.pe]                                # (p_pad, k)
        cnt_own = cnt_e[jnp.arange(hc.p_pad),
                        labels[hc.pv].astype(jnp.int32)]  # (p_pad,)
        if objective == "km1":
            pres = (cnt_e > 0).astype(jnp.float32)
            aff = jnp.zeros((n, k), jnp.float32).at[hc.pv].add(
                w_pin[:, None] * pres)
            rem = jnp.zeros((n,), jnp.float32).at[hc.pv].add(
                w_pin * (cnt_own == 1))
            return rem[:, None] - wtot[:, None] + aff
        makes = (cnt_e == (hc.esize[hc.pe] - 1.0)[:, None])
        joins = jnp.zeros((n, k), jnp.float32).at[hc.pv].add(
            w_pin[:, None] * makes.astype(jnp.float32))
        breaks = jnp.zeros((n,), jnp.float32).at[hc.pv].add(
            w_pin * (cnt_own == hc.esize[hc.pe]))
        return joins - breaks[:, None]

    def body(carry, key_r):
        labels, sizes, best_obj, best_labels, parity = carry
        cnt = cnt_fn(labels)
        # track best feasible state seen (undo-to-best)
        obj = obj_fn(cnt, hc.netw)
        feas = jnp.max(sizes - cap) <= 1e-6
        better = feas & (obj < best_obj)
        best_obj = jnp.where(better, obj, best_obj)
        best_labels = jnp.where(better, labels, best_labels)
        # propose + accept moves
        gain = gains(labels, cnt)
        gain = gain + jax.random.uniform(key_r, (n, k), jnp.float32,
                                         0.0, _NOISE)
        gain = gain.at[jnp.arange(n), labels].set(_NEG)
        room = sizes[None, :] + vw[:, None] <= cap[None, :]
        gain = jnp.where(room, gain, _NEG)
        best_gain = jnp.max(gain, axis=1)
        best_tgt = jnp.argmax(gain, axis=1).astype(labels.dtype)
        want = best_gain > _GAIN_EPS
        # overweight blocks push nodes out regardless of gain (when forced)
        over = sizes[labels] > cap[labels]
        want = want | (jnp.asarray(force_balance)
                       & over & (best_gain > _NEG / 2) & (vw > 0))
        node_par = (jnp.arange(n) + parity) % 2 == 0
        want = want & node_par
        proposal = jnp.where(want, best_tgt, labels)
        new_labels = lp_mod.capped_accept(labels, proposal, vw, sizes, cap,
                                          jnp.where(want, best_gain, _NEG))
        new_sizes = jnp.zeros((k,), jnp.float32).at[new_labels].add(vw)
        return (new_labels, new_sizes, best_obj, best_labels,
                parity + 1), obj

    sizes0 = jnp.zeros((k,), jnp.float32).at[labels0].add(vw)
    keys = jax.random.split(key, rounds)
    carry0 = (labels0, sizes0, jnp.float32(jnp.inf), labels0, jnp.int32(0))
    (labels, sizes, best_obj, best_labels, _), _ = jax.lax.scan(
        body, carry0, keys)
    # evaluate the final state too
    obj = obj_fn(cnt_fn(labels), hc.netw)
    feas = jnp.max(sizes - cap) <= 1e-6
    better = feas & (obj < best_obj)
    best_obj = jnp.where(better, obj, best_obj)
    best_labels = jnp.where(better, labels, best_labels)
    have = jnp.isfinite(best_obj)
    return jnp.where(have, best_labels, labels), best_obj


def _caps_for(hg: Hypergraph, k: int, eps: float) -> np.ndarray:
    lmax = np.ceil(hg.total_vwgt() / k)
    return np.full(k, (1.0 + eps) * lmax)


def k_bucket(k: int) -> int:
    """pow2 block-count bucket with floor 4 (DESIGN.md §12): scans for
    k=2..4 (and 5..8, ...) share one compiled program per shape bucket.
    Fake blocks get zero capacity, so no vertex ever moves into one."""
    from repro.core.csr import _pow2_pad
    return _pow2_pad(max(k, 4), 1)


def _pad_caps(cap: np.ndarray, k_pad: int) -> np.ndarray:
    out = np.zeros(k_pad, np.float32)
    out[:len(cap)] = cap
    return out


@functools.partial(jax.jit, static_argnames=("k", "rounds", "objective",
                                             "use_kernel"))
def _hyper_refine_scan_batch(hc: PinCoo, labels0: jax.Array, cap: jax.Array,
                             keys: jax.Array, force: jax.Array, k: int,
                             rounds: int, objective: str,
                             use_kernel: bool,
                             ell: Optional[EllHypergraph] = None):
    """THE hypergraph refinement program: everything routes through here."""
    def one(lab0, key, f):
        return _hyper_refine_scan(hc, lab0, cap, key, k, rounds, objective,
                                  f, use_kernel, ell=ell)
    return jax.vmap(one)(labels0, keys, force)


def _run_hyper_scan_batch(hc, cap_np, labs, keys, force, k, rounds,
                          objective, use_kernel, ell, batch_floor):
    from repro.core import multilevel as ML
    from repro.core.refine import _pad_rows, batch_bucket
    b = labs.shape[0]
    b_pad = batch_bucket(b, batch_floor)
    k_pad = k_bucket(k)
    ML.note_bucket_pad(b_pad - b)
    ML.note_program("hyper", hc.n_pad, hc.e_pad, hc.p_pad, k_pad, rounds,
                    objective, b_pad, use_kernel)
    outs, _ = _hyper_refine_scan_batch(
        hc, jnp.asarray(_pad_rows(labs, b_pad)),
        jnp.asarray(_pad_caps(np.asarray(cap_np), k_pad)),
        jnp.asarray(_pad_rows(keys, b_pad)),
        jnp.asarray(_pad_rows(force, b_pad)),
        k_pad, rounds, objective, use_kernel, ell=ell)
    return np.asarray(outs, dtype=np.int64)[:b]


def refine_hypergraph(hg: Hypergraph, part: np.ndarray, k: int,
                      eps: float = 0.03, rounds: int = 12, seed: int = 0,
                      objective: str = "km1",
                      force_balance: bool = False,
                      use_kernel: Optional[bool] = None,
                      hc: Optional[PinCoo] = None,
                      ell: Optional[EllHypergraph] = None,
                      batch_floor: int = 1) -> np.ndarray:
    """Polish ``part``; never returns a worse feasible objective.

    ``use_kernel=None`` resolves to the backend default (Pallas pin counts
    on TPU, COO scatter elsewhere); ``hc``/``ell`` accept cached views.
    ``batch_floor`` pads the batch dim up to the medium's bucket so this
    single call reuses the tournament's compiled program.
    """
    if k <= 1 or hg.n == 0:
        return np.asarray(part, dtype=np.int64)
    from repro.core.refine import default_use_kernel
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    hc = hc if hc is not None else to_pincoo(hg)
    if use_kernel and ell is None:
        ell = to_ell_h(hg)
    labs = np.zeros((1, hc.n_pad), dtype=np.int32)
    labs[0, :hg.n] = part
    keys = np.asarray(jax.random.PRNGKey(seed))[None]
    outs = _run_hyper_scan_batch(hc, _caps_for(hg, k, eps), labs, keys,
                                 np.asarray([force_balance]), k, rounds,
                                 objective, use_kernel, ell, batch_floor)
    out = outs[0][:hg.n]
    score = M.connectivity if objective == "km1" else M.cut_net
    # paranoia: keep the better of (in, out) among feasible options
    if score(hg, out) <= score(hg, part) or force_balance:
        return out
    return np.asarray(part, dtype=np.int64)


def refine_hypergraph_batch(hg: Hypergraph, parts: list, k: int,
                            eps: float = 0.03, rounds: int = 12,
                            seed: int = 0, objective: str = "km1",
                            use_kernel: Optional[bool] = None,
                            hc: Optional[PinCoo] = None,
                            ell: Optional[EllHypergraph] = None,
                            keys: Optional[np.ndarray] = None,
                            batch_floor: int = 1) -> list:
    """Refine several candidate partitions in one vmapped device call (the
    initial-partition tournament shares a single compile).  ``keys``
    overrides the per-candidate PRNG keys (shape ``(b, 2)``) — the memetic
    sweep passes per-island keys so each island's trajectory is independent
    of how many islands are batched together."""
    if k <= 1 or hg.n == 0 or not parts:
        return [np.asarray(p, dtype=np.int64) for p in parts]
    from repro.core.refine import default_use_kernel
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    hc = hc if hc is not None else to_pincoo(hg)
    if use_kernel and ell is None:
        ell = to_ell_h(hg)
    labs = np.zeros((len(parts), hc.n_pad), dtype=np.int32)
    for i, p in enumerate(parts):
        labs[i, :hg.n] = p
    force = np.asarray([not M.is_feasible(hg, p, k, eps) for p in parts])
    if keys is None:
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed),
                                           len(parts)))
    outs = _run_hyper_scan_batch(hc, _caps_for(hg, k, eps), labs,
                                 np.asarray(keys), force, k, rounds,
                                 objective, use_kernel, ell, batch_floor)
    outs = outs[:, :hg.n]
    score = M.connectivity if objective == "km1" else M.cut_net
    result = []
    for i, p in enumerate(parts):
        if score(hg, outs[i]) <= score(hg, p) or force[i]:
            result.append(outs[i])
        else:
            result.append(np.asarray(p, dtype=np.int64))
    return result
