"""kahypar — the multilevel hypergraph partitioner driver.

Since PR 2 the multilevel loop lives in the shared engine
(core/multilevel.py); this module provides the hypergraph `Medium` adapter
and the ``kahypar`` program entry.  Riding on the engine, hypergraphs get
cut-protected iterated V-cycles and ``time_limit`` restarts for free —
both with the same non-worsening guarantees as the graph side — and the
pin-COO / ELL-H device views are built once per hierarchy level and reused
across refinement rounds, initial tries, V-cycles and restarts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import multilevel as ML
from repro.core.hypergraph.container import Hypergraph, to_ell_h, to_pincoo
from repro.core.hypergraph import coarsen as C
from repro.core.hypergraph import initial as I
from repro.core.hypergraph import metrics as M
from repro.core.hypergraph.refine import (refine_hypergraph,
                                          refine_hypergraph_batch)


@dataclasses.dataclass
class KahyparConfig:
    lp_iters: int = 8                   # clustering LP iterations per level
    refine_rounds: int = 10
    initial_tries: int = 4
    vcycles: int = 1                    # iterated multilevel cycles
    contraction_stop_factor: int = 20   # stop coarsening at ~factor*k nodes
    cluster_weight_factor: float = 3.0  # max cluster weight = W/(factor*k)
    stop_n_floor: int = 48              # never coarsen below this many nodes
    max_net_size: int = 64              # larger nets use the star fallback
    use_kernel: Optional[bool] = None   # None = Pallas on TPU, COO fallback

    @property
    def batch_floor(self) -> int:
        """Shared pow2 batch bucket (DESIGN.md §12): single refines pad up
        to the tournament width so both run one compiled program."""
        from repro.core.csr import _pow2_pad
        return _pow2_pad(max(self.initial_tries, 1), 1)


PRESETS = {
    "fast":   KahyparConfig(refine_rounds=6, initial_tries=2),
    "eco":    KahyparConfig(refine_rounds=10, initial_tries=4),
    "strong": KahyparConfig(refine_rounds=16, initial_tries=8,
                            contraction_stop_factor=30, vcycles=2),
}


class HypergraphMedium(ML.ViewCache):
    """The hypergraph adapter for the shared multilevel engine."""

    def __init__(self, hg: Hypergraph, cfg: KahyparConfig,
                 objective: str = "km1", recorder=None):
        if objective not in ("km1", "cut"):
            raise ValueError(f"unknown objective {objective!r}")
        from repro.core.refine import default_use_kernel
        self.hg = hg
        self.cfg = cfg
        self.obj = objective
        self.recorder = recorder
        self.use_kernel = (default_use_kernel() if cfg.use_kernel is None
                           else cfg.use_kernel)

    # -- structure ---------------------------------------------------------
    @property
    def n(self) -> int:
        return self.hg.n

    @property
    def params(self) -> ML.EngineParams:
        cfg = self.cfg
        return ML.EngineParams(
            initial_tries=cfg.initial_tries, vcycles=cfg.vcycles,
            contraction_stop_factor=cfg.contraction_stop_factor,
            cluster_weight_factor=cfg.cluster_weight_factor,
            stop_n_floor=cfg.stop_n_floor, recorder=self.recorder)

    def total_vwgt(self) -> int:
        return self.hg.total_vwgt()

    def cluster(self, max_cluster_weight: float, seed: int,
                protect: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
        return C.lp_clustering(self.hg, max_cluster_weight,
                               iters=self.cfg.lp_iters, seed=seed,
                               max_net_size=self.cfg.max_net_size,
                               protect=protect)

    def contract(self, clusters: np.ndarray):
        coarse, cl = C.contract(self.hg, clusters)
        return HypergraphMedium(coarse, self.cfg, self.obj,
                                recorder=self.recorder), cl

    # -- device views ------------------------------------------------------
    def build_views(self):
        hc = to_pincoo(self.hg)
        ell = to_ell_h(self.hg) if self.use_kernel else None
        return hc, ell

    # -- refinement --------------------------------------------------------
    def refine(self, part: np.ndarray, k: int, eps: float, seed: int,
               force_balance: Optional[bool] = None) -> np.ndarray:
        hc, ell = self.views
        if force_balance is None:
            force_balance = not M.is_feasible(self.hg, part, k, eps)
        out = refine_hypergraph(self.hg, part, k, eps,
                                rounds=self.cfg.refine_rounds, seed=seed,
                                objective=self.obj,
                                force_balance=force_balance,
                                use_kernel=self.use_kernel, hc=hc, ell=ell,
                                batch_floor=self.cfg.batch_floor)
        rec = ML.recorder_of(self)
        if rec.enabled:
            rec.count("refine/rounds", self.cfg.refine_rounds)
            rec.count("refine/moves",
                      int(np.sum(out != np.asarray(part, dtype=np.int64))))
            if force_balance:
                rec.count("refine/forced_balance")
        return out

    def refine_batch(self, parts: Sequence[np.ndarray], k: int, eps: float,
                     seed: int, keys=None) -> List[np.ndarray]:
        hc, ell = self.views
        return refine_hypergraph_batch(self.hg, list(parts), k, eps,
                                       rounds=self.cfg.refine_rounds,
                                       seed=seed, objective=self.obj,
                                       use_kernel=self.use_kernel,
                                       hc=hc, ell=ell, keys=keys,
                                       batch_floor=self.cfg.batch_floor)

    def polish(self, part: np.ndarray, k: int, eps: float,
               seed: int) -> np.ndarray:
        return part

    # -- initial partitioning ----------------------------------------------
    def initial_candidates(self, k: int, eps: float,
                           seed: int) -> List[np.ndarray]:
        return [I.greedy_growing(self.hg, k, seed=seed + 101 * t)
                if t % 2 == 0
                else I.random_partition(self.hg, k, seed=seed + 101 * t)
                for t in range(self.cfg.initial_tries)]

    # -- objective ---------------------------------------------------------
    def objective(self, part: np.ndarray) -> float:
        score = M.connectivity if self.obj == "km1" else M.cut_net
        return float(score(self.hg, part))

    def imbalance(self, part: np.ndarray, k: int) -> float:
        return M.balance(self.hg, part, k)

    def is_feasible(self, part: np.ndarray, k: int, eps: float) -> bool:
        return M.is_feasible(self.hg, part, k, eps)


def multilevel_hypergraph_partition(hg: Hypergraph, k: int, eps: float,
                                    cfg: KahyparConfig, seed: int,
                                    objective: str) -> np.ndarray:
    return ML.multilevel(HypergraphMedium(hg, cfg, objective), k, eps, seed)


def kahypar(hg: Hypergraph, k: int, eps: float = 0.03, preset: str = "eco",
            seed: int = 0, objective: str = "km1",
            input_partition: Optional[np.ndarray] = None,
            vcycles: Optional[int] = None,
            time_limit: float = 0.0, report=None) -> np.ndarray:
    """The ``kahypar`` program: multilevel hypergraph partitioning.

    ``objective`` ∈ {"km1", "cut"}; returns a block id per vertex.
    ``vcycles`` overrides the preset's iterated-multilevel count and
    ``time_limit`` enables repeated restarts under a wall-clock budget —
    both engine features shared with kaffpa.  ``report`` is an optional
    ``obs.Recorder`` capturing this run's spans, counters and quality
    trajectory (DESIGN.md §11).
    """
    if objective not in ("km1", "cut"):
        raise ValueError(f"unknown objective {objective!r}")
    cfg = PRESETS[preset]
    if k <= 1:
        return np.zeros(hg.n, dtype=np.int64)
    medium = HypergraphMedium(hg, cfg, objective, recorder=report)
    return ML.run(medium, k, eps, seed, vcycles=vcycles,
                  time_limit=time_limit, input_partition=input_partition)


def kahyparE(hg: Hypergraph, k: int, eps: float = 0.03, preset: str = "eco",
             seed: int = 0, objective: str = "km1", n_islands: int = 2,
             population: int = 2, time_limit: float = 10.0,
             generations: Optional[int] = None, migrate: bool = True,
             mesh=None, on_generation=None, report=None) -> np.ndarray:
    """The ``kahyparE`` program: memetic multilevel hypergraph partitioning
    (the KaHyParE analogue of kaffpaE, DESIGN.md §10).

    Rides the medium-generic island driver over `HypergraphMedium` for
    either objective.  ``mesh`` lays the islands out as shards for
    collective_permute migration; on a multi-device mesh the per-island
    local search additionally polishes every child with the distributed
    ``parhyp`` refinement round (preset-matched round count, cached
    `ShardedHypergraph`), so the whole archipelago keeps the devices busy.
    ``generations`` selects a deterministic generation count instead of the
    ``time_limit`` wall-clock budget.
    """
    from repro.core import memetic as MEM
    MEM.validate_memetic_params(n_islands, population, time_limit,
                                generations)
    if objective not in ("km1", "cut"):
        raise ValueError(f"unknown objective {objective!r}")
    if k <= 1:
        return np.zeros(hg.n, dtype=np.int64)
    medium = HypergraphMedium(hg, PRESETS[preset], objective,
                              recorder=report)
    polish_fn = None
    if mesh is not None and np.asarray(mesh.devices).size > 1:
        from jax.sharding import Mesh
        from repro.core.hypergraph import dist as D
        devs = np.asarray(mesh.devices).reshape(-1)
        nets_mesh = Mesh(devs, ("nets",))
        pre = "eco" if preset in ("eco", "strong") else "fast"
        rounds = D.PARHYP_PRESETS[pre]["rounds"]
        sh = D.shard_hypergraph(hg, len(devs))

        def polish_fn(part, pseed):
            return D.parhyp_refine(hg, part, k, eps, nets_mesh,
                                   rounds=rounds, seed=pseed,
                                   objective=objective, sh=sh)

    cfg = MEM.MemeticConfig(n_islands=n_islands, population=population,
                            time_limit=time_limit, generations=generations,
                            migrate=migrate)
    state = MEM.evolve_islands(medium, k, eps, cfg, seed,
                               polish_fn=polish_fn, mesh=mesh,
                               on_generation=on_generation)
    return state.best_part()
