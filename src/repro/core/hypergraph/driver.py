"""kahypar — the multilevel hypergraph partitioner driver.

Mirrors the kaffpa multilevel loop (core/kaffpa.py): LP-clustering
coarsening until ~stop_factor·k vertices remain, greedy hypergraph growing
on the coarsest level, then size-constrained LP refinement at every level of
the uncoarsening, optimizing cut-net or connectivity (λ−1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.hypergraph.container import Hypergraph, to_ell_h, to_pincoo
from repro.core.hypergraph import coarsen as C
from repro.core.hypergraph import initial as I
from repro.core.hypergraph import metrics as M
from repro.core.hypergraph.refine import refine_hypergraph


@dataclasses.dataclass
class KahyparConfig:
    lp_iters: int = 8                   # clustering LP iterations per level
    refine_rounds: int = 10
    initial_tries: int = 4
    contraction_stop_factor: int = 20   # stop coarsening at ~factor*k nodes
    cluster_weight_factor: float = 3.0  # max cluster weight = W/(factor*k)
    max_net_size: int = 64              # nets larger than this skip rating
    use_kernel: bool = False            # Pallas pin-count path in refinement


PRESETS = {
    "fast":   KahyparConfig(refine_rounds=6, initial_tries=2),
    "eco":    KahyparConfig(refine_rounds=10, initial_tries=4),
    "strong": KahyparConfig(refine_rounds=16, initial_tries=8,
                            contraction_stop_factor=30),
}


def _build_hierarchy(hg: Hypergraph, k: int, cfg: KahyparConfig, seed: int):
    """levels = [(hg0, None), (hg1, cl0), ...]; cl maps fine → coarse ids."""
    levels = [(hg, None)]
    cur = hg
    stop_n = max(cfg.contraction_stop_factor * k, 48)
    lvl = 0
    while cur.n > stop_n:
        max_cw = max(1.0, cur.total_vwgt()
                     / (cfg.cluster_weight_factor * k))
        res = C.coarsen_level(cur, max_cw, seed + 31 * lvl,
                              iters=cfg.lp_iters,
                              max_net_size=cfg.max_net_size)
        if res is None:
            break
        coarse, cl = res
        levels.append((coarse, cl))
        cur = coarse
        lvl += 1
    return levels


def _refine_level(hg: Hypergraph, part: np.ndarray, k: int, eps: float,
                  cfg: KahyparConfig, seed: int, objective: str,
                  views=None) -> np.ndarray:
    hc, ell = views if views is not None else (None, None)
    force = not M.is_feasible(hg, part, k, eps)
    return refine_hypergraph(hg, part, k, eps, rounds=cfg.refine_rounds,
                             seed=seed, objective=objective,
                             force_balance=force,
                             use_kernel=cfg.use_kernel, hc=hc, ell=ell)


def _initial_partition(hg: Hypergraph, k: int, eps: float,
                       cfg: KahyparConfig, seed: int,
                       objective: str) -> np.ndarray:
    score = M.connectivity if objective == "km1" else M.cut_net
    hc = to_pincoo(hg)
    ell = to_ell_h(hg) if cfg.use_kernel else None
    best, best_obj = None, np.inf
    for t in range(cfg.initial_tries):
        raw = I.greedy_growing(hg, k, seed=seed + 101 * t) if t % 2 == 0 \
            else I.random_partition(hg, k, seed=seed + 101 * t)
        part = _refine_level(hg, raw, k, eps, cfg, seed + t, objective,
                             views=(hc, ell))
        s = score(hg, part)
        if s < best_obj and M.is_feasible(hg, part, k, eps):
            best, best_obj = part, s
        elif best is None:
            best = part
    return best


def multilevel_hypergraph_partition(hg: Hypergraph, k: int, eps: float,
                                    cfg: KahyparConfig, seed: int,
                                    objective: str) -> np.ndarray:
    levels = _build_hierarchy(hg, k, cfg, seed)
    hg_c, _ = levels[-1]
    part = _initial_partition(hg_c, k, eps, cfg, seed, objective)
    for li in range(len(levels) - 1, 0, -1):
        hg_fine, _ = levels[li - 1]
        _, cl = levels[li]
        part = C.project(part, cl)
        part = _refine_level(hg_fine, part, k, eps, cfg, seed + li,
                             objective)
    return part


def kahypar(hg: Hypergraph, k: int, eps: float = 0.03, preset: str = "eco",
            seed: int = 0, objective: str = "km1",
            input_partition: Optional[np.ndarray] = None) -> np.ndarray:
    """The ``kahypar`` program: multilevel hypergraph partitioning.

    ``objective`` ∈ {"km1", "cut"}; returns a block id per vertex.
    """
    if objective not in ("km1", "cut"):
        raise ValueError(f"unknown objective {objective!r}")
    cfg = PRESETS[preset]
    if k <= 1:
        return np.zeros(hg.n, dtype=np.int64)
    if input_partition is not None:
        part = np.asarray(input_partition, dtype=np.int64)
        return _refine_level(hg, part, k, eps, cfg, seed, objective)
    return multilevel_hypergraph_partition(hg, k, eps, cfg, seed, objective)
