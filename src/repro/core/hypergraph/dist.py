"""parhyp — distributed-memory multilevel hypergraph partitioning via
shard_map (DESIGN.md §9), the hypergraph sibling of core/parhip.py.

The MPI design of ParHIP carries over to hypergraphs with one twist: the
unit of distribution is the *net*, not the vertex.  Nets (and all their
pins) are block-distributed over the mesh axis ``nets`` as padded per-shard
pin-COO rows; vertex labels stay replicated (the ghost exchange is the
all-gather SPMD partitioning inserts).  Each refinement round:

  1. every shard scatters its local pins into a per-(net, block) pin-count
     partial and ``psum``s it into the replicated global histogram Φ(e, b);
  2. exact (λ−1) / cut-net move gains are derived from Φ — the per-vertex
     affinity/removal partials are again local scatters followed by a
     ``psum`` (a net's pins all live on one shard, so its contribution to
     any vertex gain is computed exactly once);
  3. moves are proposed with the same noise/parity split as the sequential
     refiner, and each shard applies capped acceptance on its *owned
     vertex slice* against its share of the psum'd global remaining
     capacity — so the balance constraint holds globally without a
     sequential arbiter (the core/parhip.py recipe).

With a 1-device mesh the round is bit-identical to the sequential COO
oracle (`refine._hyper_refine_scan` with ``use_kernel=False``): same pin
layout, same RNG stream, same scatter orders, same capped acceptance —
the regression test pins this.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro import obs
from repro.core.csr import _pow2_pad
from repro.core import lp as lp_mod
from repro.core.hypergraph.container import Hypergraph
from repro.core.hypergraph import metrics as M

# psums issued per distributed refinement round: the Φ(e,b) histogram plus
# two gain partials (aff/rem for km1, joins/breaks for cut-net)
_PSUMS_PER_ROUND = 3

_NEG = -1e30
_NOISE = 1e-4
_GAIN_EPS = 1e-3


# ---------------------------------------------------------------------------
# host container: net-block-distributed pin COO
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedHypergraph:
    """Host container: nets (with all their pins) block-distributed into
    padded per-shard pin-COO rows; net/vertex weight vectors replicated.

    Padding pins are (net ``e_pad-1``, vertex ``n_pad-1``, mask 0) on a
    zero-weight net — the `PinCoo` convention, so with one shard the layout
    is exactly ``to_pincoo``'s (the bit-exactness anchor).
    """

    pv: np.ndarray      # (S, p_shard) int32 — pin's vertex (global id)
    pe: np.ndarray      # (S, p_shard) int32 — pin's net (global id)
    mask: np.ndarray    # (S, p_shard) f32   — 1 real, 0 padding
    netw: np.ndarray    # (e_pad,) f32 — net weights, 0 padding (replicated)
    esize: np.ndarray   # (e_pad,) f32 — pin counts, 0 padding (replicated)
    vwgt: np.ndarray    # (n_pad,) f32 — vertex weights, 0 pad (replicated)
    n: int
    m: int
    rows_v: int         # vertices owned per shard (n_pad == S * rows_v)

    @property
    def n_shards(self) -> int:
        return self.pv.shape[0]

    @property
    def p_shard(self) -> int:
        return self.pv.shape[1]

    @property
    def n_pad(self) -> int:
        return len(self.vwgt)

    @property
    def e_pad(self) -> int:
        return len(self.netw)


def shard_hypergraph(hg: Hypergraph, n_shards: int, p_mult: int = 256,
                     n_mult: int = 128, e_mult: int = 128
                     ) -> ShardedHypergraph:
    """Block-distribute nets over ``n_shards``: shard s owns the contiguous
    net-id range [s·⌈e_pad/S⌉, (s+1)·⌈e_pad/S⌉) and all of those nets'
    pins, laid out in global pin order."""
    n, m, p = hg.n, hg.m, hg.pins
    n_pad = _pow2_pad(max(n, 1), n_mult)
    rows_v = -(-n_pad // n_shards)
    n_pad = rows_v * n_shards
    e_pad = _pow2_pad(m + 1, e_mult)
    e_rows = -(-e_pad // n_shards)
    pe_h = hg.pin_sources()
    owner = np.minimum(pe_h // e_rows, n_shards - 1)
    pmax = int(np.bincount(owner, minlength=n_shards).max()) if p else 1
    p_shard = _pow2_pad(max(pmax, 1), p_mult)
    pv = np.full((n_shards, p_shard), n_pad - 1, dtype=np.int32)
    pe = np.full((n_shards, p_shard), e_pad - 1, dtype=np.int32)
    mask = np.zeros((n_shards, p_shard), dtype=np.float32)
    for s in range(n_shards):
        ids = np.flatnonzero(owner == s)
        pv[s, :len(ids)] = hg.eind[ids]
        pe[s, :len(ids)] = pe_h[ids]
        mask[s, :len(ids)] = 1.0
    netw = np.zeros(e_pad, dtype=np.float32)
    netw[:m] = hg.ewgt
    esize = np.zeros(e_pad, dtype=np.float32)
    esize[:m] = hg.net_sizes()
    vwgt = np.zeros(n_pad, dtype=np.float32)
    vwgt[:n] = hg.vwgt
    return ShardedHypergraph(pv=pv, pe=pe, mask=mask, netw=netw,
                             esize=esize, vwgt=vwgt, n=n, m=m, rows_v=rows_v)


# ---------------------------------------------------------------------------
# the distributed round (shard_map body)
# ---------------------------------------------------------------------------

def _dist_cnt_local(pv, pe, mask, labels, k: int, e_pad: int, axis: str):
    """Local per-(net, block) pin-count partial, psum'd to global Φ(e, b)."""
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    cnt = jnp.zeros((e_pad, k), jnp.float32).at[
        pe, labels[pv].astype(jnp.int32)].add(mask)
    return jax.lax.psum(cnt, axis)


def _dist_wtot_local(pv, pe, mask, netw, vwgt, axis: str):
    """Per-vertex total incident net weight W(v), psum'd — round-invariant,
    so it is computed once before the refinement scan."""
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    w_pin = mask * netw[pe]
    n = vwgt.shape[0]
    return jax.lax.psum(
        jnp.zeros((n,), jnp.float32).at[pv].add(w_pin), axis)


def _dist_round_local(pv, pe, mask, netw, esize, vwgt, wtot, labels, sizes,
                      cap, key, parity, force, rows_v: int, k: int,
                      n_shards: int, axis: str, objective: str):
    """One distributed LP round, run per shard under shard_map.

    ``labels`` is the full replicated vector; pin arrays arrive as (1, ·)
    local blocks.  Returns (new labels for the owned vertex slice, the
    pre-move objective) — gain math mirrors refine._hyper_refine_scan
    exactly so the 1-shard round is bit-identical to the sequential oracle.
    """
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    n = labels.shape[0]
    e_pad = netw.shape[0]
    p_loc = pv.shape[0]
    w_pin = mask * netw[pe]
    cnt = jax.lax.psum(
        jnp.zeros((e_pad, k), jnp.float32).at[
            pe, labels[pv].astype(jnp.int32)].add(mask), axis)
    obj_fn = M.km1_device if objective == "km1" else M.cut_net_device
    obj = obj_fn(cnt, netw)
    # exact move gains from the replicated histogram (per-vertex partials
    # from local pins, psum'd — each net contributes on exactly one shard)
    cnt_e = cnt[pe]                                       # (p_loc, k)
    cnt_own = cnt_e[jnp.arange(p_loc), labels[pv].astype(jnp.int32)]
    if objective == "km1":
        pres = (cnt_e > 0).astype(jnp.float32)
        aff = jax.lax.psum(jnp.zeros((n, k), jnp.float32).at[pv].add(
            w_pin[:, None] * pres), axis)
        rem = jax.lax.psum(jnp.zeros((n,), jnp.float32).at[pv].add(
            w_pin * (cnt_own == 1)), axis)
        gain = rem[:, None] - wtot[:, None] + aff
    else:
        makes = (cnt_e == (esize[pe] - 1.0)[:, None])
        joins = jax.lax.psum(jnp.zeros((n, k), jnp.float32).at[pv].add(
            w_pin[:, None] * makes.astype(jnp.float32)), axis)
        breaks = jax.lax.psum(jnp.zeros((n,), jnp.float32).at[pv].add(
            w_pin * (cnt_own == esize[pe])), axis)
        gain = joins - breaks[:, None]
    gain = gain + jax.random.uniform(key, (n, k), jnp.float32, 0.0, _NOISE)
    gain = gain.at[jnp.arange(n), labels].set(_NEG)
    room = sizes[None, :] + vwgt[:, None] <= cap[None, :]
    gain = jnp.where(room, gain, _NEG)
    best_gain = jnp.max(gain, axis=1)
    best_tgt = jnp.argmax(gain, axis=1).astype(labels.dtype)
    want = best_gain > _GAIN_EPS
    over = sizes[labels] > cap[labels]
    want = want | (jnp.asarray(force)
                   & over & (best_gain > _NEG / 2) & (vwgt > 0))
    node_par = (jnp.arange(n) + parity) % 2 == 0
    want = want & node_par
    proposal = jnp.where(want, best_tgt, labels)
    pri = jnp.where(want, best_gain, _NEG)
    # Per-shard capped acceptance on the owned vertex slice against the
    # psum'd global size constraint.  The split of the remaining room is
    # contention-aware: per block, if the global proposed inflow (demand,
    # computable locally from the replicated proposals) fits the room,
    # every shard may accept (total <= demand <= room); otherwise only a
    # rotating owner shard gets the room (total <= room).  Either way the
    # global constraint holds without a sequential arbiter, and an even
    # room/S split — which rounds to zero headroom for unit-weight moves at
    # tight eps — is avoided.  With one shard the owner is always shard 0,
    # so the round stays bit-identical to the sequential oracle.
    me = jax.lax.axis_index(axis)
    vw_mov = jnp.where(proposal != labels, vwgt, 0.0)
    demand = jnp.zeros((k,), jnp.float32).at[proposal].add(vw_mov)
    uncontended = demand <= cap - sizes
    owner_b = (jnp.arange(k) + parity) % n_shards == me
    cap_local = jnp.where(uncontended | owner_b, cap, sizes)
    off = me * rows_v
    lab_own = jax.lax.dynamic_slice(labels, (off,), (rows_v,))
    prop_own = jax.lax.dynamic_slice(proposal, (off,), (rows_v,))
    vw_own = jax.lax.dynamic_slice(vwgt, (off,), (rows_v,))
    pri_own = jax.lax.dynamic_slice(pri, (off,), (rows_v,))
    new_own = lp_mod.capped_accept(lab_own, prop_own, vw_own, sizes,
                                   cap_local, pri_own)
    return new_own, obj


@functools.partial(jax.jit,
                   static_argnames=("rows_v", "k", "rounds", "n_shards",
                                    "axis", "objective", "mesh"))
def _parhyp_refine_jit(mesh: Mesh, pv, pe, mask, netw, esize, vwgt,
                       labels0, cap, key, force, rows_v: int, k: int,
                       rounds: int, n_shards: int, axis: str,
                       objective: str):
    spec_p = P(axis, None)
    spec_r = P()
    e_pad = netw.shape[0]
    round_fn = shard_map(
        functools.partial(_dist_round_local, rows_v=rows_v, k=k,
                          n_shards=n_shards, axis=axis, objective=objective),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r, spec_r, spec_r,
                  spec_r, spec_r, spec_r, spec_r, spec_r, spec_r),
        out_specs=(P(axis), P()),
        check_vma=False,
    )
    cnt_fn = shard_map(
        functools.partial(_dist_cnt_local, k=k, e_pad=e_pad, axis=axis),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r),
        out_specs=P(),
        check_vma=False,
    )
    wtot_fn = shard_map(
        functools.partial(_dist_wtot_local, axis=axis),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r),
        out_specs=P(),
        check_vma=False,
    )
    obj_fn = M.km1_device if objective == "km1" else M.cut_net_device
    wtot = wtot_fn(pv, pe, mask, netw, vwgt)

    def body(carry, key_r):
        labels, sizes, best_obj, best_labels, parity = carry
        new_labels, obj = round_fn(pv, pe, mask, netw, esize, vwgt, wtot,
                                   labels, sizes, cap, key_r, parity, force)
        # undo-to-best: track the best feasible pre-move state
        feas = jnp.max(sizes - cap) <= 1e-6
        better = feas & (obj < best_obj)
        best_obj = jnp.where(better, obj, best_obj)
        best_labels = jnp.where(better, labels, best_labels)
        new_sizes = jnp.zeros((k,), jnp.float32).at[new_labels].add(vwgt)
        return (new_labels, new_sizes, best_obj, best_labels,
                parity + 1), obj

    sizes0 = jnp.zeros((k,), jnp.float32).at[labels0].add(vwgt)
    keys = jax.random.split(key, rounds)
    carry0 = (labels0, sizes0, jnp.float32(jnp.inf), labels0, jnp.int32(0))
    (labels, sizes, best_obj, best_labels, _), _ = jax.lax.scan(
        body, carry0, keys)
    # evaluate the final state too
    obj = obj_fn(cnt_fn(pv, pe, mask, labels), netw)
    feas = jnp.max(sizes - cap) <= 1e-6
    better = feas & (obj < best_obj)
    best_obj = jnp.where(better, obj, best_obj)
    best_labels = jnp.where(better, labels, best_labels)
    have = jnp.isfinite(best_obj)
    return jnp.where(have, best_labels, labels), best_obj


def parhyp_refine(hg: Hypergraph, part: np.ndarray, k: int,
                  eps: float = 0.03, mesh: Optional[Mesh] = None,
                  rounds: int = 12, seed: int = 0, objective: str = "km1",
                  force_balance: bool = False, axis: str = "nets",
                  sh: Optional[ShardedHypergraph] = None) -> np.ndarray:
    """Distributed k-way LP refinement of a hypergraph partition.

    Never returns a worse feasible objective than the input (the caller's
    better-of-in/out guard, as in refine_hypergraph); ``sh`` accepts a
    cached `ShardedHypergraph`.
    """
    if k <= 1 or hg.n == 0:
        return np.asarray(part, dtype=np.int64)
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis,))
    n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                            if a == axis]))
    rec = obs.current()
    sh = sh if sh is not None else shard_hypergraph(hg, n_shards)
    from repro.core.hypergraph.refine import _caps_for
    cap = jnp.asarray(_caps_for(hg, k, eps), jnp.float32)
    labels0 = np.zeros(sh.n_pad, dtype=np.int32)
    labels0[:hg.n] = part
    with rec.span("parhyp_refine", n=hg.n, rounds=rounds, shards=n_shards):
        out, _ = _parhyp_refine_jit(mesh, jnp.asarray(sh.pv),
                                    jnp.asarray(sh.pe),
                                    jnp.asarray(sh.mask),
                                    jnp.asarray(sh.netw),
                                    jnp.asarray(sh.esize),
                                    jnp.asarray(sh.vwgt),
                                    jnp.asarray(labels0), cap,
                                    jax.random.PRNGKey(seed),
                                    jnp.asarray(force_balance), sh.rows_v, k,
                                    rounds, n_shards, axis, objective)
        out = np.asarray(out, dtype=np.int64)[:hg.n]
    rec.count("parhyp/dist_rounds", rounds)
    # per round: Φ + two gain partials; plus the one-off wtot and final Φ
    rec.count("parhyp/psum_rounds", _PSUMS_PER_ROUND * rounds + 2)
    score = M.connectivity if objective == "km1" else M.cut_net
    if score(hg, out) <= score(hg, part) or force_balance:
        return out
    rec.count("parhyp/rounds_rejected")
    return np.asarray(part, dtype=np.int64)


# ---------------------------------------------------------------------------
# the parhyp program: host-orchestrated multilevel on the shared engine
# ---------------------------------------------------------------------------

PARHYP_PRESETS = {
    "ultrafast": dict(preset="fast", rounds=4),
    "fast":      dict(preset="fast", rounds=8),
    "eco":       dict(preset="eco", rounds=12),
}


def parhyp(hg: Hypergraph, k: int, eps: float = 0.03,
           preconfiguration: str = "fast", seed: int = 0,
           mesh: Optional[Mesh] = None, objective: str = "km1",
           report=None) -> np.ndarray:
    """The ``parhyp`` program: distributed multilevel hypergraph
    partitioning (DESIGN.md §9).

    Host-orchestrated multilevel on the shared engine (hierarchy +
    initial-partition tournament from `HypergraphMedium`), with the
    distributed LP round as the refinement engine at every level and the
    sequential force-balance refiner as the feasibility repair fallback —
    including level 0 of single-level hierarchies (small inputs).
    ``report`` is an optional ``obs.Recorder`` capturing the distributed
    rounds, psum counts and per-level quality (DESIGN.md §11).
    """
    if objective not in ("km1", "cut"):
        raise ValueError(f"unknown objective {objective!r}")
    if k <= 1:
        return np.zeros(hg.n, dtype=np.int64)
    from repro.core import multilevel as ML
    from repro.core.hypergraph.coarsen import project
    from repro.core.hypergraph.driver import PRESETS, HypergraphMedium
    from repro.core.hypergraph.refine import refine_hypergraph
    pc = PARHYP_PRESETS[preconfiguration]
    cfg = PRESETS[pc["preset"]]
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), ("nets",))
    with obs.use(report):
        rec = obs.current()
        with rec.span("parhyp", n=hg.n, k=k,
                      preconfiguration=preconfiguration):
            levels = ML.build_hierarchy(HypergraphMedium(hg, cfg, objective),
                                        k, seed)
            part = ML.initial_partition(levels[-1], k, eps, seed)

            def refine_level(hg_fine: Hypergraph, part: np.ndarray,
                             li: int) -> np.ndarray:
                part = parhyp_refine(hg_fine, part, k, eps, mesh,
                                     rounds=pc["rounds"], seed=seed + li,
                                     objective=objective)
                if not M.is_feasible(hg_fine, part, k, eps):
                    part = refine_hypergraph(hg_fine, part, k, eps, rounds=6,
                                             seed=seed + li,
                                             objective=objective,
                                             force_balance=True)
                    rec.count("parhyp/repairs")
                return part

            score = M.connectivity if objective == "km1" else M.cut_net
            for li in range(len(levels) - 1, 0, -1):
                part = project(part, levels[li].cl)
                fine = levels[li - 1].medium.hg
                with rec.span("parhyp_level", level=li - 1, n=fine.n):
                    part = refine_level(fine, part, li)
                if rec.enabled:
                    rec.point("parhyp", level=li - 1,
                              objective=float(score(fine, part)))
            if len(levels) == 1:
                # single-level hierarchy: the loop above is empty — still
                # refine and repair at level 0 (the parhip bug PR 4 fixed)
                with rec.span("parhyp_level", level=0, n=hg.n):
                    part = refine_level(hg, part, 0)
                if rec.enabled:
                    rec.point("parhyp", level=0,
                              objective=float(score(hg, part)))
    return part
