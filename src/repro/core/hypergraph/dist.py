"""parhyp — distributed-memory multilevel hypergraph partitioning via
shard_map (DESIGN.md §9), the hypergraph sibling of core/parhip.py.

The MPI design of ParHIP carries over to hypergraphs with one twist: the
unit of distribution is the *net*, not the vertex.  Nets (and all their
pins) are block-distributed over the ``nets`` mesh axis as padded per-shard
pin-COO rows; on a 2-D ``(nets, verts)`` mesh each net row is additionally
split by the pin's *vertex column*, so the (n, k) gain/affinity scatters
shrink per device.  Vertex labels stay replicated (the ghost exchange is
the all-gather SPMD partitioning inserts).  Each refinement round:

  1. every shard scatters its local pins into a per-(net, block) pin-count
     partial; the partials ``psum`` over the ``verts`` axis first into the
     net-sharded histogram Φ(e_rows, b), then per-row objectives psum over
     ``nets``;
  2. exact (λ−1) / cut-net move gains are derived from Φ — the per-vertex
     affinity/removal partials are local scatters into the device's vertex
     *column*, psum'd over ``nets`` only (a net's pins for one column all
     live on one device, so its contribution is computed exactly once);
  3. moves are proposed with the same noise/parity split as the sequential
     refiner, and each shard applies capped acceptance on its *owned
     vertex slice* against its share of the psum'd global remaining
     capacity — so the balance constraint holds globally without a
     sequential arbiter (the core/parhip.py recipe).

Coarsening is device-resident too: a distributed LP-clustering round
(deterministic min-label tie-breaks, integer fixed-point ratings so every
psum is order-independent) proposes column-local clusters, and a
contraction step rebuilds the coarser `ShardedHypergraph` in place — same
padded shapes at every level, so the whole hierarchy shares one compiled
program per (cluster, contract, refine) — without a host round-trip.  The
only host pull per level is the scalar coarse-vertex count.

With a 1-device mesh the refinement round is bit-identical to the
sequential COO oracle (`refine._hyper_refine_scan` with
``use_kernel=False``): same pin layout, same RNG stream, same scatter
orders, same capped acceptance — the regression test pins this.  The
cluster/contract bodies double as their own 1-device oracles: calling them
with ``ax_n=ax_v=None`` outside shard_map is the reference the shard_map
plumbing is tested against, and the host `coarsen.contract` is the
objective-preservation oracle for the device contraction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro import obs
from repro.core.csr import _pow2_pad
from repro.core import lp as lp_mod
from repro.core.hypergraph.container import Hypergraph
from repro.core.hypergraph import metrics as M
from repro.core.hypergraph.coarsen import RATING_SCALE

# psums issued per distributed refinement round: the Φ(e,b) histogram plus
# two gain partials (aff/rem for km1, joins/breaks for cut-net)
_PSUMS_PER_ROUND = 3

_NEG = -1e30
_NOISE = 1e-4
_GAIN_EPS = 1e-3
_STALL = 0.95          # stop coarsening when a level shrinks less than this
_POLISH_N = 65536      # sequential polish cutoff on the device path
# Below this size the whole problem goes to the host-orchestrated path, as
# ParHIP gathers a small-enough subproblem onto one PE: data-parallel LP
# clustering pays a few percent cluster impurity that a tiny hierarchy has
# too few levels to refine away, while at scale the loss amortises.
_DEVICE_MIN_N = 8192


# ---------------------------------------------------------------------------
# host container: net/vertex-block-distributed pin COO
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedHypergraph:
    """Host container: nets block-distributed over ``s_nets`` row groups and
    pins additionally split over ``s_verts`` vertex columns; each of the
    ``S = s_nets·s_verts`` shards holds one padded pin-COO row.  Net/vertex
    weight vectors are replicated.

    Shard ``ie·s_verts + jv`` owns the pins of net rows
    [ie·e_rows, (ie+1)·e_rows) whose vertex lies in column
    [jv·n_col, (jv+1)·n_col).  Padding pins are (net ``e_pad-1``, vertex
    ``n_pad-1``, mask 0) on a zero-weight net — the `PinCoo` convention, so
    with one shard the layout is exactly ``to_pincoo``'s (the bit-exactness
    anchor).
    """

    pv: np.ndarray      # (S, p_shard) int32 — pin's vertex (global id)
    pe: np.ndarray      # (S, p_shard) int32 — pin's net (global id)
    mask: np.ndarray    # (S, p_shard) f32   — 1 real, 0 padding
    netw: np.ndarray    # (e_pad,) f32 — net weights, 0 padding (replicated)
    esize: np.ndarray   # (e_pad,) f32 — pin counts, 0 padding (replicated)
    vwgt: np.ndarray    # (n_pad,) f32 — vertex weights, 0 pad (replicated)
    n: int
    m: int
    rows_v: int         # vertices owned per shard (n_pad == S * rows_v)
    s_nets: int = 1     # mesh extent over net rows
    s_verts: int = 1    # mesh extent over vertex columns

    @property
    def n_shards(self) -> int:
        return self.pv.shape[0]

    @property
    def p_shard(self) -> int:
        return self.pv.shape[1]

    @property
    def n_pad(self) -> int:
        return len(self.vwgt)

    @property
    def e_pad(self) -> int:
        return len(self.netw)

    @property
    def n_col(self) -> int:
        """Vertices per column (n_pad == s_verts · n_col)."""
        return self.n_pad // self.s_verts

    @property
    def e_rows(self) -> int:
        """Nets per row group (e_pad == s_nets · e_rows)."""
        return self.e_pad // self.s_nets


def shard_hypergraph(hg: Hypergraph, shards, p_mult: int = 256,
                     n_mult: int = 128, e_mult: int = 128
                     ) -> ShardedHypergraph:
    """Block-distribute ``hg`` over ``shards`` = S (1-D over nets) or
    ``(s_nets, s_verts)`` (2-D): net-row group ie owns the contiguous
    net-id range [ie·e_rows, (ie+1)·e_rows), vertex column jv the vertex
    range [jv·n_col, (jv+1)·n_col); shard ie·s_verts+jv holds their
    intersection's pins in global pin order."""
    if isinstance(shards, tuple):
        s_nets, s_verts = shards
    else:
        s_nets, s_verts = int(shards), 1
    S = s_nets * s_verts
    n, m, p = hg.n, hg.m, hg.pins
    n_pad = _pow2_pad(max(n, 1), n_mult)
    rows_v = -(-n_pad // S)
    n_pad = rows_v * S
    n_col = rows_v * s_nets
    e_pad = _pow2_pad(m + 1, e_mult)
    e_rows = -(-e_pad // s_nets)
    e_pad = e_rows * s_nets
    pe_h = hg.pin_sources()
    owner_e = np.minimum(pe_h // e_rows, s_nets - 1)
    col_v = np.minimum(hg.eind // n_col, s_verts - 1)
    owner = owner_e * s_verts + col_v
    pmax = int(np.bincount(owner, minlength=S).max()) if p else 1
    p_shard = _pow2_pad(max(pmax, 1), p_mult)
    pv = np.full((S, p_shard), n_pad - 1, dtype=np.int32)
    pe = np.full((S, p_shard), e_pad - 1, dtype=np.int32)
    mask = np.zeros((S, p_shard), dtype=np.float32)
    for s in range(S):
        ids = np.flatnonzero(owner == s)
        pv[s, :len(ids)] = hg.eind[ids]
        pe[s, :len(ids)] = pe_h[ids]
        mask[s, :len(ids)] = 1.0
    netw = np.zeros(e_pad, dtype=np.float32)
    netw[:m] = hg.ewgt
    esize = np.zeros(e_pad, dtype=np.float32)
    esize[:m] = hg.net_sizes()
    vwgt = np.zeros(n_pad, dtype=np.float32)
    vwgt[:n] = hg.vwgt
    return ShardedHypergraph(pv=pv, pe=pe, mask=mask, netw=netw,
                             esize=esize, vwgt=vwgt, n=n, m=m, rows_v=rows_v,
                             s_nets=s_nets, s_verts=s_verts)


# ---------------------------------------------------------------------------
# mesh plumbing: axis-optional collectives (ax=None ⇒ 1-extent identity,
# which makes every shard_map body its own sequential oracle)
# ---------------------------------------------------------------------------

def _mesh_axes(mesh: Mesh) -> Tuple[str, Optional[str]]:
    names = tuple(mesh.axis_names)
    if len(names) == 1:
        return names[0], None
    if len(names) == 2:
        return names[0], names[1]
    raise ValueError(f"parhyp mesh must be 1-D (nets) or 2-D (nets, verts); "
                     f"got axes {names}")


def _mesh_extents(mesh: Mesh) -> Tuple[int, int]:
    ax_n, ax_v = _mesh_axes(mesh)
    return mesh.shape[ax_n], (mesh.shape[ax_v] if ax_v else 1)


def _specs(ax_n, ax_v):
    """(pin-block, vertex-vector, replicated) PartitionSpecs for a mesh."""
    if ax_v is None:
        return P(ax_n, None), P(ax_n), P()
    # pins: nets-major over the leading shard dim; vertex vectors: the flat
    # owned block of device (ie, jv) is jv·s_nets + ie, i.e. column-major —
    # so its slice starts at jv·n_col + ie·rows_v
    return P((ax_n, ax_v), None), P((ax_v, ax_n)), P()


def _psum(x, ax):
    return jax.lax.psum(x, ax) if ax is not None else x


def _pmax(x, ax):
    return jax.lax.pmax(x, ax) if ax is not None else x


def _pmin(x, ax):
    return jax.lax.pmin(x, ax) if ax is not None else x


def _idx(ax):
    return jax.lax.axis_index(ax) if ax is not None else 0


# ---------------------------------------------------------------------------
# the distributed refinement round (shard_map body)
# ---------------------------------------------------------------------------

def _dist_obj_local(pv, pe, mask, netw, labels, k: int, e_rows: int,
                    ax_n, ax_v, objective: str):
    """Replicated objective from the verts-psum'd net-sharded Φ partial."""
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    ie = _idx(ax_n)
    pe_loc = jnp.clip(pe - ie * e_rows, 0, e_rows - 1)
    cnt = _psum(jnp.zeros((e_rows, k), jnp.float32).at[
        pe_loc, labels[pv].astype(jnp.int32)].add(mask), ax_v)
    netw_row = jax.lax.dynamic_slice(netw, (ie * e_rows,), (e_rows,))
    obj_fn = M.km1_device if objective == "km1" else M.cut_net_device
    return _psum(obj_fn(cnt, netw_row), ax_n)


def _dist_wtot_local(pv, pe, mask, netw, vwgt, ax_n, ax_v):
    """Per-vertex total incident net weight W(v), psum'd over both axes —
    round-invariant, so it is computed once before the refinement scan."""
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    w_pin = mask * netw[pe]
    n = vwgt.shape[0]
    return _psum(_psum(
        jnp.zeros((n,), jnp.float32).at[pv].add(w_pin), ax_v), ax_n)


def _dist_round_local(pv, pe, mask, netw, esize, vwgt, wtot, labels, sizes,
                      cap, key, parity, force, rows_v: int, n_col: int,
                      e_rows: int, k: int, s_nets: int, s_verts: int,
                      ax_n, ax_v, objective: str):
    """One distributed LP round, run per shard under shard_map.

    ``labels`` is the full replicated vector; pin arrays arrive as (1, ·)
    local blocks.  Φ partials psum over ``verts`` into the net-sharded
    histogram; gain partials are scattered into the device's vertex column
    and psum over ``nets`` only.  Returns (new labels for the owned vertex
    slice, the pre-move objective) — gain math mirrors
    refine._hyper_refine_scan exactly so the 1-shard round is bit-identical
    to the sequential oracle.
    """
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    ie = _idx(ax_n)
    jv = _idx(ax_v)
    me = jv * s_nets + ie
    n_pad = labels.shape[0]
    p_loc = pv.shape[0]
    lab_pin = labels[pv].astype(jnp.int32)
    # clamped local indices: padding pins (mask 0) may clamp anywhere —
    # every use below is mask-weighted (the kernels/ops.py masking contract)
    pe_loc = jnp.clip(pe - ie * e_rows, 0, e_rows - 1)
    pv_loc = jnp.clip(pv - jv * n_col, 0, n_col - 1)
    w_pin = mask * netw[pe]
    cnt = _psum(jnp.zeros((e_rows, k), jnp.float32).at[
        pe_loc, lab_pin].add(mask), ax_v)
    netw_row = jax.lax.dynamic_slice(netw, (ie * e_rows,), (e_rows,))
    obj_fn = M.km1_device if objective == "km1" else M.cut_net_device
    obj = _psum(obj_fn(cnt, netw_row), ax_n)
    # exact move gains from the net-sharded histogram (per-vertex partials
    # from local pins into this device's column, psum'd over nets — each
    # net's pins for one column all live on one device)
    cnt_e = cnt[pe_loc]                                   # (p_loc, k)
    cnt_own = cnt_e[jnp.arange(p_loc), lab_pin]
    wtot_col = jax.lax.dynamic_slice(wtot, (jv * n_col,), (n_col,))
    if objective == "km1":
        pres = (cnt_e > 0).astype(jnp.float32)
        aff = _psum(jnp.zeros((n_col, k), jnp.float32).at[pv_loc].add(
            w_pin[:, None] * pres), ax_n)
        rem = _psum(jnp.zeros((n_col,), jnp.float32).at[pv_loc].add(
            w_pin * (cnt_own == 1)), ax_n)
        gain = rem[:, None] - wtot_col[:, None] + aff
    else:
        makes = (cnt_e == (esize[pe] - 1.0)[:, None])
        joins = _psum(jnp.zeros((n_col, k), jnp.float32).at[pv_loc].add(
            w_pin[:, None] * makes.astype(jnp.float32)), ax_n)
        breaks = _psum(jnp.zeros((n_col,), jnp.float32).at[pv_loc].add(
            w_pin * (cnt_own == esize[pe])), ax_n)
        gain = joins - breaks[:, None]
    # full-width noise sliced to the column: identical values per vertex on
    # every mesh layout (the layout-parity anchor)
    noise = jax.random.uniform(key, (n_pad, k), jnp.float32, 0.0, _NOISE)
    gain = gain + jax.lax.dynamic_slice(noise, (jv * n_col, 0), (n_col, k))
    labels_col = jax.lax.dynamic_slice(labels, (jv * n_col,), (n_col,))
    vw_col = jax.lax.dynamic_slice(vwgt, (jv * n_col,), (n_col,))
    gain = gain.at[jnp.arange(n_col), labels_col].set(_NEG)
    room = sizes[None, :] + vw_col[:, None] <= cap[None, :]
    gain = jnp.where(room, gain, _NEG)
    best_gain = jnp.max(gain, axis=1)
    best_tgt = jnp.argmax(gain, axis=1).astype(labels.dtype)
    want = best_gain > _GAIN_EPS
    over = sizes[labels_col] > cap[labels_col]
    want = want | (jnp.asarray(force)
                   & over & (best_gain > _NEG / 2) & (vw_col > 0))
    node_par = (jv * n_col + jnp.arange(n_col) + parity) % 2 == 0
    want = want & node_par
    proposal = jnp.where(want, best_tgt, labels_col)
    pri = jnp.where(want, best_gain, _NEG)
    # Per-shard capped acceptance on the owned vertex slice against the
    # psum'd global size constraint.  The split of the remaining room is
    # contention-aware: per block, if the global proposed inflow (demand —
    # proposals are nets-replicated, so one verts-psum makes it global)
    # fits the room, every shard may accept (total <= demand <= room);
    # otherwise only a rotating owner shard gets the room (total <= room).
    # Either way the global constraint holds without a sequential arbiter,
    # and an even room/S split — which rounds to zero headroom for
    # unit-weight moves at tight eps — is avoided.  With one shard the
    # owner is always shard 0, so the round stays bit-identical to the
    # sequential oracle.
    vw_mov = jnp.where(proposal != labels_col, vw_col, 0.0)
    demand = _psum(jnp.zeros((k,), jnp.float32).at[proposal].add(vw_mov),
                   ax_v)
    uncontended = demand <= cap - sizes
    owner_b = (jnp.arange(k) + parity) % (s_nets * s_verts) == me
    cap_local = jnp.where(uncontended | owner_b, cap, sizes)
    off = ie * rows_v
    lab_own = jax.lax.dynamic_slice(labels_col, (off,), (rows_v,))
    prop_own = jax.lax.dynamic_slice(proposal, (off,), (rows_v,))
    vw_own = jax.lax.dynamic_slice(vw_col, (off,), (rows_v,))
    pri_own = jax.lax.dynamic_slice(pri, (off,), (rows_v,))
    new_own = lp_mod.capped_accept(lab_own, prop_own, vw_own, sizes,
                                   cap_local, pri_own)
    return new_own, obj


@functools.partial(jax.jit,
                   static_argnames=("rows_v", "n_col", "e_rows", "k",
                                    "rounds", "objective", "mesh"))
def _parhyp_refine_jit(mesh: Mesh, pv, pe, mask, netw, esize, vwgt,
                       labels0, cap, key, force, rows_v: int, n_col: int,
                       e_rows: int, k: int, rounds: int, objective: str):
    ax_n, ax_v = _mesh_axes(mesh)
    s_nets, s_verts = _mesh_extents(mesh)
    spec_p, spec_v, spec_r = _specs(ax_n, ax_v)
    round_fn = shard_map(
        functools.partial(_dist_round_local, rows_v=rows_v, n_col=n_col,
                          e_rows=e_rows, k=k, s_nets=s_nets,
                          s_verts=s_verts, ax_n=ax_n, ax_v=ax_v,
                          objective=objective),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r, spec_r, spec_r,
                  spec_r, spec_r, spec_r, spec_r, spec_r, spec_r),
        out_specs=(spec_v, spec_r),
        check_vma=False,
    )
    obj_sm = shard_map(
        functools.partial(_dist_obj_local, k=k, e_rows=e_rows, ax_n=ax_n,
                          ax_v=ax_v, objective=objective),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r),
        out_specs=spec_r,
        check_vma=False,
    )
    wtot_fn = shard_map(
        functools.partial(_dist_wtot_local, ax_n=ax_n, ax_v=ax_v),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r),
        out_specs=spec_r,
        check_vma=False,
    )
    wtot = wtot_fn(pv, pe, mask, netw, vwgt)

    def body(carry, key_r):
        labels, sizes, best_obj, best_labels, parity = carry
        new_labels, obj = round_fn(pv, pe, mask, netw, esize, vwgt, wtot,
                                   labels, sizes, cap, key_r, parity, force)
        # undo-to-best: track the best feasible pre-move state
        feas = jnp.max(sizes - cap) <= 1e-6
        better = feas & (obj < best_obj)
        best_obj = jnp.where(better, obj, best_obj)
        best_labels = jnp.where(better, labels, best_labels)
        new_sizes = jnp.zeros((k,), jnp.float32).at[new_labels].add(vwgt)
        return (new_labels, new_sizes, best_obj, best_labels,
                parity + 1), obj

    sizes0 = jnp.zeros((k,), jnp.float32).at[labels0].add(vwgt)
    keys = jax.random.split(key, rounds)
    carry0 = (labels0, sizes0, jnp.float32(jnp.inf), labels0, jnp.int32(0))
    (labels, sizes, best_obj, best_labels, _), _ = jax.lax.scan(
        body, carry0, keys)
    # evaluate the final state too
    obj = obj_sm(pv, pe, mask, netw, labels)
    feas = jnp.max(sizes - cap) <= 1e-6
    better = feas & (obj < best_obj)
    best_obj = jnp.where(better, obj, best_obj)
    best_labels = jnp.where(better, labels, best_labels)
    have = jnp.isfinite(best_obj)
    out = jnp.where(have, best_labels, labels)
    out_sizes = jnp.zeros((k,), jnp.float32).at[out].add(vwgt)
    out_feas = jnp.max(out_sizes - cap) <= 1e-6
    return out, best_obj, out_feas


def parhyp_refine(hg: Hypergraph, part: np.ndarray, k: int,
                  eps: float = 0.03, mesh: Optional[Mesh] = None,
                  rounds: int = 12, seed: int = 0, objective: str = "km1",
                  force_balance: bool = False, axis: str = "nets",
                  sh: Optional[ShardedHypergraph] = None) -> np.ndarray:
    """Distributed k-way LP refinement of a hypergraph partition.

    Never returns a worse feasible objective than the input (the caller's
    better-of-in/out guard, as in refine_hypergraph); ``sh`` accepts a
    cached `ShardedHypergraph` matching the mesh layout.
    """
    if k <= 1 or hg.n == 0:
        return np.asarray(part, dtype=np.int64)
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis,))
    s_nets, s_verts = _mesh_extents(mesh)
    rec = obs.current()
    if sh is None or sh.s_nets != s_nets or sh.s_verts != s_verts:
        sh = shard_hypergraph(hg, (s_nets, s_verts))
    from repro.core import multilevel as ML
    from repro.core.hypergraph.refine import _caps_for, _pad_caps, k_bucket
    k_pad = k_bucket(k)
    cap = jnp.asarray(_pad_caps(_caps_for(hg, k, eps), k_pad), jnp.float32)
    labels0 = np.zeros(sh.n_pad, dtype=np.int32)
    labels0[:hg.n] = part
    ML.note_program("parhyp", sh.n_pad, sh.e_pad, sh.p_shard, k_pad,
                    rounds, objective, s_nets, s_verts)
    with rec.span("parhyp_refine", n=hg.n, rounds=rounds,
                  shards=sh.n_shards):
        out, _, _ = _parhyp_refine_jit(mesh, jnp.asarray(sh.pv),
                                       jnp.asarray(sh.pe),
                                       jnp.asarray(sh.mask),
                                       jnp.asarray(sh.netw),
                                       jnp.asarray(sh.esize),
                                       jnp.asarray(sh.vwgt),
                                       jnp.asarray(labels0), cap,
                                       jax.random.PRNGKey(seed),
                                       jnp.asarray(force_balance),
                                       sh.rows_v, sh.n_col, sh.e_rows,
                                       k_pad, rounds, objective)
        out = np.asarray(out, dtype=np.int64)[:hg.n]
    rec.count("parhyp/dist_rounds", rounds)
    # per round: Φ + two gain partials; plus the one-off wtot and final Φ
    rec.count("parhyp/psum_rounds", _PSUMS_PER_ROUND * rounds + 2)
    score = M.connectivity if objective == "km1" else M.cut_net
    if score(hg, out) <= score(hg, part) or force_balance:
        return out
    rec.count("parhyp/rounds_rejected")
    return np.asarray(part, dtype=np.int64)


# ---------------------------------------------------------------------------
# distributed LP-clustering coarsening (shard_map bodies)
# ---------------------------------------------------------------------------

def _cluster_round_local(pv, pe, mask, netw, esize, vwgt, labels, capv,
                         parity, rows_v: int, n_col: int, e_rows: int,
                         s_nets: int, s_verts: int, ax_n, ax_v):
    """One distributed LP-clustering round (per shard under shard_map).

    Affinities use integer fixed-point ratings r(e) = max(1,
    round(SCALE·w/(|e|−1))) computed in place from the replicated net
    vectors — linear in pins (no clique expansion), and integer-valued so
    every cross-device reduction is order-independent (exact).  Per net the
    two most frequent pin labels are found by a run-length lexsort + two
    masked scatter passes; each pin's candidate is the most frequent
    *other* label.  Tie-breaks are deterministic (min label), no RNG.
    Clusters are column-local by construction: candidates come from
    co-pins in the same vertex column, so a cluster never spans columns
    and contraction preserves the 2-D layout.
    """
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    ie = _idx(ax_n)
    jv = _idx(ax_v)
    n_pad = labels.shape[0]
    p_loc = pv.shape[0]
    pe_loc = jnp.clip(pe - ie * e_rows, 0, e_rows - 1)
    pv_loc = jnp.clip(pv - jv * n_col, 0, n_col - 1)
    netw_row = jax.lax.dynamic_slice(netw, (ie * e_rows,), (e_rows,))
    esize_row = jax.lax.dynamic_slice(esize, (ie * e_rows,), (e_rows,))
    rate_row = jnp.where(
        (esize_row >= 2) & (netw_row > 0),
        jnp.maximum(1.0, jnp.round(
            RATING_SCALE * netw_row / jnp.maximum(esize_row - 1.0, 1.0))),
        0.0)
    r_pin = mask * rate_row[pe_loc]
    live = r_pin > 0
    dead = jnp.where(live, 0, 1)
    lab_p = jnp.where(live, labels[pv].astype(jnp.int32), n_pad)
    # pass 1: per-(net, label) run counts → per-net top-2 labels
    order = jnp.lexsort((lab_p, pe_loc, dead))
    pe_s = pe_loc[order]
    lab_s = lab_p[order]
    live_s = live[order]
    newrun = jnp.concatenate(
        [jnp.array([True]),
         (pe_s[1:] != pe_s[:-1]) | (lab_s[1:] != lab_s[:-1])
         | (live_s[1:] != live_s[:-1])])
    seg = jnp.cumsum(newrun) - 1
    rc = jnp.zeros((p_loc,), jnp.float32).at[seg].add(
        live_s.astype(jnp.float32))
    rc_eff = jnp.where(live_s, rc[seg], 0.0)
    t1c = jnp.zeros((e_rows,), jnp.float32).at[pe_s].max(rc_eff)
    is_t1 = live_s & (rc_eff == t1c[pe_s])
    t1l = jnp.full((e_rows,), n_pad, jnp.int32).at[pe_s].min(
        jnp.where(is_t1, lab_s, n_pad))
    not1 = live_s & (lab_s != t1l[pe_s])
    t2c = jnp.zeros((e_rows,), jnp.float32).at[pe_s].max(
        jnp.where(not1, rc_eff, 0.0))
    is_t2 = not1 & (rc_eff == t2c[pe_s])
    t2l = jnp.full((e_rows,), n_pad, jnp.int32).at[pe_s].min(
        jnp.where(is_t2, lab_s, n_pad))
    # back to pin order: own-run count, candidate label + its count
    rc_own = jnp.zeros((p_loc,), jnp.float32).at[order].set(rc_eff)
    own_is_t1 = lab_p == t1l[pe_loc]
    cand = jnp.where(own_is_t1, t2l[pe_loc], t1l[pe_loc])
    ccnt = jnp.where(own_is_t1, t2c[pe_loc], t1c[pe_loc])
    cand = jnp.where(live, cand, n_pad)
    own_aff = jnp.zeros((n_col,), jnp.float32).at[pv_loc].add(
        r_pin * jnp.maximum(rc_own - 1.0, 0.0))
    # pass 2: aggregate candidate affinity per (vertex, candidate)
    has_cand = live & (cand < n_pad)
    dead2 = jnp.where(has_cand, 0, 1)
    order2 = jnp.lexsort((cand, pv_loc, dead2))
    pv2 = pv_loc[order2]
    cand_s = cand[order2]
    live2 = dead2[order2] == 0
    a_pin = jnp.where(has_cand, r_pin * ccnt, 0.0)[order2]
    newrun2 = jnp.concatenate(
        [jnp.array([True]),
         (pv2[1:] != pv2[:-1]) | (cand_s[1:] != cand_s[:-1])
         | (live2[1:] != live2[:-1])])
    seg2 = jnp.cumsum(newrun2) - 1
    aff_run = jnp.zeros((p_loc,), jnp.float32).at[seg2].add(a_pin)[seg2]
    # size-constrained best candidate per vertex, min-label tie-break
    sizes_cl = jnp.zeros((n_pad,), jnp.float32).at[labels].add(vwgt)
    cand_c = jnp.clip(cand_s, 0, n_pad - 1)
    vglob = jv * n_col + pv2
    room = sizes_cl[cand_c] + vwgt[vglob] <= capv[cand_c]
    g = aff_run - own_aff[pv2]
    g_eff = jnp.where(live2 & room, g, _NEG)
    g_v = jnp.full((n_col,), _NEG, jnp.float32).at[pv2].max(g_eff)
    is_best = live2 & (g_eff == g_v[pv2])
    cand_v = jnp.full((n_col,), n_pad, jnp.int32).at[pv2].min(
        jnp.where(is_best, cand_s, n_pad))
    # cross-row combine (exact: affinities are integer-valued f32)
    g2 = _pmax(g_v, ax_n)
    cand2 = _pmin(jnp.where((g_v == g2) & (cand_v < n_pad), cand_v, n_pad),
                  ax_n)
    labels_col = jax.lax.dynamic_slice(labels, (jv * n_col,), (n_col,))
    vw_col = jax.lax.dynamic_slice(vwgt, (jv * n_col,), (n_col,))
    improve = ((g2 > _GAIN_EPS) & (cand2 < n_pad) & (vw_col > 0)
               & (cand2 != labels_col))
    node_par = (jv * n_col + jnp.arange(n_col) + parity) % 2 == 0
    want = improve & node_par
    proposal = jnp.where(want, cand2, labels_col).astype(labels.dtype)
    pri = jnp.where(want, g2, _NEG)
    # contention-aware capped acceptance, as in the refinement round, with
    # per-cluster ownership: a cluster is arbitrated inside its own vertex
    # column by a rotating net-row owner
    vw_mov = jnp.where(proposal != labels_col, vw_col, 0.0)
    demand = _psum(jnp.zeros((n_pad,), jnp.float32).at[proposal].add(vw_mov),
                   ax_v)
    uncontended = demand <= capv - sizes_cl
    cid = jnp.arange(n_pad)
    owner = ((cid + parity) % s_nets == ie) & (cid // n_col == jv)
    cap_local = jnp.where(uncontended | owner, capv, sizes_cl)
    off = ie * rows_v
    lab_own = jax.lax.dynamic_slice(labels_col, (off,), (rows_v,))
    prop_own = jax.lax.dynamic_slice(proposal, (off,), (rows_v,))
    vw_own = jax.lax.dynamic_slice(vw_col, (off,), (rows_v,))
    pri_own = jax.lax.dynamic_slice(pri, (off,), (rows_v,))
    new_own = lp_mod.capped_accept(lab_own, prop_own, vw_own, sizes_cl,
                                   cap_local, pri_own)
    moved = _psum(_psum(
        jnp.sum((new_own != lab_own).astype(jnp.int32)), ax_n), ax_v)
    return new_own, moved


@functools.partial(jax.jit,
                   static_argnames=("rows_v", "n_col", "e_rows", "iters",
                                    "mesh"))
def _parhyp_cluster_jit(mesh: Mesh, pv, pe, mask, netw, esize, vwgt,
                        labels0, capv, parity0, rows_v: int, n_col: int,
                        e_rows: int, iters: int):
    ax_n, ax_v = _mesh_axes(mesh)
    s_nets, s_verts = _mesh_extents(mesh)
    spec_p, spec_v, spec_r = _specs(ax_n, ax_v)
    round_fn = shard_map(
        functools.partial(_cluster_round_local, rows_v=rows_v, n_col=n_col,
                          e_rows=e_rows, s_nets=s_nets, s_verts=s_verts,
                          ax_n=ax_n, ax_v=ax_v),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r, spec_r, spec_r,
                  spec_r, spec_r),
        out_specs=(spec_v, spec_r),
        check_vma=False,
    )

    def body(carry, _):
        labels, parity = carry
        new_labels, moved = round_fn(pv, pe, mask, netw, esize, vwgt,
                                     labels, capv, parity)
        return (new_labels, parity + 1), moved

    (labels, _), moved = jax.lax.scan(body, (labels0, parity0), None,
                                      length=iters)
    return labels, jnp.sum(moved)


def _compact_labels(labels, vwgt, n_col: int):
    """Replicated cluster-id compaction (plain jnp under jit).

    Coarse ids are assigned by a stable sort on (column, non-empty):
    within each vertex column, clusters with positive weight get the low
    contiguous ids — so the coarse level keeps the column structure (the
    recursive 2-D invariant) and the all-padding tail stays at the top.
    """
    n_pad = labels.shape[0]
    cvw_l = jnp.zeros((n_pad,), jnp.float32).at[labels].add(vwgt)
    pr = cvw_l > 0
    col = jnp.arange(n_pad) // n_col
    key = col * (2 * n_col) + jnp.where(pr, 0, n_col)
    perm = jnp.argsort(key, stable=True)
    newid = jnp.zeros((n_pad,), jnp.int32).at[perm].set(
        jnp.arange(n_pad, dtype=jnp.int32))
    coarse_of = newid[labels]
    cvw = jnp.zeros((n_pad,), jnp.float32).at[coarse_of].add(vwgt)
    nc = jnp.sum(pr.astype(jnp.int32))
    return coarse_of, cvw, nc


def _contract_pins_local(pv, pe, mask, netw, coarse_of, n_col: int,
                         e_rows: int, ax_n, ax_v):
    """Sharded pin rebuild for the coarse level (per shard).

    Pins are remapped to coarse vertices, duplicates within a net merged
    by a (net, coarse-vertex) lexsort (dead pins sort last, so live pins'
    positions are padding-inert), and dropped pins turned into sentinel
    padding.  Single-pin and empty nets get weight 0 (parallel nets are
    kept separate — objective-neutral).  Shapes are unchanged, so every
    level shares this one compiled program.
    """
    pv, pe, mask = (a.reshape(-1) for a in (pv, pe, mask))
    ie = _idx(ax_n)
    n_pad = coarse_of.shape[0]
    e_pad = netw.shape[0]
    live = mask > 0
    pvn = jnp.where(live, coarse_of[pv], n_pad - 1)
    pe_loc = jnp.clip(pe - ie * e_rows, 0, e_rows - 1)
    dead = jnp.where(live, 0, 1)
    order = jnp.lexsort((pvn, pe_loc, dead))
    pe_s = pe_loc[order]
    pvn_s = pvn[order]
    live_s = live[order]
    dup = jnp.concatenate(
        [jnp.array([False]),
         (pe_s[1:] == pe_s[:-1]) & (pvn_s[1:] == pvn_s[:-1])
         & live_s[1:] & live_s[:-1]])
    keep = live_s & ~dup
    pv2 = jnp.where(keep, pvn_s, n_pad - 1).astype(jnp.int32)
    pe2 = jnp.where(keep, pe_s + ie * e_rows, e_pad - 1).astype(jnp.int32)
    mask2 = keep.astype(jnp.float32)
    esize_new = _psum(_psum(
        jnp.zeros((e_pad,), jnp.float32).at[pe2].add(mask2), ax_v), ax_n)
    netw2 = jnp.where(esize_new >= 2, netw, 0.0)
    esize2 = jnp.where(netw2 > 0, esize_new, 0.0)
    # every kept pin lives in the dead-last sort's live prefix, so the max
    # per-shard live count bounds the slice the host may compact pins to
    hi = _pmax(_pmax(jnp.sum(live.astype(jnp.int32)), ax_v), ax_n)
    return (pv2.reshape(1, -1), pe2.reshape(1, -1), mask2.reshape(1, -1),
            netw2, esize2, hi)


@functools.partial(jax.jit,
                   static_argnames=("n_col", "e_rows", "mesh"))
def _parhyp_contract_jit(mesh: Mesh, pv, pe, mask, netw, vwgt, labels,
                         n_col: int, e_rows: int):
    ax_n, ax_v = _mesh_axes(mesh)
    spec_p, spec_v, spec_r = _specs(ax_n, ax_v)
    coarse_of, cvw, nc = _compact_labels(labels, vwgt, n_col)
    pins_fn = shard_map(
        functools.partial(_contract_pins_local, n_col=n_col, e_rows=e_rows,
                          ax_n=ax_n, ax_v=ax_v),
        mesh=mesh,
        in_specs=(spec_p, spec_p, spec_p, spec_r, spec_r),
        out_specs=(spec_p, spec_p, spec_p, spec_r, spec_r, spec_r),
        check_vma=False,
    )
    pv2, pe2, mask2, netw2, esize2, hi = pins_fn(pv, pe, mask, netw,
                                                 coarse_of)
    return pv2, pe2, mask2, netw2, esize2, cvw, coarse_of, nc, hi


# ---------------------------------------------------------------------------
# device-resident hierarchy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _DeviceLevel:
    """One hierarchy level held on device (constant shapes at every level)."""
    pv: jax.Array
    pe: jax.Array
    mask: jax.Array
    netw: jax.Array
    esize: jax.Array
    vwgt: jax.Array
    coarse_of: Optional[jax.Array] = None   # fine vertex → coarse id


def _device_hierarchy(sh: ShardedHypergraph, mesh: Mesh, cfg, k: int,
                      seed: int, rec) -> Tuple[List[_DeviceLevel], int]:
    """Coarsen on device until ~stop_n vertices remain (floored so level
    count — and with it the pin memory — stays bounded on million-scale
    inputs).  The only host round-trip per level is a pair of scalars
    (coarse-vertex count + live-pin bound); between levels the pin
    buffers are compacted to the next pow2 bucket of the live-pin bound
    — the dead-last contraction sort leaves every kept pin in a per-shard
    prefix — so level cost shrinks geometrically with the hypergraph
    while compile count stays bounded by the bucket count."""
    from repro.core import multilevel as ML
    stop_n = ML.coarsen_stop_n(cfg, k)
    stop_dev = max(stop_n, min(4096, sh.n // 8))
    levels = [_DeviceLevel(jnp.asarray(sh.pv), jnp.asarray(sh.pe),
                           jnp.asarray(sh.mask), jnp.asarray(sh.netw),
                           jnp.asarray(sh.esize), jnp.asarray(sh.vwgt))]
    total_w = float(np.sum(sh.vwgt))
    max_cw = max(1.0, total_w / (cfg.cluster_weight_factor * k))
    labels0 = jnp.asarray(np.arange(sh.n_pad, dtype=np.int32))
    capv = jnp.asarray(np.full(sh.n_pad, max_cw, np.float32))
    n_cur = sh.n
    lvl = 0
    while n_cur > stop_dev:
        L = levels[-1]
        p_cur = L.pv.shape[1]
        ML.note_program("parhyp_cluster", sh.n_pad, sh.e_pad, p_cur,
                        cfg.lp_iters, sh.s_nets, sh.s_verts)
        ML.note_program("parhyp_contract", sh.n_pad, sh.e_pad, p_cur,
                        sh.s_nets, sh.s_verts)
        with rec.span("parhyp_coarsen", level=lvl, n=n_cur):
            labels, _ = _parhyp_cluster_jit(
                mesh, L.pv, L.pe, L.mask, L.netw, L.esize, L.vwgt,
                labels0, capv, jnp.int32(lvl), sh.rows_v, sh.n_col,
                sh.e_rows, cfg.lp_iters)
            (pv2, pe2, mask2, netw2, esize2, cvw, coarse_of,
             nc, hi) = _parhyp_contract_jit(mesh, L.pv, L.pe, L.mask,
                                            L.netw, L.vwgt, labels,
                                            sh.n_col, sh.e_rows)
            nc_i, hi_i = int(nc), int(hi)
        if nc_i >= n_cur * _STALL:
            break
        p_new = _pow2_pad(max(hi_i, 1), 256)
        if p_new < p_cur:
            pv2, pe2, mask2 = (a[:, :p_new] for a in (pv2, pe2, mask2))
        L.coarse_of = coarse_of
        levels.append(_DeviceLevel(pv2, pe2, mask2, netw2, esize2, cvw))
        n_cur = nc_i
        lvl += 1
    rec.count("parhyp/device_levels", len(levels))
    return levels, n_cur


def _extract_coarsest(L: _DeviceLevel) -> Tuple[Hypergraph, np.ndarray]:
    """Pull the coarsest device level to the host as a `Hypergraph`.

    Returns (hg, ids) where ids[c] is the device vertex id of host vertex
    c — the scatter map that seeds the device uncoarsening from the host
    initial partition."""
    pv = np.asarray(L.pv).reshape(-1)
    pe = np.asarray(L.pe).reshape(-1)
    mask = np.asarray(L.mask).reshape(-1)
    netw = np.asarray(L.netw)
    vwgt = np.asarray(L.vwgt)
    live = (mask > 0) & (netw[pe] > 0)
    real = vwgt > 0
    real[pv[live]] = True
    ids = np.flatnonzero(real)
    remap = np.full(len(vwgt), 0, np.int64)
    remap[ids] = np.arange(len(ids))
    pe_l = pe[live]
    pv_l = remap[pv[live]]
    order = np.argsort(pe_l, kind="stable")
    pe_s, pv_s = pe_l[order], pv_l[order]
    cnt = np.bincount(pe_s, minlength=len(netw))
    keepnet = (cnt >= 2) & (netw > 0)
    keep_pin = keepnet[pe_s]
    pv_s = pv_s[keep_pin]
    nid = np.flatnonzero(keepnet)
    eptr = np.concatenate([[0], np.cumsum(cnt[nid])]).astype(np.int64)
    hg = Hypergraph.from_arrays(len(ids), eptr, pv_s,
                                ewgt=netw[nid].astype(np.int64),
                                vwgt=np.maximum(vwgt[ids], 1).astype(
                                    np.int64))
    return hg, ids


# ---------------------------------------------------------------------------
# the parhyp program
# ---------------------------------------------------------------------------

PARHYP_PRESETS = {
    "ultrafast": dict(preset="fast", rounds=4),
    "fast":      dict(preset="fast", rounds=8),
    "eco":       dict(preset="eco", rounds=12),
}


def _parhyp_host(hg: Hypergraph, k: int, eps: float, cfg, rounds: int,
                 seed: int, mesh: Mesh, objective: str, rec) -> np.ndarray:
    """Host-orchestrated multilevel fallback (small inputs / stalled
    coarsening): hierarchy + initial-partition tournament from
    `HypergraphMedium`, the distributed LP round as the refinement engine
    at every level, the sequential force-balance refiner as the repair."""
    from repro.core import multilevel as ML
    from repro.core.hypergraph.coarsen import project
    from repro.core.hypergraph.driver import HypergraphMedium
    from repro.core.hypergraph.refine import refine_hypergraph
    levels = ML.build_hierarchy(HypergraphMedium(hg, cfg, objective),
                                k, seed)
    part = ML.initial_partition(levels[-1], k, eps, seed)

    def refine_level(hg_fine: Hypergraph, part: np.ndarray,
                     li: int) -> np.ndarray:
        part = parhyp_refine(hg_fine, part, k, eps, mesh, rounds=rounds,
                             seed=seed + li, objective=objective)
        if not M.is_feasible(hg_fine, part, k, eps):
            part = refine_hypergraph(hg_fine, part, k, eps, rounds=6,
                                     seed=seed + li, objective=objective,
                                     force_balance=True)
            rec.count("parhyp/repairs")
        return part

    score = M.connectivity if objective == "km1" else M.cut_net
    for li in range(len(levels) - 1, 0, -1):
        part = project(part, levels[li].cl)
        fine = levels[li - 1].medium.hg
        with rec.span("parhyp_level", level=li - 1, n=fine.n):
            part = refine_level(fine, part, li)
        if rec.enabled:
            rec.point("parhyp", level=li - 1,
                      objective=float(score(fine, part)))
    if len(levels) == 1:
        # single-level hierarchy: the loop above is empty — still refine
        # and repair at level 0 (the parhip bug PR 4 fixed)
        with rec.span("parhyp_level", level=0, n=hg.n):
            part = refine_level(hg, part, 0)
        if rec.enabled:
            rec.point("parhyp", level=0, objective=float(score(hg, part)))
    return part


def _parhyp_device(hg: Hypergraph, k: int, eps: float, cfg, rounds: int,
                   seed: int, mesh: Mesh, objective: str,
                   rec) -> Optional[np.ndarray]:
    """Device-resident V-cycle: coarsen → (host) initial partition on the
    coarsest → uncoarsen-refine, all level state staying on device.

    Returns None when coarsening stalls immediately (the caller falls back
    to the host-orchestrated path)."""
    from repro.core import multilevel as ML
    from repro.core.hypergraph.driver import HypergraphMedium
    from repro.core.hypergraph.refine import (_caps_for, _pad_caps,
                                              k_bucket, refine_hypergraph)
    s_nets, s_verts = _mesh_extents(mesh)
    sh = shard_hypergraph(hg, (s_nets, s_verts))
    levels, n_coarse = _device_hierarchy(sh, mesh, cfg, k, seed, rec)
    if len(levels) == 1:
        return None
    hg_c, ids = _extract_coarsest(levels[-1])
    with rec.span("parhyp_initial", n=hg_c.n, k=k):
        part_c = ML.multilevel(HypergraphMedium(hg_c, cfg, objective),
                               k, eps, seed)
    k_pad = k_bucket(k)
    cap = jnp.asarray(_pad_caps(_caps_for(hg, k, eps), k_pad), jnp.float32)
    lab_h = np.zeros(sh.n_pad, dtype=np.int32)
    lab_h[ids] = part_c
    labels = jnp.asarray(lab_h)
    score = M.connectivity if objective == "km1" else M.cut_net
    for li in range(len(levels) - 2, -1, -1):
        L = levels[li]
        ML.note_program("parhyp", sh.n_pad, sh.e_pad, L.pv.shape[1],
                        k_pad, rounds, objective, s_nets, s_verts)
        labels = jnp.take(labels, L.coarse_of)
        with rec.span("parhyp_level", level=li):
            out, obj, feas = _parhyp_refine_jit(
                mesh, L.pv, L.pe, L.mask, L.netw, L.esize, L.vwgt,
                labels, cap, jax.random.PRNGKey(seed + li),
                jnp.asarray(False), sh.rows_v, sh.n_col, sh.e_rows,
                k_pad, rounds, objective)
            rec.count("parhyp/dist_rounds", rounds)
            rec.count("parhyp/psum_rounds", _PSUMS_PER_ROUND * rounds + 2)
            if not bool(feas):
                # forced-balance repair on the SAME device level views —
                # no re-sharding from the host container
                out, obj, feas = _parhyp_refine_jit(
                    mesh, L.pv, L.pe, L.mask, L.netw, L.esize, L.vwgt,
                    out, cap, jax.random.PRNGKey(seed + li + 7919),
                    jnp.asarray(True), sh.rows_v, sh.n_col, sh.e_rows,
                    k_pad, rounds, objective)
                rec.count("parhyp/repairs")
        labels = out
        if rec.enabled:
            rec.point("parhyp", level=li, objective=float(obj))
    part = np.asarray(labels, dtype=np.int64)[:hg.n]
    if not M.is_feasible(hg, part, k, eps):
        # last-resort host repair (forced balance never worsens feasibly)
        part = refine_hypergraph(hg, part, k, eps, rounds=6, seed=seed,
                                 objective=objective, force_balance=True)
        rec.count("parhyp/repairs")
    elif hg.n <= _POLISH_N:
        # small instances: one sequential polish pass (never-worse guard
        # inside) — quality insurance where its cost is negligible
        part = refine_hypergraph(hg, part, k, eps, rounds=6, seed=seed,
                                 objective=objective)
    if rec.enabled:
        rec.point("parhyp", level=0, objective=float(score(hg, part)))
    return part


def parhyp(hg: Hypergraph, k: int, eps: float = 0.03,
           preconfiguration: str = "fast", seed: int = 0,
           mesh: Optional[Mesh] = None, objective: str = "km1",
           report=None, device_min_n: Optional[int] = None) -> np.ndarray:
    """The ``parhyp`` program: distributed multilevel hypergraph
    partitioning (DESIGN.md §9).

    Device-resident V-cycle (distributed LP-clustering coarsening, host
    initial partition on the coarsest level only, distributed LP
    uncoarsening-refinement) for inputs above ``device_min_n`` (default
    ``_DEVICE_MIN_N``, the ParHIP gather-to-one-PE floor); the
    host-orchestrated multilevel on the shared engine remains the path
    for small inputs and the fallback for stalled coarsening.
    ``report`` is an optional ``obs.Recorder`` capturing the distributed
    rounds, psum counts, coarsening spans and per-level quality
    (DESIGN.md §11).
    """
    if objective not in ("km1", "cut"):
        raise ValueError(f"unknown objective {objective!r}")
    if k <= 1:
        return np.zeros(hg.n, dtype=np.int64)
    from repro.core import multilevel as ML
    from repro.core.hypergraph.driver import PRESETS
    pc = PARHYP_PRESETS[preconfiguration]
    cfg = PRESETS[pc["preset"]]
    rounds = pc["rounds"]
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), ("nets",))
    with obs.use(report):
        rec = obs.current()
        with rec.span("parhyp", n=hg.n, k=k,
                      preconfiguration=preconfiguration):
            part = None
            min_n = _DEVICE_MIN_N if device_min_n is None else device_min_n
            if hg.n > max(ML.coarsen_stop_n(cfg, k), min_n):
                part = _parhyp_device(hg, k, eps, cfg, rounds, seed, mesh,
                                      objective, rec)
            if part is None:
                part = _parhyp_host(hg, k, eps, cfg, rounds, seed, mesh,
                                    objective, rec)
    return part
