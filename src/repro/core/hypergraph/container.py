"""Hypergraph containers — the dual-CSR layout KaHyPar-style partitioners use.

Host side: `Hypergraph` keeps BOTH incidence directions so every phase has
the traversal it needs without rebuilding:
  * vertex → incident nets:  ``vind`` (offsets) / ``vedges`` (net ids)
  * net    → pins:           ``eptr`` (offsets) / ``eind``  (vertex ids)
plus vertex weights ``vwgt`` and net weights ``ewgt``.  All irregular
preprocessing (IO, contraction bookkeeping, validation) happens here in
numpy, mirroring ``csr.Graph``.

Device side: two rectangular views suitable for TPU:
  * `EllHypergraph` — padded ELL over BOTH sides: ``vnets`` (n_pad, dvmax)
    incident-net ids per vertex, and ``pins`` (e_pad, pmax) pin ids per net
    with a validity ``pin_mask``.  This is the layout the Pallas pin-affinity
    kernel consumes (128-net-row tiles).
  * `PinCoo` — padded COO over pins for segment-op algorithms (LP
    refinement oracle, gain computation, objectives).

Padding conventions: ``e_pad > m`` always, so net row ``e_pad - 1`` is a
genuine padding net (``netw == 0``) and can serve as the ELL sentinel for
``vnets``; padding pins carry ``pin_mask == 0`` / ``w == 0`` and point at
vertex ``n_pad - 1``, contributing nothing to any reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.csr import GraphFormatError, _as1d, _pow2_pad


class HypergraphFormatError(GraphFormatError):
    """Raised by the hypergraph checker for malformed hypergraphs."""


@dataclasses.dataclass
class Hypergraph:
    """Host dual-CSR hypergraph."""

    vind: np.ndarray    # (n+1,) int64, offsets into vedges
    vedges: np.ndarray  # (p,)   int64, incident net ids per vertex
    eptr: np.ndarray    # (m+1,) int64, offsets into eind
    eind: np.ndarray    # (p,)   int64, pin vertex ids per net
    vwgt: np.ndarray    # (n,)   int64, vertex weights (>= 0)
    ewgt: np.ndarray    # (m,)   int64, net weights (> 0)

    def __post_init__(self):
        self.vind = _as1d(self.vind, np.int64)
        self.vedges = _as1d(self.vedges, np.int64)
        self.eptr = _as1d(self.eptr, np.int64)
        self.eind = _as1d(self.eind, np.int64)
        self.vwgt = _as1d(self.vwgt, np.int64)
        self.ewgt = _as1d(self.ewgt, np.int64)

    # -- basic accessors ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.vind) - 1

    @property
    def m(self) -> int:
        """Number of nets (hyperedges)."""
        return len(self.eptr) - 1

    @property
    def pins(self) -> int:
        return len(self.eind)

    def net_sizes(self) -> np.ndarray:
        return np.diff(self.eptr)

    def vertex_degrees(self) -> np.ndarray:
        return np.diff(self.vind)

    def net_pins(self, e: int) -> np.ndarray:
        return self.eind[self.eptr[e]:self.eptr[e + 1]]

    def incident_nets(self, v: int) -> np.ndarray:
        return self.vedges[self.vind[v]:self.vind[v + 1]]

    def total_vwgt(self) -> int:
        return int(self.vwgt.sum())

    def total_ewgt(self) -> int:
        return int(self.ewgt.sum())

    def pin_sources(self) -> np.ndarray:
        """Net id of each pin slot of ``eind`` (CSR row expansion)."""
        return np.repeat(np.arange(self.m, dtype=np.int64),
                         np.diff(self.eptr))

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_nets(n: int, nets: Sequence[Sequence[int]],
                  ewgt: Optional[Sequence[int]] = None,
                  vwgt: Optional[Sequence[int]] = None,
                  dedup_pins: bool = True) -> "Hypergraph":
        """Build from a list of pin lists; the vertex side is derived.

        Duplicate pins within a net are merged when ``dedup_pins`` (the
        hypergraph checker rejects them otherwise).
        """
        eptr = [0]
        eind: list = []
        for pins in nets:
            pins = np.asarray(pins, dtype=np.int64)
            if dedup_pins:
                pins = np.unique(pins)
            eind.extend(pins.tolist())
            eptr.append(len(eind))
        m = len(nets)
        ew = np.ones(m, dtype=np.int64) if ewgt is None \
            else _as1d(ewgt, np.int64)
        vw = np.ones(n, dtype=np.int64) if vwgt is None \
            else _as1d(vwgt, np.int64)
        eptr_a = np.asarray(eptr, dtype=np.int64)
        eind_a = np.asarray(eind, dtype=np.int64)
        vind, vedges = _dual_from_nets(n, eptr_a, eind_a)
        return Hypergraph(vind=vind, vedges=vedges, eptr=eptr_a,
                          eind=eind_a, vwgt=vw, ewgt=ew)

    @staticmethod
    def from_arrays(n: int, eptr, eind, ewgt=None, vwgt=None) -> "Hypergraph":
        """Build from the hMETIS-style (eptr, eind) arrays alone."""
        eptr = _as1d(eptr, np.int64)
        eind = _as1d(eind, np.int64)
        m = len(eptr) - 1
        ew = np.ones(m, dtype=np.int64) if ewgt is None \
            else _as1d(ewgt, np.int64)
        vw = np.ones(n, dtype=np.int64) if vwgt is None \
            else _as1d(vwgt, np.int64)
        vind, vedges = _dual_from_nets(n, eptr, eind)
        return Hypergraph(vind=vind, vedges=vedges, eptr=eptr, eind=eind,
                          vwgt=vw, ewgt=ew)

    @staticmethod
    def from_coactivation(counts: np.ndarray,
                          load: Optional[np.ndarray] = None,
                          sets: Optional[dict] = None,
                          min_weight: float = 0.5) -> "Hypergraph":
        """Snapshot constructor for observed-traffic hypergraphs
        (``obs.live.TrafficAccumulator``, DESIGN.md §13).

        ``counts`` is an (n, n) co-activation weight matrix (only the
        strict upper triangle of ``counts`` is read — symmetrise first if
        both directions carry weight): every entry ≥ ``min_weight``
        becomes a 2-pin net with the rounded weight.  ``sets`` optionally
        maps pin tuples (KV co-access sets, cardinality ≥ 2) to weights,
        appended as genuine multi-pin nets.  ``load`` becomes the vertex
        weights (rounded, floored at 1) so (λ−1) partitioning balances
        observed item load while minimising replication traffic.
        """
        counts = np.asarray(counts, dtype=np.float64)
        n = counts.shape[0]
        u, v = np.triu_indices(n, 1)
        w = counts[u, v]
        keep = w >= min_weight
        u, v, w = u[keep], v[keep], np.rint(w[keep]).astype(np.int64)
        pins = np.empty(2 * len(u), dtype=np.int64)
        pins[0::2], pins[1::2] = u, v
        eptr = np.arange(0, 2 * len(u) + 1, 2, dtype=np.int64).tolist()
        eind = pins.tolist()
        ewgt = np.maximum(w, 1).tolist()
        if sets:
            for key in sorted(sets):
                sw = sets[key]
                if len(key) < 2 or sw < min_weight:
                    continue
                eind.extend(int(x) for x in key)
                eptr.append(len(eind))
                ewgt.append(max(int(round(sw)), 1))
        vwgt = None
        if load is not None:
            vwgt = np.maximum(np.rint(np.asarray(load)), 1).astype(np.int64)
        return Hypergraph.from_arrays(n, np.asarray(eptr, dtype=np.int64),
                                      np.asarray(eind, dtype=np.int64),
                                      ewgt=np.asarray(ewgt, dtype=np.int64),
                                      vwgt=vwgt)

    # -- checker -----------------------------------------------------------
    def check(self, raise_on_error: bool = True) -> list:
        """Validate all structural invariants (mirrors ``Graph.check``)."""
        errs = []
        n, m = self.n, self.m
        if self.eptr[0] != 0 or self.eptr[-1] != len(self.eind):
            errs.append("eptr endpoints inconsistent with eind length")
        if np.any(np.diff(self.eptr) < 0):
            errs.append("eptr not monotone")
        if self.vind[0] != 0 or self.vind[-1] != len(self.vedges):
            errs.append("vind endpoints inconsistent with vedges length")
        if np.any(np.diff(self.vind) < 0):
            errs.append("vind not monotone")
        if len(self.eind) and (self.eind.min() < 0 or self.eind.max() >= n):
            errs.append("pin vertex id out of range")
        if len(self.vedges) and (self.vedges.min() < 0
                                 or self.vedges.max() >= m):
            errs.append("incident net id out of range")
        if len(self.vwgt) != n:
            errs.append("vwgt length mismatch")
        if np.any(self.vwgt < 0):
            errs.append("negative vertex weight")
        if len(self.ewgt) != m:
            errs.append("ewgt length mismatch")
        if len(self.ewgt) and np.any(self.ewgt <= 0):
            errs.append("non-positive net weight")
        if not errs:
            pe = self.pin_sources()
            key = pe * np.int64(n) + self.eind
            skey = np.sort(key)
            if len(skey) > 1 and np.any(skey[1:] == skey[:-1]):
                errs.append("duplicate pin within a net")
            # dual consistency: (v, e) incidences must match on both sides
            pv = np.repeat(np.arange(n, dtype=np.int64),
                           np.diff(self.vind))
            vkey = self.vedges * np.int64(n) + pv
            if len(vkey) != len(key) or not np.array_equal(
                    np.sort(vkey), skey):
                errs.append("vertex-side and net-side incidences disagree")
        if errs and raise_on_error:
            raise HypergraphFormatError("; ".join(errs))
        return errs

    def is_unit_weighted(self) -> bool:
        return bool(np.all(self.vwgt == 1) and np.all(self.ewgt == 1))


def _dual_from_nets(n: int, eptr: np.ndarray, eind: np.ndarray):
    """Derive (vind, vedges) from (eptr, eind) by counting sort over pins."""
    if len(eind) and (eind.min() < 0 or eind.max() >= n):
        raise HypergraphFormatError("pin vertex id out of range")
    m = len(eptr) - 1
    pe = np.repeat(np.arange(m, dtype=np.int64), np.diff(eptr))
    order = np.argsort(eind * np.int64(max(m, 1)) + pe, kind="stable")
    vind = np.zeros(n + 1, dtype=np.int64)
    np.add.at(vind, eind + 1, 1)
    vind = np.cumsum(vind)
    return vind, pe[order]


# ---------------------------------------------------------------------------
# Device views
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EllHypergraph:
    """Padded ELL device hypergraph (both incidence directions).

    ``vnets`` padding slots point at net row ``e_pad - 1`` which always has
    ``netw == 0`` (``e_pad > m`` is guaranteed), so gathered scores vanish.
    ``pins`` padding slots carry ``pin_mask == 0``.
    """

    vnets: jax.Array     # (n_pad, dvmax) int32 — incident nets per vertex
    pins: jax.Array      # (e_pad, pmax)  int32 — pin ids per net
    pin_mask: jax.Array  # (e_pad, pmax)  f32   — 1 on real pins, 0 padding
    netw: jax.Array      # (e_pad,)       f32   — net weights, 0 padding
    vwgt: jax.Array      # (n_pad,)       f32   — vertex weights, 0 padding

    @property
    def n_pad(self) -> int:
        return self.vnets.shape[0]

    @property
    def e_pad(self) -> int:
        return self.pins.shape[0]

    @property
    def pmax(self) -> int:
        return self.pins.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PinCoo:
    """Padded pin list.  Padding pins are (net e_pad-1, vertex n_pad-1,
    mask 0) on a zero-weight net — invisible to every reduction."""

    pv: jax.Array       # (p_pad,) int32 — pin's vertex
    pe: jax.Array       # (p_pad,) int32 — pin's net
    mask: jax.Array     # (p_pad,) f32   — 1 real, 0 padding
    netw: jax.Array     # (e_pad,) f32   — net weights, 0 padding
    esize: jax.Array    # (e_pad,) f32   — pin counts, 0 padding
    vwgt: jax.Array     # (n_pad,) f32   — vertex weights, 0 padding

    @property
    def p_pad(self) -> int:
        return self.pv.shape[0]

    @property
    def e_pad(self) -> int:
        return self.netw.shape[0]

    @property
    def n_pad(self) -> int:
        return self.vwgt.shape[0]


def to_ell_h(hg: Hypergraph, row_tile: int = 128, p_mult: int = 8,
             d_mult: int = 8) -> EllHypergraph:
    """Dual CSR → padded ELL views with pow2 shape bucketing.

    ``e_pad`` is padded past ``m`` so the last net row is always a padding
    net — the safe sentinel target for ``vnets`` padding slots.
    """
    n, m = hg.n, hg.m
    n_pad = _pow2_pad(max(n, 1), row_tile)
    e_pad = _pow2_pad(m + 1, row_tile)
    # net → pins side
    esz = hg.net_sizes()
    pmax = int(esz.max()) if m else 0
    # pow2-bucketed like every other device dim (DESIGN.md §12)
    pmax = _pow2_pad(max(pmax, 1), p_mult)
    pins = np.full((e_pad, pmax), n_pad - 1, dtype=np.int32)
    mask = np.zeros((e_pad, pmax), dtype=np.float32)
    pe = hg.pin_sources()
    rank = np.arange(len(pe)) - hg.eptr[pe]
    pins[pe, rank] = hg.eind
    mask[pe, rank] = 1.0
    netw = np.zeros(e_pad, dtype=np.float32)
    netw[:m] = hg.ewgt
    # vertex → nets side
    deg = hg.vertex_degrees()
    dvmax = int(deg.max()) if n else 0
    dvmax = _pow2_pad(max(dvmax, 1), d_mult)
    vnets = np.full((n_pad, dvmax), e_pad - 1, dtype=np.int32)
    pv = np.repeat(np.arange(n, dtype=np.int64), deg)
    vrank = np.arange(len(pv)) - hg.vind[pv]
    vnets[pv, vrank] = hg.vedges
    vw = np.zeros(n_pad, dtype=np.float32)
    vw[:n] = hg.vwgt
    return EllHypergraph(vnets=jnp.asarray(vnets), pins=jnp.asarray(pins),
                         pin_mask=jnp.asarray(mask), netw=jnp.asarray(netw),
                         vwgt=jnp.asarray(vw))


def to_pincoo(hg: Hypergraph, p_mult: int = 256, n_mult: int = 128,
              e_mult: int = 128) -> PinCoo:
    """Dual CSR → padded pin COO with pow2 shape bucketing."""
    n, m, p = hg.n, hg.m, hg.pins
    p_pad = _pow2_pad(max(p, 1), p_mult)
    n_pad = _pow2_pad(max(n, 1), n_mult)
    e_pad = _pow2_pad(m + 1, e_mult)
    pv = np.full(p_pad, n_pad - 1, dtype=np.int32)
    pe = np.full(p_pad, e_pad - 1, dtype=np.int32)
    mask = np.zeros(p_pad, dtype=np.float32)
    pv[:p] = hg.eind
    pe[:p] = hg.pin_sources()
    mask[:p] = 1.0
    netw = np.zeros(e_pad, dtype=np.float32)
    netw[:m] = hg.ewgt
    esize = np.zeros(e_pad, dtype=np.float32)
    esize[:m] = hg.net_sizes()
    vw = np.zeros(n_pad, dtype=np.float32)
    vw[:n] = hg.vwgt
    return PinCoo(pv=jnp.asarray(pv), pe=jnp.asarray(pe),
                  mask=jnp.asarray(mask), netw=jnp.asarray(netw),
                  esize=jnp.asarray(esize), vwgt=jnp.asarray(vw))
