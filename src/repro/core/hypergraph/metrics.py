"""Hypergraph partition metrics — cut-net, connectivity (λ−1), balance.

Objectives (KaHyPar line of work):
  * cut-net        Σ_{e cut} w(e)                    (net spans ≥ 2 blocks)
  * connectivity   Σ_e w(e)·(λ(e) − 1)               (λ = #blocks e touches)
  * balance        max_i c(V_i) / ⌈c(V)/k⌉  must be ≤ 1+ε

Both host (numpy) and device (jnp, jit-safe) versions are provided; the
device versions operate on the padded (e_pad, k) pin-count matrix that the
refinement loop already materialises each round.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.hypergraph.container import Hypergraph, PinCoo


# -- host ---------------------------------------------------------------------

def net_lambdas(hg: Hypergraph, part: np.ndarray) -> np.ndarray:
    """λ(e) = number of distinct blocks net e touches.  (m,)"""
    part = np.asarray(part, dtype=np.int64)
    pe = hg.pin_sources()
    k = int(part.max()) + 1 if len(part) else 1
    key = np.unique(pe * np.int64(k) + part[hg.eind])
    lam = np.zeros(hg.m, dtype=np.int64)
    np.add.at(lam, key // k, 1)
    return lam


def cut_net(hg: Hypergraph, part: np.ndarray) -> int:
    lam = net_lambdas(hg, part)
    return int(hg.ewgt[lam >= 2].sum())


def connectivity(hg: Hypergraph, part: np.ndarray) -> int:
    """The (λ−1) objective — communication volume of the data placement."""
    lam = net_lambdas(hg, part)
    return int((hg.ewgt * np.maximum(lam - 1, 0)).sum())


def block_weights(hg: Hypergraph, part: np.ndarray, k: int) -> np.ndarray:
    bw = np.zeros(k, dtype=np.int64)
    np.add.at(bw, np.asarray(part, dtype=np.int64), hg.vwgt)
    return bw


def balance(hg: Hypergraph, part: np.ndarray, k: int) -> float:
    bw = block_weights(hg, part, k)
    lmax = int(np.ceil(hg.total_vwgt() / k))
    return float(bw.max()) / max(lmax, 1)


def is_feasible(hg: Hypergraph, part: np.ndarray, k: int,
                eps: float) -> bool:
    return balance(hg, part, k) <= 1.0 + eps + 1e-9


def evaluate(hg: Hypergraph, part: np.ndarray, k: int,
             eps: float = 0.03) -> dict:
    """The evaluator report for hypergraph partitions."""
    bw = block_weights(hg, part, k)
    return {
        "k": k,
        "cut_net": cut_net(hg, part),
        "km1": connectivity(hg, part),
        "balance": balance(hg, part, k),
        "feasible": is_feasible(hg, part, k, eps),
        "max_block": int(bw.max()),
        "min_block": int(bw.min()),
    }


# -- device -------------------------------------------------------------------

def pin_counts_device(hc: PinCoo, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    """cnt[e, b] = #pins of net e with label b.  (e_pad, k), jit-safe."""
    return jnp.zeros((hc.e_pad, k), jnp.float32).at[
        hc.pe, labels[hc.pv]].add(hc.mask)


def km1_device(cnt: jnp.ndarray, netw: jnp.ndarray) -> jnp.ndarray:
    """Σ w(e)·(λ(e)−1) from pin counts; padding nets carry netw == 0."""
    lam = jnp.sum((cnt > 0).astype(jnp.float32), axis=1)
    return jnp.sum(netw * jnp.maximum(lam - 1.0, 0.0))


def cut_net_device(cnt: jnp.ndarray, netw: jnp.ndarray) -> jnp.ndarray:
    lam = jnp.sum((cnt > 0).astype(jnp.float32), axis=1)
    return jnp.sum(jnp.where(lam >= 2.0, netw, 0.0))
