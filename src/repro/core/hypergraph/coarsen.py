"""Hypergraph coarsening: LP clustering over the clique-expansion rating
graph + contraction of both CSR sides.

Clustering reuses the device LP machinery (core/lp.py) on a derived
pairwise-rating graph: r(u, v) = Σ_{e ⊇ {u,v}} w(e) / (|e| − 1) — the
heavy-edge rating the KaHyPar line uses.  Nets above ``max_net_size`` fall
back to a star expansion (hub = first pin, one rating edge per remaining
pin) instead of the full clique: linear cost instead of quadratic, but the
net still contributes clustering signal rather than being skipped outright
(ROADMAP large-net handling).

Contraction maps pins through the cluster map, dedups pins within each net,
drops single-pin nets (λ−1 ≡ 0) and merges parallel nets (identical pin
sets) by summing weights — so for any partition constant on clusters both
objectives are preserved exactly.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.csr import Graph
from repro.core import lp as lp_mod
from repro.core.hypergraph.container import Hypergraph

RATING_SCALE = 16          # fixed-point scale for w(e)/(|e|-1) int ratings


def clique_expansion(hg: Hypergraph, max_net_size: int = 64,
                     scale: int = RATING_SCALE,
                     large_net_fallback: bool = True) -> Graph:
    """Pairwise heavy-edge rating graph (integer weights, ×``scale``).

    Nets with more than ``max_net_size`` pins are star-expanded around
    their first pin (linear #edges) when ``large_net_fallback``; with the
    fallback off they are skipped entirely (the pre-PR-2 behaviour).
    """
    us, vs, ws = [], [], []
    esz = hg.net_sizes()
    for e in range(hg.m):
        sz = int(esz[e])
        if sz < 2:
            continue
        pins = hg.net_pins(e)
        r = max(1, int(round(scale * int(hg.ewgt[e]) / (sz - 1))))
        if sz > max_net_size:
            if not large_net_fallback:
                continue
            # star fallback: hub = first pin, one edge per remaining pin
            us.append(np.full(sz - 1, pins[0], dtype=np.int64))
            vs.append(pins[1:])
            ws.append(np.full(sz - 1, r, dtype=np.int64))
            continue
        iu, iv = np.triu_indices(sz, k=1)
        us.append(pins[iu]); vs.append(pins[iv])
        ws.append(np.full(len(iu), r, dtype=np.int64))
    if not us:
        return Graph.from_edges(hg.n, [], [], vwgt=hg.vwgt)
    return Graph.from_edges(hg.n, np.concatenate(us), np.concatenate(vs),
                            np.concatenate(ws), vwgt=hg.vwgt, dedup=True)


def star_expansion(hg: Hypergraph) -> Graph:
    """Exact star expansion: one zero-weight auxiliary vertex per net,
    edges (pin, net-vertex) with the net's weight.  Partitioning this graph
    with a graph partitioner is the classical hypergraph baseline; original
    vertices are ids [0, n)."""
    pe = hg.pin_sources()
    u = hg.eind
    v = hg.n + pe
    w = hg.ewgt[pe]
    vwgt = np.concatenate([hg.vwgt, np.zeros(hg.m, dtype=np.int64)])
    return Graph.from_edges(hg.n + hg.m, u, v, w, vwgt=vwgt, dedup=True)


def lp_clustering(hg: Hypergraph, max_cluster_weight: float,
                  iters: int = 8, seed: int = 0,
                  max_net_size: int = 64,
                  protect=None) -> np.ndarray:
    """Size-constrained LP clustering on the clique-expansion rating.

    ``protect`` is an optional sequence of partitions whose cuts must not
    be contracted (V-cycle / combine re-coarsening): rating edges crossing
    any protected cut are zeroed so the LP avoids them; the engine's
    signature split removes any residual violation.
    """
    g = clique_expansion(hg, max_net_size=max_net_size)
    if len(g.adjncy) == 0:
        return np.arange(hg.n, dtype=np.int64)
    if protect:
        from repro.core.multilevel import protect_cut_mask
        cross = protect_cut_mask(g.edge_sources(), g.adjncy, protect)
        g = Graph(g.xadj, g.adjncy, g.vwgt,
                  np.where(cross, 0, g.adjwgt).astype(np.int64))
    return lp_mod.size_constrained_lp(g, max_cluster_weight, iters=iters,
                                      seed=seed)


def contract(hg: Hypergraph, clusters: np.ndarray):
    """Contract clusters; returns (coarse hypergraph, vertex→coarse map)."""
    clusters = np.asarray(clusters, dtype=np.int64)
    uniq, cl = np.unique(clusters, return_inverse=True)
    nc = len(uniq)
    cvw = np.zeros(nc, dtype=np.int64)
    np.add.at(cvw, cl, hg.vwgt)
    # map pins, dedup within each net, drop single-pin nets
    pe = hg.pin_sources()
    cpin = cl[hg.eind]
    order = np.lexsort((cpin, pe))
    pe_s, cp_s = pe[order], cpin[order]
    first = np.ones(len(pe_s), dtype=bool)
    first[1:] = (pe_s[1:] != pe_s[:-1]) | (cp_s[1:] != cp_s[:-1])
    pe_d, cp_d = pe_s[first], cp_s[first]
    # merge parallel nets: canonical key = tuple of sorted coarse pins
    nets: dict = {}
    sizes = np.zeros(hg.m, dtype=np.int64)
    np.add.at(sizes, pe_d, 1)
    starts = np.zeros(hg.m + 1, dtype=np.int64)
    starts[1:] = np.cumsum(sizes)
    for e in range(hg.m):
        s, t = starts[e], starts[e + 1]
        if t - s < 2:
            continue                    # single-pin net vanishes
        key = tuple(cp_d[s:t].tolist())
        w = int(hg.ewgt[e])
        nets[key] = nets.get(key, 0) + w
    pin_lists = [np.asarray(kk, dtype=np.int64) for kk in nets.keys()]
    ewgt = np.asarray(list(nets.values()), dtype=np.int64)
    coarse = Hypergraph.from_nets(nc, pin_lists, ewgt=ewgt, vwgt=cvw,
                                  dedup_pins=False)
    return coarse, cl


def project(labels_coarse: np.ndarray, cl: np.ndarray) -> np.ndarray:
    """Lift a coarse partition back to the finer level."""
    return np.asarray(labels_coarse)[cl]


def coarsen_level(hg: Hypergraph, max_cluster_weight: float, seed: int,
                  iters: int = 8, max_net_size: int = 64,
                  stall_factor: float = 0.95) -> Optional[tuple]:
    """One coarsening step; returns (coarse, cl) or None if it stalls."""
    clusters = lp_clustering(hg, max_cluster_weight, iters=iters, seed=seed,
                             max_net_size=max_net_size)
    coarse, cl = contract(hg, clusters)
    if coarse.n >= hg.n * stall_factor:
        return None
    return coarse, cl
