"""Multilevel hypergraph partitioning (`repro.core.hypergraph`).

The hypergraph sibling of the graph pipeline: dual-CSR `Hypergraph`
container with padded ELL/COO device views, LP-clustering coarsening,
greedy hypergraph growing, size-constrained LP refinement (cut-net and
connectivity objectives, Pallas pin-affinity kernel on the hot path) and
the `kahypar` multilevel driver.
"""
from repro.core.hypergraph.container import (EllHypergraph, Hypergraph,
                                             HypergraphFormatError, PinCoo,
                                             to_ell_h, to_pincoo)
from repro.core.hypergraph.coarsen import (clique_expansion, contract,
                                           coarsen_level, lp_clustering,
                                           project, star_expansion)
from repro.core.hypergraph.driver import (HypergraphMedium, KahyparConfig,
                                          PRESETS, kahypar, kahyparE,
                                          multilevel_hypergraph_partition)
from repro.core.hypergraph.dist import (PARHYP_PRESETS, ShardedHypergraph,
                                        parhyp, parhyp_refine,
                                        shard_hypergraph)
from repro.core.hypergraph.initial import greedy_growing, random_partition
from repro.core.hypergraph.metrics import (balance, block_weights,
                                           connectivity, cut_net, evaluate,
                                           is_feasible, net_lambdas)
from repro.core.hypergraph.refine import refine_hypergraph

__all__ = [
    "Hypergraph", "HypergraphFormatError", "EllHypergraph", "PinCoo",
    "to_ell_h", "to_pincoo",
    "clique_expansion", "star_expansion", "lp_clustering", "contract",
    "coarsen_level", "project",
    "greedy_growing", "random_partition",
    "balance", "block_weights", "connectivity", "cut_net", "evaluate",
    "is_feasible", "net_lambdas",
    "refine_hypergraph",
    "HypergraphMedium", "KahyparConfig", "PRESETS", "kahypar", "kahyparE",
    "multilevel_hypergraph_partition",
    "PARHYP_PRESETS", "ShardedHypergraph", "parhyp", "parhyp_refine",
    "shard_hypergraph",
]
