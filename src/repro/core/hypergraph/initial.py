"""Initial partitioning on the coarsest hypergraph.

Greedy hypergraph growing (GHG): grow block after block from random seeds,
always absorbing the free vertex with the highest attraction to the grown
region, where touching a net for the first time adds its weight to all its
free pins.  The coarsest hypergraph is small by construction, so this runs
host-side; the caller polishes every candidate with the device LP refiner.
"""
from __future__ import annotations

import numpy as np

from repro.core.hypergraph.container import Hypergraph


def random_partition(hg: Hypergraph, k: int, seed: int = 0) -> np.ndarray:
    """Weight-aware striping after a random shuffle: near-perfect balance."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(hg.n)
    cw = np.cumsum(hg.vwgt[order])
    total = cw[-1] if hg.n else 0
    bounds = total * (np.arange(1, k + 1) / k)
    blk = np.searchsorted(bounds, cw, side="left").clip(0, k - 1)
    part = np.empty(hg.n, dtype=np.int64)
    part[order] = blk
    return part


def greedy_growing(hg: Hypergraph, k: int, seed: int = 0) -> np.ndarray:
    """Greedy hypergraph growing — blocks 0..k-2 grown to the target
    weight, leftovers land in block k-1."""
    rng = np.random.default_rng(seed)
    n = hg.n
    total = hg.total_vwgt()
    part = np.full(n, k - 1, dtype=np.int64)
    free = np.ones(n, dtype=bool)
    for b in range(k - 1):
        target = total * (b + 1) / k - (total - hg.vwgt[free].sum())
        if target <= 0 or not free.any():
            continue
        aff = np.zeros(n)
        touched = np.zeros(hg.m, dtype=bool)
        ids = np.flatnonzero(free)
        cur = int(rng.choice(ids))
        acc = 0
        while True:
            part[cur] = b
            free[cur] = False
            acc += int(hg.vwgt[cur])
            if acc >= target:
                break
            for e in hg.incident_nets(cur):
                if not touched[e]:
                    touched[e] = True
                    aff[hg.net_pins(e)] += hg.ewgt[e]
            aff[cur] = -np.inf
            cand = np.flatnonzero(free)
            if len(cand) == 0:
                break
            best = cand[np.argmax(aff[cand])]
            if aff[best] <= 0:          # region exhausted: random restart
                best = int(rng.choice(cand))
            cur = int(best)
    return part
