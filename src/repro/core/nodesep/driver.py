"""Multilevel node separators — the `SeparatorMedium` adapter and the
``node_separator`` (multilevel) program entry.

The paper's node-separator tool was a post-hoc construction (partition with
KaFFPa, then vertex-cover the boundary — core/separator.py, kept as the
seed-parity baseline).  This medium makes separators first-class on the
shared engine (core/multilevel.py): the 3-label state {A, B, S} rides the
same hierarchy build, vmap-batched initial tournament, uncoarsen-refine,
V-cycles and time-budget restarts, but every refinement step optimizes the
*separator weight* directly (arXiv:1012.0006: local search on the target
objective at every level is where the quality comes from).

Coarsening is label-oblivious on the way down (no labels exist yet); on
protected re-coarsening (V-cycles) the engine's signature splitting keeps
the 3-label state exactly representable, which in particular never
contracts an A–B pair — the separator stays a separator at every level,
and projected labels stay feasible because cluster weights are label-sums.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.csr import Graph, to_coo, to_ell
from repro.core import coarsen as C
from repro.core import initial as I
from repro.core import multilevel as ML
from repro.core import refine as R
from repro.core.nodesep.refine import (SEP, boundary_to_separator,
                                       flow_separator_polish,
                                       refine_separator,
                                       refine_separator_batch,
                                       refine_separator_multi,
                                       separator_invariant_ok,
                                       separator_is_feasible,
                                       separator_weight,
                                       vertex_cover_polish)


@dataclasses.dataclass
class NodesepConfig:
    coarsening: str = "matching"        # matching | lp
    lp_iters: int = 8
    refine_rounds: int = 10
    bisect_rounds: int = 8              # 2-way cut rounds (init + cut polish)
    multi_try: int = 0                  # localized cut restarts per level
    initial_tries: int = 4
    vcycles: int = 1
    contraction_stop_factor: int = 40
    cluster_weight_factor: float = 3.0
    stop_n_floor: int = 64
    vc_polish_max_n: int = 6000         # König polish only below this size
    use_flow: bool = True               # band min-vertex-cut polish
    flow_max_n: int = 6000
    flow_band_depth: int = 3
    use_kernel: Optional[bool] = None   # None = Pallas on TPU, COO fallback

    @property
    def batch_floor(self) -> int:
        """Shared pow2 batch bucket (DESIGN.md §12): single refines pad up
        to the tournament width so both run one compiled program."""
        from repro.core.csr import _pow2_pad
        return _pow2_pad(max(self.initial_tries, 1), 1)


PRESETS = {
    "fast":         NodesepConfig(refine_rounds=6, bisect_rounds=6,
                                  initial_tries=2),
    "eco":          NodesepConfig(refine_rounds=12, initial_tries=4,
                                  multi_try=2),
    "strong":       NodesepConfig(refine_rounds=16, initial_tries=6,
                                  multi_try=3, vcycles=2),
    "fastsocial":   NodesepConfig(coarsening="lp", refine_rounds=6,
                                  bisect_rounds=6, initial_tries=2),
    "ecosocial":    NodesepConfig(coarsening="lp", refine_rounds=12,
                                  initial_tries=4, multi_try=2),
    "strongsocial": NodesepConfig(coarsening="lp", refine_rounds=16,
                                  initial_tries=6, multi_try=3, vcycles=2),
}


class SeparatorMedium(ML.ViewCache):
    """The node-separator adapter for the shared multilevel engine.

    Partitions handled by the engine are 3-label arrays {0=A, 1=B, 2=S};
    ``k`` is always 2 (two blocks — S is the objective, not a block)."""

    def __init__(self, g: Graph, cfg: NodesepConfig, recorder=None):
        self.g = g
        self.cfg = cfg
        self.recorder = recorder
        self.use_kernel = (R.default_use_kernel() if cfg.use_kernel is None
                           else cfg.use_kernel)

    # -- structure ---------------------------------------------------------
    @property
    def n(self) -> int:
        return self.g.n

    @property
    def params(self) -> ML.EngineParams:
        cfg = self.cfg
        return ML.EngineParams(
            initial_tries=cfg.initial_tries, vcycles=cfg.vcycles,
            contraction_stop_factor=cfg.contraction_stop_factor,
            cluster_weight_factor=cfg.cluster_weight_factor,
            stop_n_floor=cfg.stop_n_floor, recorder=self.recorder)

    def total_vwgt(self) -> int:
        return self.g.total_vwgt()

    def cluster(self, max_cluster_weight: float, seed: int,
                protect: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
        g = self.g
        forbidden = None
        if protect:
            # forbids contracting any label-mixed pair — A–B in particular
            forbidden = ML.protect_cut_mask(g.edge_sources(), g.adjncy,
                                            protect)
        if self.cfg.coarsening == "lp":
            return C.lp_clustering(g, max_cluster_weight,
                                   iters=self.cfg.lp_iters, seed=seed,
                                   forbidden=forbidden)
        return C.heavy_edge_matching(g, seed=seed,
                                     max_cluster_weight=max_cluster_weight,
                                     forbidden=forbidden)

    def contract(self, clusters: np.ndarray):
        coarse, cl = C.contract(self.g, clusters)
        return SeparatorMedium(coarse, self.cfg, recorder=self.recorder), cl

    # -- device views ------------------------------------------------------
    def build_views(self):
        coo = to_coo(self.g)
        ell = to_ell(self.g, row_tile=coo.n_pad) if self.use_kernel else None
        return coo, ell

    # -- refinement --------------------------------------------------------
    def refine(self, part: np.ndarray, k: int, eps: float, seed: int,
               force_balance: Optional[bool] = None) -> np.ndarray:
        coo, ell = self.views
        rec = ML.recorder_of(self)
        if force_balance is None:
            force_balance = not separator_is_feasible(self.g, part, eps)
        part = refine_separator(self.g, part, eps,
                                rounds=self.cfg.refine_rounds, seed=seed,
                                coo=coo, ell=ell, use_kernel=self.use_kernel,
                                force_balance=force_balance,
                                batch_floor=self.cfg.batch_floor)
        if rec.enabled:
            rec.count("refine/rounds", self.cfg.refine_rounds)
            if force_balance:
                rec.count("refine/forced_balance")
        part = self.polish(part, k, eps, seed)
        cand = self._cut_candidate(part, eps, seed)
        if (separator_weight(self.g, cand) < separator_weight(self.g, part)
                and separator_is_feasible(self.g, cand, eps)):
            part = cand
            rec.count("nodesep/cut_escapes_adopted")
        return part

    def _cut_candidate(self, part: np.ndarray, eps: float,
                       seed: int) -> np.ndarray:
        """Edge-cut-driven escape candidate: reabsorb S into the bipartition
        by side affinity, refine the *cut* (the post-hoc baseline's per-level
        step), lift the boundary back into S, separator-refine and
        VC-polish.  The caller adopts it only on improvement, so `refine`
        stays non-worsening — but comparing *fully polished* candidates is
        what lets a better-cut basin win even when its raw boundary is
        heavier than the incumbent separator."""
        g = self.g
        coo, ell = self.views
        part = np.asarray(part, dtype=np.int64)
        src = g.edge_sources()
        aff = np.zeros((g.n, 2), dtype=np.int64)
        for b in (0, 1):
            m = part[g.adjncy] == b
            np.add.at(aff[:, b], src[m], g.adjwgt[m])
        two = np.where(part == SEP, (aff[:, 1] > aff[:, 0]).astype(np.int64),
                       part)
        from repro.core.partition import is_feasible
        two = R.refine_kway(g, two, 2, eps, rounds=self.cfg.bisect_rounds,
                            seed=seed + 7, coo=coo,
                            force_balance=not is_feasible(g, two, 2, eps),
                            batch_floor=self.cfg.batch_floor)
        if self.cfg.multi_try:
            two = R.multi_try_refine(g, two, 2, eps,
                                     tries=self.cfg.multi_try,
                                     rounds=self.cfg.bisect_rounds,
                                     seed=seed + 11, coo=coo,
                                     batch_floor=self.cfg.batch_floor)
        cand = boundary_to_separator(g, two)
        cand = refine_separator(g, cand, eps, rounds=self.cfg.refine_rounds,
                                seed=seed + 13, coo=coo, ell=ell,
                                use_kernel=self.use_kernel,
                                batch_floor=self.cfg.batch_floor)
        return self.polish(cand, 2, eps, seed)

    def refine_batch(self, parts: Sequence[np.ndarray], k: int, eps: float,
                     seed: int, keys=None) -> List[np.ndarray]:
        coo, ell = self.views
        return refine_separator_batch(self.g, list(parts), eps,
                                      rounds=self.cfg.refine_rounds,
                                      seed=seed, coo=coo, ell=ell,
                                      use_kernel=self.use_kernel, keys=keys,
                                      batch_floor=self.cfg.batch_floor)

    def bucket_key(self):
        """Shape-bucket identity for the ND wave (DESIGN.md §12): media
        agreeing on this key share one batched tournament program."""
        coo, _ = self.views
        return ("sep", coo.n_pad, coo.e_pad, self.cfg.refine_rounds,
                self.use_kernel)

    def refine_multi(self, media: Sequence["SeparatorMedium"],
                     cands_lists: Sequence[Sequence[np.ndarray]], k: int,
                     eps: float, seeds: Sequence[int]
                     ) -> List[List[np.ndarray]]:
        """Cross-graph batched tournament refine for same-bucket siblings
        (invoked via `ML.initial_partition_wave`)."""
        return refine_separator_multi([m.g for m in media],
                                      [list(c) for c in cands_lists], eps,
                                      rounds=self.cfg.refine_rounds,
                                      seeds=list(seeds),
                                      coos=[m.views[0] for m in media])

    def polish(self, part: np.ndarray, k: int, eps: float,
               seed: int) -> np.ndarray:
        rec = ML.recorder_of(self)
        if self.g.n <= self.cfg.vc_polish_max_n:
            part = vertex_cover_polish(self.g, part, eps)
            rec.count("nodesep/vc_polish")
        if self.cfg.use_flow and self.g.n <= self.cfg.flow_max_n:
            part = flow_separator_polish(self.g, part, eps,
                                         band_depth=self.cfg.flow_band_depth)
            rec.count("nodesep/flow_polish")
        return part

    # -- initial partitioning ----------------------------------------------
    def initial_candidates(self, k: int, eps: float,
                           seed: int) -> List[np.ndarray]:
        """Bisect (greedy growing + 2-way gain refinement on the cached
        views), then lift the lighter boundary side into S.  The engine's
        tournament separator-refines all candidates in one batched call."""
        g, cfg = self.g, self.cfg
        coo, _ = self.views
        cands = []
        for t in range(cfg.initial_tries):
            two = I.bfs_grow_bisection(g, 0.5, seed=seed + 101 * t)
            two = R.refine_kway(g, two, 2, eps, rounds=cfg.bisect_rounds,
                                seed=seed + 101 * t, coo=coo,
                                batch_floor=cfg.batch_floor)
            cands.append(boundary_to_separator(g, two))
        return cands

    # -- objective ---------------------------------------------------------
    def objective(self, part: np.ndarray) -> float:
        return float(separator_weight(self.g, part))

    def imbalance(self, part: np.ndarray, k: int) -> float:
        labels = np.asarray(part)
        wa = int(self.g.vwgt[labels == 0].sum())
        wb = int(self.g.vwgt[labels == 1].sum())
        lmax = np.ceil(self.g.total_vwgt() / 2.0)
        return float(max(wa, wb)) / max(lmax, 1.0)

    def is_feasible(self, part: np.ndarray, k: int, eps: float) -> bool:
        return (separator_is_feasible(self.g, part, eps)
                and separator_invariant_ok(self.g, part))


# ---------------------------------------------------------------------------
# program entries
# ---------------------------------------------------------------------------

def split_labels(labels: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """3-label state → (separator ids, underlying bipartition).

    S vertices get block 0 in the bipartition — callers mask them out via
    the separator ids (matching the post-hoc ``node_separator`` contract)."""
    labels = np.asarray(labels, dtype=np.int64)
    sep = np.flatnonzero(labels == SEP)
    part2 = np.where(labels == 1, 1, 0).astype(np.int64)
    return sep, part2


def multilevel_node_separator(g: Graph, eps: float = 0.20,
                              preset: str = "eco", seed: int = 0,
                              vcycles: Optional[int] = None,
                              time_limit: float = 0.0, report=None
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """The multilevel ``node_separator`` program (2-way).

    Returns (separator_ids, part2) like the post-hoc baseline
    (core/separator.py), but optimizes separator weight at every hierarchy
    level through the shared engine.
    """
    return split_labels(nodesep_labels(g, eps, preset, seed,
                                       vcycles=vcycles,
                                       time_limit=time_limit,
                                       report=report))


def nodesep_labels(g: Graph, eps: float = 0.20, preset: str = "eco",
                   seed: int = 0, vcycles: Optional[int] = None,
                   time_limit: float = 0.0, report=None) -> np.ndarray:
    """Raw 3-label output of the multilevel separator driver.

    ``report`` is an optional ``obs.Recorder`` (DESIGN.md §11)."""
    if g.n == 0:
        return np.zeros(0, dtype=np.int64)
    medium = SeparatorMedium(g, PRESETS[preset], recorder=report)
    return ML.run(medium, 2, eps, seed, vcycles=vcycles,
                  time_limit=time_limit)


def nodesep_labels_wave(graphs: Sequence[Graph], eps: float = 0.20,
                        preset: str = "eco",
                        seeds: Optional[Sequence[int]] = None,
                        report=None) -> List[np.ndarray]:
    """3-label separators for SEVERAL graphs, batching across siblings.

    The nested-dissection recursion (core/ordering.py) calls this on waves
    of sibling subproblems: hierarchies are built per graph, then the
    coarsest-level tournaments of same-shape-bucket siblings run as one
    batched device call (`ML.initial_partition_wave`, DESIGN.md §12).
    Per graph the result is bit-identical to ``nodesep_labels(graphs[i],
    eps, preset, seed=seeds[i])`` without a time budget.
    """
    seeds = list(seeds) if seeds is not None else [0] * len(graphs)
    cfg = PRESETS[preset]
    results: List[Optional[np.ndarray]] = [None] * len(graphs)
    hier = []
    for i, g in enumerate(graphs):
        if g.n == 0:
            results[i] = np.zeros(0, dtype=np.int64)
            continue
        m = SeparatorMedium(g, cfg, recorder=report)
        hier.append((i, m, ML.build_hierarchy(m, 2, seeds[i])))
    parts_c = ML.initial_partition_wave([lv[-1] for _, _, lv in hier], 2,
                                        eps, [seeds[i] for i, _, _ in hier])
    for (i, m, lv), pc in zip(hier, parts_c):
        part = ML.uncoarsen(lv, pc, 2, eps, seeds[i])
        for cyc in range(1, m.params.vcycles):
            part = ML.vcycle(m, part, 2, eps, seeds[i] + 7919 * cyc)
        results[i] = part
    return results


def memetic_nodesep_labels(g: Graph, eps: float = 0.20, preset: str = "eco",
                           seed: int = 0, n_islands: int = 2,
                           population: int = 2, time_limit: float = 5.0,
                           generations: Optional[int] = None,
                           migrate: bool = True, mesh=None) -> np.ndarray:
    """Memetic separator mode (DESIGN.md §10): the island driver over
    `SeparatorMedium` — the engine's protected-coarsening combine keeps
    both parents' 3-label states representable, so offspring separators
    are never heavier than the seeding parent."""
    from repro.core import memetic as MEM
    MEM.validate_memetic_params(n_islands, population, time_limit,
                                generations)
    if g.n == 0:
        return np.zeros(0, dtype=np.int64)
    medium = SeparatorMedium(g, PRESETS[preset])
    cfg = MEM.MemeticConfig(n_islands=n_islands, population=population,
                            time_limit=time_limit, generations=generations,
                            migrate=migrate)
    state = MEM.evolve_islands(medium, 2, eps, cfg, seed, mesh=mesh)
    return state.best_part()


def memetic_node_separator(g: Graph, eps: float = 0.20, preset: str = "eco",
                           seed: int = 0, n_islands: int = 2,
                           population: int = 2, time_limit: float = 5.0,
                           generations: Optional[int] = None,
                           migrate: bool = True, mesh=None
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Memetic ``node_separator`` (2-way): (separator_ids, part2)."""
    return split_labels(memetic_nodesep_labels(
        g, eps, preset, seed, n_islands=n_islands, population=population,
        time_limit=time_limit, generations=generations, migrate=migrate,
        mesh=mesh))
