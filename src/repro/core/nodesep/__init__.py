"""Multilevel node-separator subsystem (DESIGN.md §8).

First-class separators on the shared multilevel engine: the 3-label
{A, B, S} `SeparatorMedium`, size-constrained separator LP/FM refinement
(Pallas affinity kernel with k=3 on TPU, COO scatter oracle elsewhere),
the König vertex-cover polish, and the ``node_separator`` program entry.
The post-hoc two-step construction (core/separator.py) remains as the
seed-parity baseline.
"""
from repro.core.nodesep.driver import (NodesepConfig, PRESETS,
                                       SeparatorMedium,
                                       memetic_node_separator,
                                       memetic_nodesep_labels,
                                       multilevel_node_separator,
                                       nodesep_labels, split_labels)
from repro.core.nodesep.refine import (SEP, boundary_to_separator,
                                       flow_separator_polish,
                                       refine_separator,
                                       refine_separator_batch,
                                       sep_affinity_coo, sep_affinity_ell,
                                       separator_caps,
                                       separator_invariant_ok,
                                       separator_is_feasible,
                                       separator_weight,
                                       vertex_cover_polish)

__all__ = [
    "NodesepConfig", "PRESETS", "SEP", "SeparatorMedium",
    "boundary_to_separator", "flow_separator_polish",
    "memetic_node_separator", "memetic_nodesep_labels",
    "multilevel_node_separator", "nodesep_labels",
    "refine_separator", "refine_separator_batch", "sep_affinity_coo",
    "sep_affinity_ell", "separator_caps", "separator_invariant_ok",
    "separator_is_feasible", "separator_weight", "split_labels",
    "vertex_cover_polish",
]
