"""Size-constrained separator refinement (DESIGN.md §8) — device side.

The 3-label state {A=0, B=1, S=2} is refined with the batch-synchronous LP
adaptation of FM for node separators: per round every separator vertex
computes its *pull-in cost* for leaving S into one side, a conflict-free
subset of moves is applied under the block-size caps, and the opposite-side
neighbours of every mover are pulled into S (the two-hop mask that keeps
the invariant "no A vertex adjacent to a B vertex" by construction).

The gain of moving v from S into block ``s`` is

    gain(v → s) = w(v) − Σ { w(u) : u ∈ N(v), label(u) = other(s) }

i.e. the separator sheds w(v) and absorbs the opposite-side neighbours.
The per-neighbour *vertex-weight* histogram aff[v, b] = Σ_{u∈N(v)} w(u)·
[label(u)=b] is exactly the lp_affinity contraction with k=3 and the edge
weights replaced by gathered neighbour vertex weights — so the existing
Pallas kernel (kernels/lp_affinity.py) is the TPU path and the COO scatter
here is the jnp fallback/oracle (bit-exact: integer-valued f32 sums).

Rounds alternate the target side (A on even parity, B on odd): with all
moves of a round going to one side, a mover can never become adjacent to
the opposite block — its opposite-side neighbours are pulled into S in the
same update.  Summed single-move gains are conservative (a pulled vertex
shared by two movers is counted twice but enters S once), and undo-to-best
over feasible states guards the objective like every other refiner here.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.csr import Graph, CooGraph, EllGraph, to_coo, to_ell

SEP = 2                 # the separator label
_NEG = -1e30
_NOISE = 1e-4           # random tie-break amplitude
_GAIN_EPS = 1e-3        # strictly-positive-gain threshold (> noise)


# ---------------------------------------------------------------------------
# neighbour vertex-weight affinity: jnp oracle + Pallas kernel path
# ---------------------------------------------------------------------------

def sep_affinity_coo(g: CooGraph, labels: jax.Array) -> jax.Array:
    """aff[v, b] = total *vertex weight* of v's neighbours with label b.

    (n_pad, 3).  Padding edges carry w == 0 and are masked out explicitly:
    when n == n_pad the sentinel row is a real vertex with nonzero weight.
    """
    contrib = jnp.where(g.w > 0, g.vwgt[g.dst], 0.0)
    return jnp.zeros((g.n_pad, 3), jnp.float32).at[g.src, labels[g.dst]].add(
        contrib)


def sep_affinity_ell(ell: EllGraph, labels: jax.Array,
                     use_pallas: bool = True) -> jax.Array:
    """Kernel path: the ``sep_affinity`` op (kernels/ops.py) — lp_affinity
    with k=3 over neighbour vertex weights, ``wgt > 0`` invariant mask."""
    from repro.kernels import ops as kops
    return kops.sep_affinity(ell.nbr, ell.wgt, ell.vwgt, labels,
                             use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# the separator LP/FM scan
# ---------------------------------------------------------------------------

def _sep_refine_scan(g: CooGraph, labels0: jax.Array, cap: jax.Array,
                     key: jax.Array, rounds: int, force_balance,
                     ell: Optional[EllGraph] = None,
                     use_kernel: bool = False):
    """``rounds`` one-side-per-round separator moves with undo-to-best.

    Unjitted scan body — vmapped by `_sep_refine_scan_batch` (shared graph)
    and `_sep_refine_scan_multi` (stacked sibling graphs, DESIGN.md §12);
    single refines ride the batched program at the medium's batch floor.

    ``cap`` is (2,) — the block-size caps for A and B; S is uncapped (its
    weight *is* the objective).  ``force_balance`` may be a Python bool or a
    traced scalar (the batched tournament vmaps candidates with mixed
    feasibility): overweight blocks push boundary vertices into S, capped at
    the overshoot so balance restoration inflates S minimally.
    """
    n = g.n_pad
    vw = g.vwgt
    from repro.core.lp import capped_accept

    if use_kernel and ell is not None:
        affinity = lambda lab: sep_affinity_ell(ell, lab)      # noqa: E731
    else:
        affinity = lambda lab: sep_affinity_coo(g, lab)        # noqa: E731

    def sizes_of(lab):
        return jnp.zeros((3,), jnp.float32).at[lab].add(vw)

    sizes0 = sizes_of(labels0)
    feas0 = (sizes0[0] <= cap[0] + 1e-6) & (sizes0[1] <= cap[1] + 1e-6)
    best_w0 = jnp.where(feas0, sizes0[SEP], jnp.inf)

    def body(carry, key_r):
        labels, sizes, best_w, best_labels, parity = carry
        side = (parity % 2).astype(labels.dtype)       # this round's target
        other = (1 - side).astype(labels.dtype)
        aff = affinity(labels)
        noise = jax.random.uniform(key_r, (n,), jnp.float32, 0.0, _NOISE)
        in_sep = labels == SEP
        # gain of leaving S into `side`: shed w(v), absorb other-side nbrs
        gain = vw - aff[jnp.arange(n), other] + noise
        # plateau rounds (every third) admit zero-gain moves: the separator
        # slides sideways to thinner regions; undo-to-best keeps it safe
        thresh = jnp.where(parity % 3 == 2, -_GAIN_EPS, _GAIN_EPS)
        want_move = in_sep & (gain > thresh)
        # forced balance: the most-overweight block pushes into S
        overshoot0 = sizes[0] - cap[0]
        overshoot1 = sizes[1] - cap[1]
        over_blk = jnp.where(overshoot0 >= overshoot1, 0, 1).astype(
            labels.dtype)
        overshoot = jnp.maximum(jnp.maximum(overshoot0, overshoot1), 0.0)
        forced = jnp.asarray(force_balance) & (overshoot > 0)
        want_push = forced & (labels == over_blk) & (vw > 0)
        # parity mask (avoid neighbouring-move oscillation)
        node_par = (jnp.arange(n) + parity) % 2 == 0
        want_move = want_move & node_par
        want_push = want_push & node_par
        proposal = jnp.where(want_move, side, labels)
        proposal = jnp.where(want_push, SEP, proposal)
        # pushes prefer boundary vertices (adjacent to S or the other side)
        pri = jnp.where(want_move, gain, _NEG)
        pri = jnp.where(want_push,
                        aff[jnp.arange(n), SEP] + aff[jnp.arange(n), other]
                        + noise, pri)
        # S admits at most the overshoot (padded by one vertex so integer
        # weights can actually cross it), so forced pushes stop at balance
        push_room = jnp.where(overshoot > 0, overshoot + jnp.max(vw), 0.0)
        cap3 = jnp.stack([cap[0], cap[1], sizes[SEP] + push_room])
        new_labels = capped_accept(labels, proposal, vw, sizes, cap3, pri)
        # two-hop pull-in: opposite-side neighbours of movers enter S
        moved = (new_labels != labels) & in_sep
        reach = jnp.zeros((n,), bool).at[g.dst].max(moved[g.src] & (g.w > 0))
        pulled = reach & (labels == other)
        new_labels = jnp.where(pulled, SEP, new_labels)
        new_sizes = sizes_of(new_labels)
        feas = ((new_sizes[0] <= cap[0] + 1e-6)
                & (new_sizes[1] <= cap[1] + 1e-6))
        better = feas & (new_sizes[SEP] < best_w)
        best_w = jnp.where(better, new_sizes[SEP], best_w)
        best_labels = jnp.where(better, new_labels, best_labels)
        return (new_labels, new_sizes, best_w, best_labels,
                parity + 1), new_sizes[SEP]

    keys = jax.random.split(key, rounds)
    (labels, sizes, best_w, best_labels, _), _ = jax.lax.scan(
        body, (labels0, sizes0, best_w0, labels0, jnp.int32(0)), keys)
    have_best = jnp.isfinite(best_w)
    out = jnp.where(have_best, best_labels, labels)
    return out, jnp.where(have_best, best_w, sizes[SEP])


@functools.partial(jax.jit, static_argnames=("rounds", "use_kernel"))
def _sep_refine_scan_batch(g: CooGraph, labels0: jax.Array, cap: jax.Array,
                           keys: jax.Array, force: jax.Array, rounds: int,
                           ell: Optional[EllGraph] = None,
                           use_kernel: bool = False):
    """THE separator refinement program (one graph, b candidates)."""
    def one(lab0, key, f):
        return _sep_refine_scan(g, lab0, cap, key, rounds, f, ell=ell,
                                use_kernel=use_kernel)
    return jax.vmap(one)(labels0, keys, force)


@functools.partial(jax.jit, static_argnames=("rounds", "use_kernel"))
def _sep_refine_scan_multi(gs: CooGraph, labels0: jax.Array, caps: jax.Array,
                           keys: jax.Array, force: jax.Array, rounds: int,
                           use_kernel: bool = False):
    """Batched tournament over *stacked sibling graphs* at one shape bucket
    (nested-dissection wave, DESIGN.md §12): ``gs`` is a CooGraph whose
    arrays carry a leading batch dim; row i refines candidate i on graph i
    under caps ``caps[i]`` (B, 2)."""
    def one(g, lab0, cap, key, f):
        return _sep_refine_scan(g, lab0, cap, key, rounds, f, ell=None,
                                use_kernel=use_kernel)
    return jax.vmap(one)(gs, labels0, caps, keys, force)


# ---------------------------------------------------------------------------
# host wrappers + metrics
# ---------------------------------------------------------------------------

def separator_caps(g: Graph, eps: float) -> np.ndarray:
    """Block caps: max(w(A), w(B)) ≤ (1+eps)·⌈w(V)/2⌉ (§2.8 constraint)."""
    lmax = np.ceil(g.total_vwgt() / 2.0)
    return np.full(2, (1.0 + eps) * lmax)


def separator_weight(g: Graph, labels: np.ndarray) -> int:
    return int(g.vwgt[np.asarray(labels) == SEP].sum())


def separator_is_feasible(g: Graph, labels: np.ndarray, eps: float) -> bool:
    labels = np.asarray(labels)
    cap = separator_caps(g, eps)
    wa = int(g.vwgt[labels == 0].sum())
    wb = int(g.vwgt[labels == 1].sum())
    return wa <= cap[0] + 1e-9 and wb <= cap[1] + 1e-9


def separator_invariant_ok(g: Graph, labels: np.ndarray) -> bool:
    """The structural invariant: no A vertex is adjacent to a B vertex."""
    labels = np.asarray(labels)
    src = g.edge_sources()
    a, b = labels[src], labels[g.adjncy]
    return not np.any(((a == 0) & (b == 1)) | ((a == 1) & (b == 0)))


def _pad_labels3(labels: np.ndarray, n_pad: int) -> jnp.ndarray:
    lab = np.zeros(n_pad, dtype=np.int32)
    lab[:len(labels)] = labels
    return jnp.asarray(lab)


def _run_sep_scan_batch(coo, cap_np, labs, keys, force, rounds,
                        ell, use_kernel, batch_floor):
    from repro.core import multilevel as ML
    from repro.core.refine import _pad_rows, batch_bucket
    b = labs.shape[0]
    b_pad = batch_bucket(b, batch_floor)
    ML.note_bucket_pad(b_pad - b)
    ML.note_program("sep", coo.n_pad, coo.e_pad, rounds, b_pad, use_kernel)
    outs, _ = _sep_refine_scan_batch(
        coo, jnp.asarray(_pad_rows(labs, b_pad)),
        jnp.asarray(np.asarray(cap_np, np.float32)),
        jnp.asarray(_pad_rows(keys, b_pad)),
        jnp.asarray(_pad_rows(force, b_pad)),
        rounds, ell=ell, use_kernel=use_kernel)
    return np.asarray(outs, dtype=np.int64)[:b]


def refine_separator(g: Graph, labels: np.ndarray, eps: float = 0.20,
                     rounds: int = 10, seed: int = 0,
                     coo: Optional[CooGraph] = None,
                     ell: Optional[EllGraph] = None,
                     use_kernel: Optional[bool] = None,
                     force_balance: bool = False,
                     batch_floor: int = 1) -> np.ndarray:
    """Polish a 3-label state; never worsens a feasible separator weight."""
    if g.n == 0:
        return np.asarray(labels, dtype=np.int64)
    from repro.core.refine import default_use_kernel
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    coo = coo if coo is not None else to_coo(g)
    if use_kernel and ell is None:
        ell = to_ell(g, row_tile=coo.n_pad)
    labs = np.zeros((1, coo.n_pad), dtype=np.int32)
    labs[0, :g.n] = labels
    keys = np.asarray(jax.random.PRNGKey(seed))[None]
    outs = _run_sep_scan_batch(coo, separator_caps(g, eps), labs, keys,
                               np.asarray([force_balance]), rounds,
                               ell, use_kernel, batch_floor)
    out = outs[0][:g.n]
    # paranoia: keep the better of (in, out) among feasible options
    if force_balance:
        return out
    if (separator_weight(g, out) <= separator_weight(g, labels)
            or not separator_is_feasible(g, labels, eps)):
        return out
    return np.asarray(labels, dtype=np.int64)


def refine_separator_batch(g: Graph, cands: List[np.ndarray],
                           eps: float = 0.20, rounds: int = 10, seed: int = 0,
                           coo: Optional[CooGraph] = None,
                           ell: Optional[EllGraph] = None,
                           use_kernel: Optional[bool] = None,
                           keys: Optional[np.ndarray] = None,
                           batch_floor: int = 1) -> List[np.ndarray]:
    """Refine several 3-label candidates in one vmapped device call."""
    if g.n == 0 or not cands:
        return [np.asarray(c, dtype=np.int64) for c in cands]
    from repro.core.refine import default_use_kernel
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    coo = coo if coo is not None else to_coo(g)
    if use_kernel and ell is None:
        ell = to_ell(g, row_tile=coo.n_pad)
    labs = np.zeros((len(cands), coo.n_pad), dtype=np.int32)
    for i, c in enumerate(cands):
        labs[i, :g.n] = c
    force = np.asarray([not separator_is_feasible(g, c, eps) for c in cands])
    if keys is None:
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed),
                                           len(cands)))
    outs = _run_sep_scan_batch(coo, separator_caps(g, eps), labs,
                               np.asarray(keys), force, rounds,
                               ell, use_kernel, batch_floor)
    outs = outs[:, :g.n]
    result = []
    for i, c in enumerate(cands):
        if (separator_weight(g, outs[i]) <= separator_weight(g, c)
                or force[i]):
            result.append(outs[i])
        else:
            result.append(np.asarray(c, dtype=np.int64))
    return result


def refine_separator_multi(graphs: List[Graph],
                           cands_lists: List[List[np.ndarray]],
                           eps: float = 0.20, rounds: int = 10,
                           seeds: Optional[List[int]] = None,
                           coos: Optional[List[CooGraph]] = None
                           ) -> List[List[np.ndarray]]:
    """Refine the candidate tournaments of several *sibling graphs sharing
    one shape bucket* in a single vmapped device call (DESIGN.md §12).

    Per graph this is bit-identical to ``refine_separator_batch(graphs[i],
    cands_lists[i], seed=seeds[i])`` — rows carry per-graph keys
    ``split(PRNGKey(seeds[i]), len(cands_lists[i]))``, caps and arrays, so
    batching changes only which compiled program runs them.
    """
    if not graphs:
        return []
    seeds = seeds if seeds is not None else [0] * len(graphs)
    coos = coos if coos is not None else [to_coo(g) for g in graphs]
    n_pad = coos[0].n_pad
    e_pad = coos[0].e_pad
    assert all(c.n_pad == n_pad and c.e_pad == e_pad for c in coos), \
        "refine_separator_multi requires one shape bucket"
    rows_g, rows_lab, rows_cap, rows_key, rows_force = [], [], [], [], []
    owner = []
    for i, (g, cands) in enumerate(zip(graphs, cands_lists)):
        if not cands:
            continue
        cap = np.asarray(separator_caps(g, eps), np.float32)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(seeds[i]),
                                           len(cands)))
        for j, c in enumerate(cands):
            lab = np.zeros(n_pad, dtype=np.int32)
            lab[:g.n] = c
            rows_g.append(coos[i])
            rows_lab.append(lab)
            rows_cap.append(cap)
            rows_key.append(keys[j])
            rows_force.append(not separator_is_feasible(g, c, eps))
            owner.append((i, j))
    if not rows_g:
        return [[] for _ in graphs]
    from repro.core import multilevel as ML
    from repro.core.refine import batch_bucket
    b = len(rows_g)
    b_pad = batch_bucket(b, 1)
    ML.note_bucket_pad(b_pad - b)
    ML.note_program("sepmulti", n_pad, e_pad, rounds, b_pad, False)
    while len(rows_g) < b_pad:        # pad rows repeat row 0 (inert)
        rows_g.append(rows_g[0])
        rows_lab.append(rows_lab[0])
        rows_cap.append(rows_cap[0])
        rows_key.append(rows_key[0])
        rows_force.append(False)
    import jax.tree_util as jtu
    gs = jtu.tree_map(lambda *xs: jnp.stack(xs), *rows_g)
    outs, _ = _sep_refine_scan_multi(
        gs, jnp.asarray(np.stack(rows_lab)),
        jnp.asarray(np.stack(rows_cap)),
        jnp.asarray(np.stack(rows_key)),
        jnp.asarray(np.asarray(rows_force)), rounds, use_kernel=False)
    outs = np.asarray(outs, dtype=np.int64)
    result: List[List[np.ndarray]] = [[] for _ in graphs]
    for row, (i, j) in enumerate(owner):
        g, c = graphs[i], cands_lists[i][j]
        out = outs[row][:g.n]
        # same per-candidate paranoia as refine_separator_batch
        if (separator_weight(g, out) <= separator_weight(g, c)
                or not separator_is_feasible(g, c, eps)):
            result[i].append(out)
        else:
            result[i].append(np.asarray(c, dtype=np.int64))
    return result


# ---------------------------------------------------------------------------
# boundary → separator conversion and the vertex-cover polish (host)
# ---------------------------------------------------------------------------

def boundary_to_separator(g: Graph, part2: np.ndarray) -> np.ndarray:
    """Lift a bipartition to a 3-label state: the lighter boundary side
    becomes S (the paper's trivial separator, §2.8) — invariant holds by
    construction because non-boundary vertices have no cross-block edge."""
    part2 = np.asarray(part2, dtype=np.int64)
    labels = part2.copy()
    src = g.edge_sources()
    cut = part2[src] != part2[g.adjncy]
    b0 = np.unique(src[cut & (part2[src] == 0)])
    b1 = np.unique(src[cut & (part2[src] == 1)])
    w0 = int(g.vwgt[b0].sum())
    w1 = int(g.vwgt[b1].sum())
    labels[b0 if w0 <= w1 else b1] = SEP
    return labels


def flow_separator_polish(g: Graph, labels: np.ndarray, eps: float,
                          band_depth: int = 3,
                          max_band: int = 4000) -> np.ndarray:
    """Optimal separator within a band around S via node-capacitated max-flow
    (the §2.8 'advanced flow-based separator' idea that superseded the
    post-hoc construction).

    Every band vertex v is split into v_in → v_out with capacity w(v); band
    edges get infinite capacity, the source feeds band vertices adjacent to
    the retained A region and the sink drains those adjacent to retained B.
    The min s-t cut is then a *minimum-weight vertex set* separating A from
    B inside the band — the invariant holds structurally for the recut
    labels (an A'–B' adjacency would cross an uncut infinite edge).  Band
    growth into a side is capped by the opposite block's slack so any recut
    stays feasible; the result is adopted only if strictly lighter.
    """
    from repro.core.refine import _dinic
    labels = np.asarray(labels, dtype=np.int64)
    in_sep = labels == SEP
    if not in_sep.any() or int(in_sep.sum()) > max_band:
        return labels
    src = g.edge_sources()
    cap_blk = separator_caps(g, eps)
    w_blk = [int(g.vwgt[labels == 0].sum()), int(g.vwgt[labels == 1].sum())]
    w_sep = int(g.vwgt[in_sep].sum())
    band = in_sep.copy()
    # BFS band_depth steps into each side, budgeted by the other side's slack
    for side in (0, 1):
        budget = cap_blk[1 - side] - w_blk[1 - side] - w_sep
        cur = band.copy()
        wsum = 0
        for _ in range(band_depth):
            nxt = np.zeros(g.n, dtype=bool)
            hits = cur[src] & (labels[g.adjncy] == side) & ~band[g.adjncy]
            nxt[g.adjncy[hits]] = True
            add_ids = np.flatnonzero(nxt)
            order = np.argsort(g.vwgt[add_ids])          # cheap nodes first
            for i in add_ids[order]:
                if wsum + int(g.vwgt[i]) > budget or band.sum() >= max_band:
                    break
                band[i] = True
                wsum += int(g.vwgt[i])
            cur = nxt & band
            if not cur.any():
                break
    ids = np.flatnonzero(band)
    if len(ids) == 0 or len(ids) > max_band:
        return labels
    remap = -np.ones(g.n, dtype=np.int64)
    remap[ids] = np.arange(len(ids))
    nb = len(ids)
    S_node, T_node = 2 * nb, 2 * nb + 1
    big = int(g.vwgt.sum()) + 1
    edges = []
    for i, v in enumerate(ids):
        edges.append([2 * i, 2 * i + 1, int(g.vwgt[v])])   # v_in → v_out
    inside = band[src] & band[g.adjncy]
    for e in np.flatnonzero(inside):                       # directed edges
        u, v = remap[src[e]], remap[g.adjncy[e]]
        edges.append([2 * u + 1, 2 * v, big])              # u_out → v_in
    touch_a = band[src] & ~band[g.adjncy] & (labels[g.adjncy] == 0)
    touch_b = band[src] & ~band[g.adjncy] & (labels[g.adjncy] == 1)
    for u in np.unique(src[touch_a]):
        edges.append([S_node, 2 * remap[u], big])
    for u in np.unique(src[touch_b]):
        edges.append([2 * remap[u] + 1, T_node, big])
    _, reach = _dinic(2 * nb + 2, edges, S_node, T_node)
    in_r = reach[0:2 * nb:2]
    out_r = reach[1:2 * nb:2]
    new_labels = labels.copy()
    new_labels[ids] = np.where(in_r & out_r, 0,
                               np.where(in_r & ~out_r, SEP, 1))
    if (separator_weight(g, new_labels) < separator_weight(g, labels)
            and separator_is_feasible(g, new_labels, eps)
            and separator_invariant_ok(g, new_labels)):
        return new_labels
    return labels


def vertex_cover_polish(g: Graph, labels: np.ndarray,
                        eps: float) -> np.ndarray:
    """Replace S with a minimum vertex cover of a boundary bipartite graph.

    S is merged into one side, the resulting 2-way cut's König min-VC is
    extracted (the post-hoc construction, core/separator.py) and adopted iff
    it is lighter and feasible.  Both merge directions are tried.
    """
    from repro.core.separator import separator_from_partition_pair
    labels = np.asarray(labels, dtype=np.int64)
    best = labels
    best_w = separator_weight(g, labels)
    for side in (0, 1):
        part2 = np.where(labels == (1 - side), 1 - side, side)
        sep = separator_from_partition_pair(g, part2, 0, 1)
        cand = part2.copy()
        cand[sep] = SEP
        w = separator_weight(g, cand)
        if (w < best_w and separator_is_feasible(g, cand, eps)
                and separator_invariant_ok(g, cand)):
            best, best_w = cand, w
    return best
