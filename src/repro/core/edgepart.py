"""Edge partitioning via the split-and-connect (SPAC) model (paper §2.7).

Every vertex v of degree d is split into d *split vertices*, one per
incident edge, connected in a cycle by auxiliary edges of weight
``infinity`` (the --infinity option).  Every original edge becomes a
unit-weight edge between the two corresponding split vertices.  A node
partition of the SPAC graph induces an edge partition of the original graph;
the heavy auxiliary cycles keep a vertex's split copies together, minimizing
vertex replication.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.csr import Graph
from repro.core.partition import edge_partition_metrics


def build_spac(g: Graph, infinity: int = 1000):
    """Returns (spac graph, edge→split-vertex map (m, 2))."""
    src = g.edge_sources()
    fwd = src < g.adjncy                     # canonical undirected edges
    eu, ev = src[fwd], g.adjncy[fwd]
    m = len(eu)
    # split vertex id = position of the directed edge in adjncy
    # for edge j with endpoints (u, v): splits are the two directed slots
    dir_id = np.arange(len(src))
    # map each canonical edge to its two directed slots
    key_fwd = eu * np.int64(g.n) + ev
    key_all = src * np.int64(g.n) + g.adjncy
    key_rev = ev * np.int64(g.n) + eu
    order_all = np.argsort(key_all)
    pos_fwd = order_all[np.searchsorted(key_all[order_all], key_fwd)]
    pos_rev = order_all[np.searchsorted(key_all[order_all], key_rev)]
    esplit = np.stack([pos_fwd, pos_rev], axis=1)     # (m, 2) split ids
    # unit edges between the two split vertices of each original edge
    spac_u = [pos_fwd]
    spac_v = [pos_rev]
    spac_w = [np.ones(m, dtype=np.int64)]
    # auxiliary cycles per original vertex
    deg = g.degrees()
    for v in range(g.n):
        lo, hi = g.xadj[v], g.xadj[v + 1]
        ids = dir_id[lo:hi]
        d = len(ids)
        if d >= 2:
            nxt = np.roll(ids, -1)
            if d == 2:     # avoid parallel edges on a 2-cycle
                spac_u.append(ids[:1]); spac_v.append(nxt[:1])
                spac_w.append(np.full(1, infinity, dtype=np.int64))
            else:
                spac_u.append(ids); spac_v.append(nxt)
                spac_w.append(np.full(d, infinity, dtype=np.int64))
    nspac = len(src)
    spac = Graph.from_edges(nspac, np.concatenate(spac_u),
                            np.concatenate(spac_v), np.concatenate(spac_w),
                            dedup=True)
    return spac, esplit


def spac_medium(g: Graph, preset: str = "eco", infinity: int = 1000):
    """The edge-partitioning adapter onto the shared engine: a `GraphMedium`
    of the SPAC graph (the PR-2 'new media as ~100-line adapters'
    follow-up).  The infinity-weight auxiliary cycles survive every engine
    phase structurally: heavy-edge matching contracts them first, and under
    protected re-coarsening (V-cycles) an auxiliary edge is only left
    uncontracted when the protected partition already cuts it — in which
    case refinement's huge gain for healing it keeps split copies together.

    Returns (medium, esplit) — partition ``medium`` with ``multilevel.run``
    and map blocks through ``esplit[:, 0]``.
    """
    from repro.core.kaffpa import GraphMedium, PRESETS
    spac, esplit = build_spac(g, infinity)
    return GraphMedium(spac, PRESETS[preset]), esplit


def edge_partition(g: Graph, k: int, eps: float = 0.03,
                   preset: str = "eco", infinity: int = 1000,
                   seed: int = 0, partitioner=None,
                   vcycles: Optional[int] = None,
                   time_limit: float = 0.0) -> np.ndarray:
    """The ``edge_partitioning`` program: returns block id per canonical
    undirected edge (lo<hi order, matching Graph.from_edges).

    Drives the shared multilevel engine on a `GraphMedium` of the SPAC
    graph, so V-cycles and time-budget restarts apply to edge partitioning
    like every other medium."""
    from repro.core import multilevel as ML
    if partitioner is not None:
        spac, esplit = build_spac(g, infinity)
        part = partitioner(spac, k, eps, seed)
        return part[esplit[:, 0]]
    medium, esplit = spac_medium(g, preset, infinity)
    part = ML.run(medium, k, eps, seed, vcycles=vcycles,
                  time_limit=time_limit)
    # edge block: block of its first split vertex (splits almost always agree
    # thanks to the infinity cycles)
    return part[esplit[:, 0]]


def distributed_edge_partition(g: Graph, k: int, eps: float = 0.03,
                               preconfiguration: str = "fastmesh",
                               infinity: int = 1000, seed: int = 0,
                               mesh=None) -> np.ndarray:
    """The ``distributed_edge_partitioning`` program: ParHIP on the SPAC
    graph (§4.6)."""
    from repro.core.parhip import parhip
    spac, esplit = build_spac(g, infinity)
    part = parhip(spac, k, eps, preconfiguration, seed=seed, mesh=mesh)
    return part[esplit[:, 0]]


def naive_edge_partition(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Baseline: random balanced edge assignment (for benchmarks)."""
    rng = np.random.default_rng(seed)
    m = g.m
    blk = np.repeat(np.arange(k), (m + k - 1) // k)[:m]
    return blk[rng.permutation(m)]
