"""Process mapping (paper §2.6, §4.8) — map k processes onto a hierarchical
processor network, minimizing the QAP objective

    J(σ) = Σ_{p,q} comm(p, q) · dist(σ(p), σ(q)) .

``hierarchy_parameter_string`` "4:8:8" means 4 cores/PE, 8 PEs/rack, 8 racks;
``distance_parameter_string`` "1:10:100" gives the distance charged at each
level of the deepest common ancestor.  k = prod(hierarchy).

Algorithms (paper): *global multisection* — recursively partition the
communication graph along the hierarchy top-down with perfectly-balanced
KaFFPa calls — plus a pairwise-swap local search.  ``MAPMODE_BISECTION``
falls back to recursive bisection into prod() blocks.

This module is also the integration point for the LM framework: the
communication graph of a compiled train step (collective bytes per mesh-axis
pair) is mapped onto the TPU pod hierarchy (launch/topology.py).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.csr import Graph
from repro.core.kaffpa import kaffpa
from repro.core.kabape import balance_path

MAPMODE_MULTISECTION = 0
MAPMODE_BISECTION = 1


def parse_hierarchy(hierarchy: str | Sequence[int],
                    distances: str | Sequence[int]):
    if isinstance(hierarchy, str):
        hierarchy = [int(x) for x in hierarchy.split(":")]
    if isinstance(distances, str):
        distances = [int(x) for x in distances.split(":")]
    assert len(hierarchy) == len(distances), "hierarchy/distance mismatch"
    return list(hierarchy), list(distances)


def processor_distance_matrix(hierarchy: Sequence[int],
                              distances: Sequence[int]) -> np.ndarray:
    """dist[i, j] between processors in hierarchical numbering.

    Processor id = mixed-radix number, *innermost level first*: with 4:8:8,
    id = core + 4·(pe + 8·rack).  dist = distances[highest differing level].
    """
    k = int(np.prod(hierarchy))
    ids = np.arange(k)
    coords = []
    rest = ids
    for h in hierarchy:
        coords.append(rest % h)
        rest = rest // h
    dist = np.zeros((k, k), dtype=np.int64)
    for lvl in range(len(hierarchy) - 1, -1, -1):
        differ = coords[lvl][:, None] != coords[lvl][None, :]
        dist = np.where((dist == 0) & differ, distances[lvl], dist)
    return dist


def qap_cost(comm: np.ndarray, dist: np.ndarray,
             mapping: np.ndarray) -> int:
    """mapping[p] = processor of process p."""
    d = dist[mapping[:, None], mapping[None, :]]
    return int((comm * d).sum()) // 2


def _comm_graph(comm: np.ndarray) -> Graph:
    k = comm.shape[0]
    u, v = np.triu_indices(k, 1)
    w = comm[u, v]
    keep = w > 0
    # kaffpa needs positive integer weights
    return Graph.from_edges(k, u[keep], v[keep],
                            np.maximum(w[keep], 1).astype(np.int64))


def _multisection(comm: np.ndarray, hierarchy: Sequence[int],
                  seed: int, preset: str = "eco") -> np.ndarray:
    """Top-down recursive multisection along the hierarchy (outermost level
    first).  Returns processor id per process (innermost-first mixed radix).
    """
    k = comm.shape[0]
    procs = np.zeros(k, dtype=np.int64)

    def recurse(ids: np.ndarray, levels: list, base: int, stride_done: int):
        if len(levels) == 0 or len(ids) <= 1:
            # leaf: assign consecutive processor ids
            for i, p in enumerate(ids):
                procs[p] = base + i
            return
        parts_at_level = levels[-1]            # outermost level size
        sub = comm[np.ix_(ids, ids)]
        gsub = _comm_graph(sub)
        if gsub.m == 0:
            blk = np.arange(len(ids)) % parts_at_level
        else:
            blk = kaffpa(gsub, parts_at_level, 0.0, preset, seed=seed,
                         enforce_balance=True)
            if np.bincount(blk, minlength=parts_at_level).max() \
                    > len(ids) // parts_at_level:
                blk = balance_path(gsub, blk, parts_at_level, 0.0)
            # hard guarantee: exact equal sizes (arbitrary moves if needed)
            want = len(ids) // parts_at_level
            sizes = np.bincount(blk, minlength=parts_at_level)
            for b in range(parts_at_level):
                while sizes[b] > want:
                    under = int(np.argmin(sizes))
                    victim = np.flatnonzero(blk == b)[-1]
                    blk[victim] = under
                    sizes[b] -= 1
                    sizes[under] += 1
        inner = int(np.prod(levels[:-1])) if len(levels) > 1 else 1
        for b in range(parts_at_level):
            sel = ids[blk == b]
            recurse(sel, levels[:-1], base + b * inner, stride_done)

    recurse(np.arange(k), list(hierarchy), 0, 1)
    return procs


def _swap_local_search(comm: np.ndarray, dist: np.ndarray,
                       mapping: np.ndarray, iters: int = 3) -> np.ndarray:
    """Pairwise-swap hill climbing on the QAP objective (paper's fast local
    search, restricted to pairs with nonzero communication)."""
    mapping = mapping.copy()
    k = len(mapping)
    pairs = np.argwhere(comm > 0)
    pairs = pairs[pairs[:, 0] < pairs[:, 1]]
    for _ in range(iters):
        improved = False
        cur = qap_cost(comm, dist, mapping)
        for (p, q) in pairs:
            mapping[p], mapping[q] = mapping[q], mapping[p]
            c = qap_cost(comm, dist, mapping)
            if c < cur:
                cur = c
                improved = True
            else:
                mapping[p], mapping[q] = mapping[q], mapping[p]
        if not improved:
            break
    return mapping


def process_mapping(comm: np.ndarray, hierarchy, distances,
                    mode: int = MAPMODE_MULTISECTION, seed: int = 0,
                    local_search: bool = True) -> np.ndarray:
    """The ``process_mapping`` library call / ``global_multisection`` program.

    comm: (k, k) symmetric nonnegative communication matrix.
    Returns mapping[p] = processor id.
    """
    hierarchy, distances = parse_hierarchy(hierarchy, distances)
    k = int(np.prod(hierarchy))
    assert comm.shape == (k, k), f"comm must be ({k},{k})"
    if mode == MAPMODE_MULTISECTION:
        mapping = _multisection(comm, hierarchy, seed)
    else:
        # bisection mode: one flat perfectly-balanced k-partition is the
        # identity here (k singleton blocks) → start from identity
        mapping = np.arange(k, dtype=np.int64)
    if local_search:
        dist = processor_distance_matrix(hierarchy, distances)
        mapping = _swap_local_search(comm, dist, mapping)
    return mapping


def kaffpa_with_mapping(g: Graph, hierarchy, distances, eps: float = 0.03,
                        preset: str = "eco", seed: int = 0) -> tuple:
    """kaffpa --enable_mapping: partition into k = prod(hierarchy) blocks,
    then map blocks to processors (§4.1).  Returns (part, mapping, qap)."""
    hierarchy, distances = parse_hierarchy(hierarchy, distances)
    k = int(np.prod(hierarchy))
    part = kaffpa(g, k, eps, preset, seed=seed)
    # block-level communication volume matrix
    src = g.edge_sources()
    comm = np.zeros((k, k), dtype=np.int64)
    ext = part[src] != part[g.adjncy]
    np.add.at(comm, (part[src[ext]], part[g.adjncy[ext]]), g.adjwgt[ext])
    mapping = process_mapping(comm, hierarchy, distances, seed=seed)
    dist = processor_distance_matrix(hierarchy, distances)
    return part, mapping, qap_cost(comm, dist, mapping)
