"""Uncoarsening refinement (paper §2.1).

Three refiners, mirroring KaFFPa's arsenal under the batch-synchronous
adaptation documented in DESIGN.md §2:

  * ``refine_kway``      — round-based k-way gain refinement (the FM variant:
    all boundary nodes eligible, best-gain moves, balance-capped, undo to the
    best feasible cut seen).
  * ``multi_try_refine`` — the *multi-try FM* analogue: search is seeded from
    a random subset of boundary nodes and expands only through moved nodes'
    neighbourhoods (localized search escapes local optima, §2.1).
  * ``flow_refine``      — max-flow min-cut improvement on the boundary band
    of a block pair (host-side Dinic; the ``strong`` preset applies it on
    small/coarse levels, where KaHIP also concentrates its flow budget).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.csr import Graph, CooGraph, EllGraph, to_coo, to_ell
from repro.core.partition import edge_cut_device, edge_cut, is_feasible
from repro.core import lp as lp_mod


def default_use_kernel() -> bool:
    """Resolve ``use_kernel=None``: the Pallas affinity kernels are the
    default k-way refinement path on TPU; off-TPU they would run in
    interpret mode, so the COO scatter fallback/oracle is used instead."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# batched k-way gain refinement
#
# One jitted program per (bucket, k, rounds, batch bucket): the former
# allow_zero_gain / localized static flags are traced per batch row, and
# every entry point (single refine, multi-try, tournament) routes through
# the same vmapped scan — padded to the medium's pow2 batch bucket so
# hierarchy levels, V-cycles, islands and ND subproblems at the same shape
# share one compile (DESIGN.md §12).
# ---------------------------------------------------------------------------

def _refine_scan(g: CooGraph, labels0: jax.Array, cap: jax.Array,
                 rkeys: jax.Array, nrounds: jax.Array, k: int, rounds: int,
                 allow_zero_gain, force_balance,
                 active0: jax.Array,
                 ell: Optional[EllGraph] = None, use_kernel: bool = False):
    """One candidate's scan body (unjitted; vmapped by `_refine_scan_batch`).

    ``allow_zero_gain`` and ``force_balance`` are traced booleans; the
    localized-search reach expansion always runs (with ``active0`` all-ones
    it is the identity, bit-identical to an unmasked scan).  ``rkeys`` holds
    the per-round PRNG keys (``rounds``, 2) precomputed on the host, and
    ``nrounds`` (traced) masks trailing rounds to no-ops — a short search
    (e.g. multi-try's ``rounds//2``) keeps its exact ``split(key, r)`` key
    sequence while sharing the full-length compiled program.
    """
    n = g.n_pad
    vw = g.vwgt
    sizes0 = jnp.zeros((k,), jnp.float32).at[labels0].add(vw)
    cut0 = edge_cut_device(g, labels0)
    feas0 = jnp.max(sizes0 - cap) <= 1e-6
    best_cut0 = jnp.where(feas0, cut0, jnp.inf)
    affinity_fn = None
    if use_kernel and ell is not None:
        from repro.kernels import ops as kops
        affinity_fn = lambda _g, lab, kk: kops.lp_affinity(   # noqa: E731
            ell.nbr, ell.wgt, lab, kk)

    def body(carry, key_r):
        labels, sizes, active, best_cut, best_labels, parity = carry
        prop_labels, prop_sizes = lp_mod.kway_lp_round(
            g, labels, sizes, cap, key_r, k, parity,
            active, allow_zero_gain, force_balance,
            affinity_fn=affinity_fn)
        live = parity < nrounds
        new_labels = jnp.where(live, prop_labels, labels)
        new_sizes = jnp.where(live, prop_sizes, sizes)
        moved = new_labels != labels
        reach = jnp.zeros((n,), bool).at[g.dst].max(
            moved[g.src] & (g.w > 0))
        active = active | reach | moved
        cut = edge_cut_device(g, new_labels)
        feas = jnp.max(new_sizes - cap) <= 1e-6
        better = feas & (cut < best_cut)
        best_cut = jnp.where(better, cut, best_cut)
        best_labels = jnp.where(better, new_labels, best_labels)
        return (new_labels, new_sizes, active, best_cut, best_labels,
                parity + 1), cut

    (labels, sizes, _, best_cut, best_labels, _), cuts = jax.lax.scan(
        body, (labels0, sizes0, active0, best_cut0, labels0, jnp.int32(0)),
        rkeys)
    # undo-to-best (KaFFPa semantics): return best feasible if one was seen
    have_best = jnp.isfinite(best_cut)
    out = jnp.where(have_best, best_labels, labels)
    return out, jnp.where(have_best, best_cut, edge_cut_device(g, labels))


@functools.partial(jax.jit, static_argnames=("k", "rounds", "use_kernel"))
def _refine_scan_batch(g: CooGraph, labels0: jax.Array, cap: jax.Array,
                       rkeys: jax.Array, nrounds: jax.Array,
                       zero_gain: jax.Array, force: jax.Array,
                       active0: jax.Array, k: int, rounds: int,
                       ell: Optional[EllGraph] = None,
                       use_kernel: bool = False):
    """THE k-way refinement program: everything routes through here."""
    def one(lab0, rk, nr, z, f, a0):
        return _refine_scan(g, lab0, cap, rk, nr, k, rounds, z, f, a0,
                            ell=ell, use_kernel=use_kernel)
    return jax.vmap(one)(labels0, rkeys, nrounds, zero_gain, force, active0)


def _caps_for(g: Graph, k: int, eps: float,
              fractions: Optional[np.ndarray] = None) -> np.ndarray:
    total = g.total_vwgt()
    if fractions is None:
        lmax = np.ceil(total / k)
        return np.full(k, (1.0 + eps) * lmax)
    return (1.0 + eps) * np.asarray(fractions) * total


def _pad_labels(part: np.ndarray, n_pad: int) -> jnp.ndarray:
    lab = np.zeros(n_pad, dtype=np.int32)
    lab[:len(part)] = part
    return jnp.asarray(lab)


def batch_bucket(b: int, batch_floor: int = 1) -> int:
    """pow2 batch bucket shared by singles and tournaments at a floor."""
    from repro.core.csr import _pow2_pad
    return max(_pow2_pad(max(b, 1), 1), _pow2_pad(max(batch_floor, 1), 1))


def _pad_rows(arr: np.ndarray, b_pad: int) -> np.ndarray:
    """Pad the batch dim to ``b_pad`` by repeating row 0 (rows are
    independent under vmap, so padding rows never change real rows)."""
    b = arr.shape[0]
    if b == b_pad:
        return arr
    return np.concatenate([arr, np.broadcast_to(arr[:1],
                                                (b_pad - b,) + arr.shape[1:])])


def _round_keys(key, rounds: int, rounds_bucket: int) -> np.ndarray:
    """Host-side per-round key schedule (``rounds_bucket``, 2): the first
    ``rounds`` entries are exactly ``split(key, rounds)``; the padding tail
    feeds masked no-op rounds."""
    ks = np.asarray(jax.random.split(key, rounds))
    if rounds < rounds_bucket:
        ks = np.concatenate(
            [ks, np.broadcast_to(ks[:1], (rounds_bucket - rounds, 2))])
    return ks


def _run_scan_batch(coo, cap_np, labs, rkeys, nrounds, zero, force, active,
                    k, rounds_bucket, ell, use_kernel, batch_floor):
    """Shared batched-entry plumbing: pow2-pad the batch dim, count bucket
    pads and program-cache hits, run the one jitted program."""
    from repro.core import multilevel as ML
    b = labs.shape[0]
    b_pad = batch_bucket(b, batch_floor)
    ML.note_bucket_pad(b_pad - b)
    ML.note_program("kway", coo.n_pad, coo.e_pad, k, rounds_bucket, b_pad,
                    use_kernel)
    outs, _ = _refine_scan_batch(
        coo, jnp.asarray(_pad_rows(labs, b_pad)),
        jnp.asarray(np.asarray(cap_np, np.float32)),
        jnp.asarray(_pad_rows(rkeys, b_pad)),
        jnp.asarray(_pad_rows(np.asarray(nrounds, np.int32), b_pad)),
        jnp.asarray(_pad_rows(zero, b_pad)),
        jnp.asarray(_pad_rows(force, b_pad)),
        jnp.asarray(_pad_rows(active, b_pad)),
        k, rounds_bucket, ell=ell, use_kernel=use_kernel)
    return np.asarray(outs, dtype=np.int64)[:b]


def refine_kway(g: Graph, part: np.ndarray, k: int, eps: float = 0.03,
                rounds: int = 12, seed: int = 0,
                fractions: Optional[np.ndarray] = None,
                coo: Optional[CooGraph] = None,
                force_balance: bool = False,
                use_kernel: Optional[bool] = None,
                ell: Optional[EllGraph] = None,
                batch_floor: int = 1,
                rounds_bucket: Optional[int] = None) -> np.ndarray:
    """Polish ``part``; never returns a worse feasible cut (undo-to-best).

    ``use_kernel=None`` resolves to the backend default (Pallas on TPU, COO
    scatter elsewhere); ``coo``/``ell`` accept cached per-level views.
    ``batch_floor`` pads the batch dim up to the medium's bucket so this
    single call reuses the tournament's compiled program; ``rounds_bucket``
    likewise pads the round schedule (extra rounds are masked no-ops).
    """
    if k <= 1 or g.n == 0:
        return part
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    coo = coo if coo is not None else to_coo(g)
    if use_kernel and ell is None:
        ell = to_ell(g, row_tile=coo.n_pad)   # same n_pad as the COO view
    rb = max(rounds, rounds_bucket or 0)
    labs = np.zeros((1, coo.n_pad), dtype=np.int32)
    labs[0, :g.n] = part
    rkeys = _round_keys(jax.random.PRNGKey(seed), rounds, rb)[None]
    outs = _run_scan_batch(coo, _caps_for(g, k, eps, fractions), labs, rkeys,
                           np.asarray([rounds]),
                           np.zeros(1, bool), np.asarray([force_balance]),
                           np.ones((1, coo.n_pad), bool), k, rb, ell,
                           use_kernel, batch_floor)
    out = outs[0][:g.n]
    # paranoia: keep the better of (in, out) among feasible options
    if edge_cut(g, out) <= edge_cut(g, part) or force_balance:
        return out
    return part


def refine_kway_batch(g: Graph, parts: list, k: int, eps: float = 0.03,
                      rounds: int = 12, seed: int = 0,
                      coo: Optional[CooGraph] = None,
                      ell: Optional[EllGraph] = None,
                      use_kernel: Optional[bool] = None,
                      keys: Optional[np.ndarray] = None,
                      batch_floor: int = 1,
                      rounds_bucket: Optional[int] = None) -> list:
    """Refine several candidate partitions in one vmapped device call.

    The initial-partition tournament uses this so all tries share a single
    compile; per-candidate force-balance rides along as a traced scalar.
    ``keys`` overrides the per-candidate PRNG keys (shape ``(b, 2)``) —
    the memetic sweep passes per-island keys so each island's trajectory
    is independent of how many islands are batched together.
    """
    if k <= 1 or g.n == 0 or not parts:
        return [np.asarray(p, dtype=np.int64) for p in parts]
    use_kernel = default_use_kernel() if use_kernel is None else use_kernel
    coo = coo if coo is not None else to_coo(g)
    if use_kernel and ell is None:
        ell = to_ell(g, row_tile=coo.n_pad)
    rb = max(rounds, rounds_bucket or 0)
    labs = np.zeros((len(parts), coo.n_pad), dtype=np.int32)
    for i, p in enumerate(parts):
        labs[i, :g.n] = p
    force = np.asarray([not is_feasible(g, p, k, eps) for p in parts])
    if keys is None:
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(seed),
                                           len(parts)))
    rkeys = np.stack([_round_keys(kk, rounds, rb) for kk in np.asarray(keys)])
    outs = _run_scan_batch(coo, _caps_for(g, k, eps), labs, rkeys,
                           np.full(len(parts), rounds),
                           np.zeros(len(parts), bool),
                           force, np.ones((len(parts), coo.n_pad), bool),
                           k, rb, ell, use_kernel, batch_floor)
    outs = outs[:, :g.n]
    result = []
    for i, p in enumerate(parts):
        # same per-candidate paranoia as refine_kway
        if edge_cut(g, outs[i]) <= edge_cut(g, p) or force[i]:
            result.append(outs[i])
        else:
            result.append(np.asarray(p, dtype=np.int64))
    return result


def multi_try_refine(g: Graph, part: np.ndarray, k: int, eps: float = 0.03,
                     tries: int = 3, rounds: int = 8, seed: int = 0,
                     seed_frac: float = 0.05,
                     coo: Optional[CooGraph] = None,
                     batch_floor: int = 1,
                     rounds_bucket: Optional[int] = None) -> np.ndarray:
    """Multi-try FM analogue: several localized searches from random boundary
    seeds; keeps the best feasible result."""
    if k <= 1 or g.n == 0:
        return part
    coo = coo if coo is not None else to_coo(g)
    rb = max(rounds, rounds_bucket or 0)
    cap_np = _caps_for(g, k, eps)
    best = np.asarray(part, dtype=np.int64)
    best_cut = edge_cut(g, best)
    rng = np.random.default_rng(seed)
    src = g.edge_sources()
    for t in range(tries):
        labs = np.zeros((1, coo.n_pad), dtype=np.int32)
        labs[0, :g.n] = best
        bnd = np.unique(src[best[src] != best[g.adjncy]])
        if len(bnd) == 0:
            break
        nseed = max(1, int(len(bnd) * seed_frac))
        chosen = rng.choice(bnd, size=nseed, replace=False)
        active0 = np.zeros((1, coo.n_pad), dtype=bool)
        active0[0, chosen] = True
        rkeys = _round_keys(jax.random.PRNGKey(seed * 997 + t),
                            rounds, rb)[None]
        outs = _run_scan_batch(coo, cap_np, labs, rkeys,
                               np.asarray([rounds]),
                               np.ones(1, bool), np.zeros(1, bool),
                               active0, k, rb, None, False, batch_floor)
        out = outs[0][:g.n]
        c = edge_cut(g, out)
        if c < best_cut:
            best, best_cut = out, c
    return best


# ---------------------------------------------------------------------------
# flow-based refinement (host, 2 blocks, boundary band)
# ---------------------------------------------------------------------------

def _dinic(nv: int, edges: list, s: int, t: int):
    """Dinic max-flow. edges: list of [u, v, cap]; returns (flow, S-side set)."""
    graph = [[] for _ in range(nv)]
    for (u, v, c) in edges:
        graph[u].append([v, c, len(graph[v])])
        graph[v].append([u, 0, len(graph[u]) - 1])

    def bfs():
        level = [-1] * nv
        level[s] = 0
        q = [s]
        for u in q:
            for e in graph[u]:
                if e[1] > 0 and level[e[0]] < 0:
                    level[e[0]] = level[u] + 1
                    q.append(e[0])
        return level if level[t] >= 0 else None

    def dfs(u, f, level, it):
        if u == t:
            return f
        while it[u] < len(graph[u]):
            e = graph[u][it[u]]
            if e[1] > 0 and level[e[0]] == level[u] + 1:
                d = dfs(e[0], min(f, e[1]), level, it)
                if d > 0:
                    e[1] -= d
                    graph[e[0]][e[2]][1] += d
                    return d
            it[u] += 1
        return 0

    flow = 0
    while True:
        level = bfs()
        if level is None:
            break
        it = [0] * nv
        while True:
            f = dfs(s, float("inf"), level, it)
            if f == 0:
                break
            flow += f
    # S side of the min cut = reachable in residual
    seen = [False] * nv
    seen[s] = True
    q = [s]
    for u in q:
        for e in graph[u]:
            if e[1] > 0 and not seen[e[0]]:
                seen[e[0]] = True
                q.append(e[0])
    return flow, np.asarray(seen)


def flow_refine_pair(g: Graph, part: np.ndarray, a: int, b: int,
                     eps: float, band_depth: int = 2,
                     max_band: int = 4000) -> np.ndarray:
    """Max-flow min-cut improvement between blocks a and b (paper §2.1).

    Grows a band around the a|b boundary sized so that *any* s-t cut inside
    it keeps both blocks within the balance constraint, then replaces the
    boundary with the min cut.
    """
    part = np.asarray(part, dtype=np.int64)
    k = int(part.max()) + 1
    total = g.total_vwgt()
    lmax = (1.0 + eps) * np.ceil(total / k)
    in_pair = (part == a) | (part == b)
    src = g.edge_sources()
    # boundary nodes of the pair
    bmask = np.zeros(g.n, dtype=bool)
    cutedges = in_pair[src] & in_pair[g.adjncy] & (part[src] != part[g.adjncy])
    bmask[src[cutedges]] = True
    if not bmask.any():
        return part
    wa = int(g.vwgt[part == a].sum())
    wb = int(g.vwgt[part == b].sum())
    # budget: how much weight may cross either way
    slack_a = lmax - wa      # room in a
    slack_b = lmax - wb
    band = bmask.copy()
    # BFS out `band_depth` steps inside each block, capped by slack so every
    # cut in the band is feasible (moving whole band-side stays within lmax)
    for side, slack in ((a, slack_b), (b, slack_a)):
        depth_mask = bmask & (part == side)
        wsum = int(g.vwgt[depth_mask].sum())
        cur = depth_mask
        for _ in range(band_depth):
            nxt = np.zeros(g.n, dtype=bool)
            hits = cur[src] & (part[g.adjncy] == side) & ~band[g.adjncy] & ~cur[g.adjncy]
            nxt[g.adjncy[hits]] = True
            add_ids = np.flatnonzero(nxt)
            order = np.argsort(g.vwgt[add_ids])  # cheap nodes first
            for i in add_ids[order]:
                if wsum + int(g.vwgt[i]) > slack or band.sum() > max_band:
                    break
                band[i] = True
                wsum += int(g.vwgt[i])
            cur = nxt & band
            if not cur.any():
                break
    ids = np.flatnonzero(band)
    if len(ids) > max_band:
        return part
    remap = -np.ones(g.n, dtype=np.int64)
    remap[ids] = np.arange(len(ids))
    nv = len(ids) + 2
    S, T = len(ids), len(ids) + 1
    edges = []
    inside = band[src] & band[g.adjncy]
    fwd = inside & (src < g.adjncy)
    for e in np.flatnonzero(fwd):
        u, v, w = remap[src[e]], remap[g.adjncy[e]], int(g.adjwgt[e])
        edges.append([u, v, w])
        edges.append([v, u, w])
    big = int(g.adjwgt.sum()) + 1
    # attach S to band nodes adjacent to non-band a-side, T to b-side
    touch_a = band[src] & ~band[g.adjncy] & (part[g.adjncy] == a)
    touch_b = band[src] & ~band[g.adjncy] & (part[g.adjncy] == b)
    for u in np.unique(src[touch_a]):
        edges.append([S, remap[u], big])
    for u in np.unique(src[touch_b]):
        edges.append([remap[u], T, big])
    flow, sside = _dinic(nv, edges, S, T)
    new_part = part.copy()
    new_part[ids] = np.where(sside[:len(ids)], a, b)
    # accept only if feasible and not worse
    bw = np.zeros(k, dtype=np.int64)
    np.add.at(bw, new_part, g.vwgt)
    if bw.max() > lmax + 1e-9:
        return part
    if edge_cut(g, new_part) <= edge_cut(g, part):
        return new_part
    return part


def flow_refine_all_pairs(g: Graph, part: np.ndarray, k: int, eps: float,
                          max_n: int = 20000, seed: int = 0) -> np.ndarray:
    """Apply pairwise flow refinement over all adjacent block pairs."""
    if g.n > max_n:
        return part
    part = np.asarray(part, dtype=np.int64)
    src = g.edge_sources()
    for a in range(k):
        for b in range(a + 1, k):
            touching = np.any((part[src] == a) & (part[g.adjncy] == b))
            if touching:
                part = flow_refine_pair(g, part, a, b, eps)
    return part
