"""Node separators (paper §2.8).

2-way: partition with KaFFPa, then extract the *smallest* separator
obtainable from boundary nodes — a minimum vertex cover of the bipartite
graph of cut edges (Pothen et al. [27]; König: min-VC = max-matching).

k-way: the ``partition_to_vertex_separator`` program — apply the pairwise
construction between all pairs of blocks that share a boundary; the union of
the pairwise separators is a k-way separator.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.csr import Graph
from repro.core.kaffpa import kaffpa


def _bipartite_min_vertex_cover(left: np.ndarray, right: np.ndarray,
                                edges: list) -> Tuple[set, set]:
    """König construction. ``edges``: list of (li, ri) index pairs into
    left/right.  Returns (cover_left_idx, cover_right_idx)."""
    nl, nr = len(left), len(right)
    adj = [[] for _ in range(nl)]
    for (li, ri) in edges:
        adj[li].append(ri)
    match_l = -np.ones(nl, dtype=np.int64)
    match_r = -np.ones(nr, dtype=np.int64)

    def try_kuhn(u, seen):
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                if match_r[v] < 0 or try_kuhn(match_r[v], seen):
                    match_l[u] = v
                    match_r[v] = u
                    return True
        return False

    import sys
    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(10000, nl + nr + 100))
    try:
        for u in range(nl):
            try_kuhn(u, np.zeros(nr, dtype=bool))
    finally:
        sys.setrecursionlimit(old)

    # König: Z = unmatched-L ∪ reachable via alternating paths
    visited_l = match_l < 0
    visited_r = np.zeros(nr, dtype=bool)
    queue = list(np.flatnonzero(visited_l))
    while queue:
        u = queue.pop()
        for v in adj[u]:
            if not visited_r[v]:
                visited_r[v] = True
                w = match_r[v]
                if w >= 0 and not visited_l[w]:
                    visited_l[w] = True
                    queue.append(int(w))
    cover_l = set(np.flatnonzero(~visited_l).tolist())
    cover_r = set(np.flatnonzero(visited_r).tolist())
    return cover_l, cover_r


def separator_from_partition_pair(g: Graph, part: np.ndarray, a: int,
                                  b: int) -> np.ndarray:
    """Minimum boundary-vertex-cover separator for the (a, b) cut."""
    src = g.edge_sources()
    cut = (part[src] == a) & (part[g.adjncy] == b)
    if not cut.any():
        return np.zeros(0, dtype=np.int64)
    u = src[cut]
    v = g.adjncy[cut]
    left, linv = np.unique(u, return_inverse=True)
    right, rinv = np.unique(v, return_inverse=True)
    cov_l, cov_r = _bipartite_min_vertex_cover(
        left, right, list(zip(linv.tolist(), rinv.tolist())))
    return np.concatenate([left[sorted(cov_l)], right[sorted(cov_r)]])


def partition_to_vertex_separator(g: Graph, part: np.ndarray,
                                  k: int) -> np.ndarray:
    """The ``partition_to_vertex_separator`` program (k > 2)."""
    seps = []
    src = g.edge_sources()
    for a in range(k):
        for b in range(a + 1, k):
            if np.any((part[src] == a) & (part[g.adjncy] == b)):
                seps.append(separator_from_partition_pair(g, part, a, b))
    if not seps:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.concatenate(seps))


def node_separator(g: Graph, eps: float = 0.20, preset: str = "strong",
                   seed: int = 0, part: np.ndarray = None) -> tuple:
    """The ``node_separator`` program (2-way, §4.4.2).

    Returns (separator_ids, part2) where part2 is the underlying bipartition.
    """
    if part is None:
        part = kaffpa(g, 2, eps, preset, seed=seed)
    sep = partition_to_vertex_separator(g, part, 2)
    # trivial fallback: smaller boundary side (the paper's baseline §2.8)
    src = g.edge_sources()
    cutedge = part[src] != part[g.adjncy]
    b0 = np.unique(src[cutedge & (part[src] == 0)])
    b1 = np.unique(src[cutedge & (part[src] == 1)])
    trivial = b0 if len(b0) <= len(b1) else b1
    if len(trivial) and (len(sep) == 0 or len(trivial) < len(sep)):
        sep = trivial
    return sep, part


def verify_separator(g: Graph, part: np.ndarray, sep: np.ndarray,
                     k: int) -> bool:
    """No edge may run between distinct blocks once S is removed, AND
    removing S must actually disconnect the blocks: no connected component
    of G − S may contain vertices of two distinct blocks.  The component
    sweep asserts the disconnection property directly; it is implied by the
    edge check (a mixed component must contain a cross-block edge), so it
    is belt-and-braces — a second, independent implementation of the
    guarantee rather than a stronger one."""
    part = np.asarray(part, dtype=np.int64)
    in_sep = np.zeros(g.n, dtype=bool)
    in_sep[np.asarray(sep, dtype=np.int64)] = True
    src = g.edge_sources()
    ok = in_sep[src] | in_sep[g.adjncy] | (part[src] == part[g.adjncy])
    if not np.all(ok):
        return False
    # connected components of G - S via label propagation to the minimum id
    comp = np.where(in_sep, -1, np.arange(g.n))
    alive = ~in_sep[src] & ~in_sep[g.adjncy]
    u, v = src[alive], g.adjncy[alive]
    while True:
        nxt = comp.copy()
        np.minimum.at(nxt, u, comp[v])
        if np.array_equal(nxt, comp):
            break
        comp = nxt
    for c in np.unique(comp[comp >= 0]):
        members = comp == c
        if len(np.unique(part[members])) > 1:
            return False
    return True
