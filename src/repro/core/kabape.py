"""KaBaPE — strictly balanced refinement via negative cycles (paper §2.3).

The balance constraint is relaxed per *move* but maintained globally by
combining moves: build the directed *block-gain graph* where arc (a → b)
carries cost = −(best single-node gain of moving some node from block a to
block b).  A negative-cost cycle is a set of moves that strictly decreases
the cut while every block's weight is unchanged (each block on the cycle
loses and gains one node) — for unit node weights exactly, for weighted
nodes up to a feasibility check.  Efficient negative-cycle detection =
Bellman–Ford on k nodes (k is small).

The *balancing* variant finds a min-cost path from an overloaded block to an
underloaded one — this is what lets KaBaPE guarantee feasible output where
Metis/Scotch/Jostle cannot (§2.3).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.core.csr import Graph, to_coo
from repro.core import lp as lp_mod
from repro.core.partition import edge_cut, block_weights, is_feasible


def _gain_matrix(g: Graph, part: np.ndarray, k: int, coo=None):
    """best_gain[a, b], best_node[a, b]: best single-node move a→b."""
    coo = coo if coo is not None else to_coo(g)
    lab = np.zeros(coo.n_pad, dtype=np.int32)
    lab[:g.n] = part
    aff = np.asarray(lp_mod.kway_affinity_coo(coo, jnp.asarray(lab), k))[:g.n]
    own = aff[np.arange(g.n), part]
    gain = aff - own[:, None]                       # (n, k)
    best_gain = np.full((k, k), -np.inf)
    best_node = -np.ones((k, k), dtype=np.int64)
    for a in range(k):
        ids = np.flatnonzero(part == a)
        if len(ids) == 0:
            continue
        ga = gain[ids]                              # (na, k)
        arg = np.argmax(ga, axis=0)
        best_gain[a] = ga[arg, np.arange(k)]
        best_node[a] = ids[arg]
        best_gain[a, a] = -np.inf
    return best_gain, best_node


def _bellman_ford_negative_cycle(cost: np.ndarray) -> Optional[list]:
    """Return a negative cycle (list of node ids) in the dense digraph, or
    None.  cost[a, b] = arc cost (np.inf = absent)."""
    k = cost.shape[0]
    dist = np.zeros(k)
    pred = -np.ones(k, dtype=np.int64)
    x = -1
    for _ in range(k):
        x = -1
        for a in range(k):
            for b in range(k):
                if np.isfinite(cost[a, b]) and dist[a] + cost[a, b] < dist[b] - 1e-9:
                    dist[b] = dist[a] + cost[a, b]
                    pred[b] = a
                    x = b
        if x < 0:
            return None
    # x is on or reachable from a negative cycle; walk back k steps
    for _ in range(k):
        x = pred[x]
    cyc = [x]
    v = pred[x]
    while v != x:
        cyc.append(v)
        v = pred[v]
    cyc.reverse()
    return cyc


def negative_cycle_refine(g: Graph, part: np.ndarray, k: int, eps: float,
                          max_iters: int = 50) -> np.ndarray:
    """Apply negative-cycle move combinations until none remain."""
    part = np.asarray(part, dtype=np.int64).copy()
    coo = to_coo(g)
    total = g.total_vwgt()
    lmax = (1.0 + eps) * np.ceil(total / k)
    for _ in range(max_iters):
        bg, bn = _gain_matrix(g, part, k, coo)
        cost = np.where(np.isfinite(bg), -bg, np.inf)
        # arcs with no movable node are absent
        cyc = _bellman_ford_negative_cycle(cost)
        if cyc is None:
            return part
        cand = part.copy()
        for i, a in enumerate(cyc):
            b = cyc[(i + 1) % len(cyc)]
            v = bn[a, b]
            if v < 0:
                break
            cand[v] = b
        else:
            bw = block_weights(g, cand, k)
            if (bw.max() <= lmax + 1e-9
                    and edge_cut(g, cand) < edge_cut(g, part)):
                part = cand
                continue
        return part
    return part


def balance_path(g: Graph, part: np.ndarray, k: int, eps: float,
                 max_iters: int = 200) -> np.ndarray:
    """Make an infeasible partition feasible via min-cost gain paths from
    overloaded to underloaded blocks (the KaBaPE balancing variant)."""
    part = np.asarray(part, dtype=np.int64).copy()
    coo = to_coo(g)
    total = g.total_vwgt()
    lmax = np.ceil((1.0 + eps) * np.ceil(total / k))
    for _ in range(max_iters):
        bw = block_weights(g, part, k)
        over = np.flatnonzero(bw > lmax)
        if len(over) == 0:
            return part
        a0 = int(over[np.argmax(bw[over])])
        bg, bn = _gain_matrix(g, part, k, coo)
        cost = np.where(np.isfinite(bg), -bg, np.inf)
        # hop-bounded DP (≤ k arcs): costs are negative (gains), so plain
        # Bellman-Ford pred-chains may loop — the hop index makes it a DAG.
        dp = np.full((k + 1, k), np.inf)
        pred = -np.ones((k + 1, k), dtype=np.int64)
        dp[0, a0] = 0.0
        for h in range(1, k + 1):
            dp[h] = dp[h - 1]
            pred[h] = -1
            for a in range(k):
                if not np.isfinite(dp[h - 1, a]):
                    continue
                for b in range(k):
                    if np.isfinite(cost[a, b]) and dp[h - 1, a] + cost[a, b] < dp[h, b] - 1e-12:
                        dp[h, b] = dp[h - 1, a] + cost[a, b]
                        pred[h, b] = a
        under = np.flatnonzero(bw < lmax)
        cand = [(dp[h, b], h, b) for h in range(1, k + 1) for b in under
                if np.isfinite(dp[h, b]) and pred[h, b] >= 0]
        if not cand:
            return part  # cannot balance further
        _, h0, b0 = min(cand)
        # reconstruct hop-indexed path a0 → ... → b0 and apply the moves
        path = [b0]
        h, v = h0, b0
        while h > 0:
            if pred[h, v] >= 0:
                v = int(pred[h, v])
                path.append(v)
            h -= 1                      # pred == -1 ⇒ dp copied from h-1
        path.reverse()
        if len(set(path)) != len(path) or path[0] != a0:
            # the DP found a *walk* through a negative cycle — fall back to
            # the direct arc a0 → cheapest underloaded block (always simple,
            # guaranteed progress)
            direct = [u for u in under if np.isfinite(cost[a0, u])]
            if not direct:
                return part
            b0 = int(min(direct, key=lambda u: cost[a0, u]))
            path = [a0, b0]
        for i in range(len(path) - 1):
            a, b = path[i], path[i + 1]
            node = bn[a, b]
            if node >= 0:
                part[node] = b
    return part


def kabapeE(g: Graph, k: int, eps: float = 0.03, preset: str = "fast",
            n_islands: int = 4, population: int = 4,
            time_limit: float = 10.0, seed: int = 0,
            internal_bal: float = 0.01, **kwargs) -> np.ndarray:
    """The memetic KaBaPE program: the same island driver as ``kaffpaE``
    (core/memetic) with the negative-cycle polish on every child and the
    balanced replacement rule (infeasible members are evicted first), so
    the archipelago converges to strictly balanced partitions."""
    from repro.core.evolve import kaffpaE
    return kaffpaE(g, k, eps, preset, n_islands=n_islands,
                   population=population, time_limit=time_limit, seed=seed,
                   enable_kabape=True, kabaE_internal_bal=internal_bal,
                   **kwargs)


def kabape_refine(g: Graph, part: np.ndarray, k: int, eps: float = 0.0,
                  internal_bal: float = 0.01, rounds: int = 3,
                  seed: int = 0) -> np.ndarray:
    """Full KaBaPE polish: relax to ``internal_bal``, explore, re-balance,
    then eliminate negative cycles at the strict constraint."""
    from repro.core import refine as R
    part = np.asarray(part, dtype=np.int64)
    for r in range(rounds):
        # relaxed local search (larger neighbourhood, §2.3)
        part = R.refine_kway(g, part, k, eps + internal_bal,
                             rounds=8, seed=seed + r)
        part = balance_path(g, part, k, eps)
        part = negative_cycle_refine(g, part, k, eps)
        if is_feasible(g, part, k, eps):
            break
    if not is_feasible(g, part, k, eps):
        part = balance_path(g, part, k, eps, max_iters=500)
    return part
