"""Exact solver and ILP-style improvement (paper §2.10, §4.9).

Gurobi is not available offline, so the *model* construction (the paper's
actual contribution — shrink the instance so an exact solver scales) is kept
and the backend is an exact branch-and-bound with the paper's symmetry
breaking (block ids are interchangeable → a node may only open block
``max_used + 1``; ``overlap`` presets additionally fix seed vertices).

``ilp_exact``  : exact minimum-cut balanced partition of (small) graphs.
``ilp_improve``: extract a local model around high-gain/boundary vertices
(modes boundary|gain|trees), contract the remainder into k fixed terminals,
solve the model exactly, accept if the cut improves (never worse).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.csr import Graph
from repro.core.partition import edge_cut, block_weights, is_feasible


def _exact_bb(g: Graph, k: int, lmax: float, fixed: Optional[np.ndarray],
              timeout: float = 60.0, ub: float = np.inf):
    """Branch-and-bound exact partitioner.

    fixed[v] = block id or -1 (free).  Returns (best_part, best_cut) or
    (None, ub) if nothing beats ub.  Symmetry breaking: a free node may use
    at most one block beyond those already opened.
    """
    n = g.n
    order = np.argsort(-g.degrees(), kind="stable")  # high degree first
    order = np.concatenate([order[fixed[order] >= 0],
                            order[fixed[order] < 0]]) if fixed is not None \
        else order
    adj = [(g.neighbors(v), g.edge_weights(v)) for v in range(n)]
    part = -np.ones(n, dtype=np.int64)
    sizes = np.zeros(k, dtype=np.int64)
    best = {"cut": ub, "part": None}
    t0 = time.monotonic()

    def lower_bound(idx, cur_cut):
        return cur_cut            # admissible (edges only counted when both set)

    def rec(idx, cur_cut, max_used):
        if time.monotonic() - t0 > timeout:
            return
        if cur_cut >= best["cut"]:
            return
        if idx == n:
            best["cut"] = cur_cut
            best["part"] = part.copy()
            return
        v = order[idx]
        if fixed is not None and fixed[v] >= 0:
            blocks = [int(fixed[v])]
        else:
            blocks = list(range(min(max_used + 1, k - 1) + 1))
        nbrs, ws = adj[v]
        # try blocks in order of least added cut (best-first)
        added = []
        for b in blocks:
            if sizes[b] + g.vwgt[v] > lmax:
                continue
            delta = int(sum(w for u, w in zip(nbrs, ws)
                            if part[u] >= 0 and part[u] != b))
            added.append((delta, b))
        added.sort()
        for delta, b in added:
            part[v] = b
            sizes[b] += g.vwgt[v]
            rec(idx + 1, cur_cut + delta,
                max(max_used, b))
            sizes[b] -= g.vwgt[v]
            part[v] = -1

    rec(0, 0, -1)
    return best["part"], best["cut"]


def ilp_exact(g: Graph, k: int, eps: float = 0.03, timeout: float = 60.0,
              seed: int = 0) -> np.ndarray:
    """Exact balanced min-cut partition (use on small graphs / models)."""
    lmax = (1.0 + eps) * np.ceil(g.total_vwgt() / k)
    # warm start with kaffpa for a good upper bound
    from repro.core.kaffpa import kaffpa
    warm = kaffpa(g, k, eps, "fast", seed=seed)
    ub = edge_cut(g, warm) + 1
    part, cut = _exact_bb(g, k, lmax, None, timeout, ub)
    return part if part is not None else warm


def build_model(g: Graph, part: np.ndarray, k: int,
                mode: str = "boundary", min_gain: int = -1,
                bfs_depth: int = 2, limit_nonzeroes: int = 5_000_000,
                max_free: int = 18) -> tuple:
    """The paper's *model* graph: free vertices (BFS balls around selected
    boundary/gain vertices) + k contracted fixed terminals.

    Returns (model graph, fixed array, free_old_ids).
    """
    src = g.edge_sources()
    boundary = np.unique(src[part[src] != part[g.adjncy]])
    if mode == "gain" and len(boundary):
        # gain of best single move per boundary vertex
        gains = []
        for v in boundary:
            nbrs, ws = g.neighbors(v), g.edge_weights(v)
            own = int(ws[part[nbrs] == part[v]].sum())
            bestx = 0
            for b in np.unique(part[nbrs]):
                if b != part[v]:
                    bestx = max(bestx, int(ws[part[nbrs] == b].sum()))
            gains.append(bestx - own)
        boundary = boundary[np.asarray(gains) >= min_gain]
    sel = set(boundary.tolist())
    frontier = set(boundary.tolist())
    for _ in range(bfs_depth - 1):
        nxt = set()
        for v in frontier:
            nxt.update(g.neighbors(v).tolist())
        nxt -= sel
        sel.update(nxt)
        frontier = nxt
    free = np.asarray(sorted(sel), dtype=np.int64)[:max_free]
    # every block must keep at least one contracted (terminal) node
    freemask = np.isin(np.arange(g.n), free)
    if len(np.unique(part[~freemask])) < k:
        return None, None, np.zeros(0, dtype=np.int64)
    # contract everything else into k terminals
    cl = np.where(freemask,
                  k + np.searchsorted(free, np.arange(g.n)),
                  part)
    from repro.core.coarsen import contract
    model, clmap = contract(g, cl)
    # terminals are the first k coarse ids (cluster ids 0..k-1 sort first)
    fixed = -np.ones(model.n, dtype=np.int64)
    fixed[:k] = np.arange(k)
    return model, fixed, free


def ilp_improve(g: Graph, part: np.ndarray, k: int, eps: float = 0.03,
                mode: str = "boundary", min_gain: int = -1,
                bfs_depth: int = 2, timeout: float = 60.0,
                seed: int = 0) -> np.ndarray:
    """Improve ``part`` by exactly solving the local model (never worse)."""
    part = np.asarray(part, dtype=np.int64)
    model, fixed, free = build_model(g, part, k, mode, min_gain, bfs_depth)
    if model is None or len(free) == 0:
        return part
    lmax = (1.0 + eps) * np.ceil(g.total_vwgt() / k)
    warm_cut = edge_cut(model, np.concatenate(
        [np.arange(k), part[free]]))
    mp, cut = _exact_bb(model, k, lmax, fixed, timeout, warm_cut + 1)
    if mp is None:
        return part
    out = part.copy()
    out[free] = mp[k:]
    if (edge_cut(g, out) <= edge_cut(g, part)
            and is_feasible(g, out, k, eps)):
        return out
    return part
