"""KaFFPa — the multilevel partitioner (paper §2.1, §4.1).

Preconfigurations follow the paper's use-case table: {fast, eco, strong} for
mesh-like graphs (matching coarsening) and {fastsocial, ecosocial,
strongsocial} for social networks (size-constrained LP coarsening, §2.4).

`strong` additionally runs pairwise max-flow refinement on small levels and
an iterated V-cycle with cut-edge-protected re-coarsening (§2.1, Walshaw
iterated multilevel — quality is non-decreasing because refinement never
worsens and protected coarsening keeps the current partition representable).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.csr import Graph, to_coo
from repro.core import coarsen as C
from repro.core import initial as I
from repro.core import refine as R
from repro.core.partition import edge_cut, is_feasible, block_weights


@dataclasses.dataclass
class KaffpaConfig:
    coarsening: str = "matching"        # matching | lp
    lp_iters: int = 8
    refine_rounds: int = 10
    multi_try: int = 0                  # localized-search restarts per level
    use_flow: bool = False              # pairwise max-flow refinement
    flow_max_n: int = 6000
    initial_tries: int = 4
    vcycles: int = 1                    # iterated multilevel cycles
    contraction_stop_factor: int = 40   # stop coarsening at ~factor*k nodes
    cluster_weight_factor: float = 3.0  # max cluster weight = W/(factor*k)


PRESETS = {
    "fast":         KaffpaConfig(coarsening="matching", refine_rounds=6,
                                 initial_tries=2),
    "eco":          KaffpaConfig(coarsening="matching", refine_rounds=10,
                                 multi_try=2, initial_tries=4),
    "strong":       KaffpaConfig(coarsening="matching", refine_rounds=14,
                                 multi_try=3, use_flow=True, initial_tries=6,
                                 vcycles=2),
    "fastsocial":   KaffpaConfig(coarsening="lp", refine_rounds=6,
                                 initial_tries=2),
    "ecosocial":    KaffpaConfig(coarsening="lp", refine_rounds=10,
                                 multi_try=2, initial_tries=4),
    "strongsocial": KaffpaConfig(coarsening="lp", refine_rounds=14,
                                 multi_try=3, use_flow=True, initial_tries=6,
                                 vcycles=2),
}


def _build_hierarchy(g: Graph, k: int, cfg: KaffpaConfig, seed: int,
                     forbidden: Optional[np.ndarray] = None):
    """Coarsen until ~contraction_stop_factor*k nodes; returns level list.

    levels = [(g0, None), (g1, cl0), ...] where cl maps level-i nodes to
    level-(i+1) nodes.
    """
    levels = [(g, None)]
    cur, cur_forbidden = g, forbidden
    stop_n = max(cfg.contraction_stop_factor * k, 64)
    lvl = 0
    while cur.n > stop_n:
        max_cw = max(1.0, cur.total_vwgt() / (cfg.cluster_weight_factor * k))
        res = C.coarsen_level(cur, "lp" if cfg.coarsening == "lp" else "matching",
                              max_cw, seed + 31 * lvl, forbidden=cur_forbidden)
        if res is None:
            break
        coarse, cl = res
        levels.append((coarse, cl))
        if cur_forbidden is not None:
            # push the protected-edge mask to the coarse level
            src = coarse.edge_sources()
            # recompute from scratch: an edge (cu, cv) is protected iff any
            # protected fine edge maps onto it
            fsrc = cur.edge_sources()
            pko = cur_forbidden & (cl[fsrc] != cl[cur.adjncy])
            prot_pairs = set(zip(cl[fsrc[pko]].tolist(),
                                 cl[cur.adjncy[pko]].tolist()))
            cur_forbidden = np.fromiter(
                ((int(a), int(b)) in prot_pairs
                 for a, b in zip(src, coarse.adjncy)),
                dtype=bool, count=len(coarse.adjncy))
        cur = coarse
        lvl += 1
    return levels


def _uncoarsen(levels, part_coarse: np.ndarray, k: int, eps: float,
               cfg: KaffpaConfig, seed: int) -> np.ndarray:
    part = part_coarse
    for li in range(len(levels) - 1, 0, -1):
        g_fine, _ = levels[li - 1]
        _, cl = levels[li]
        part = C.project(part, cl)
        part = _refine_level(g_fine, part, k, eps, cfg, seed + li)
    return part


def _refine_level(g: Graph, part: np.ndarray, k: int, eps: float,
                  cfg: KaffpaConfig, seed: int) -> np.ndarray:
    coo = to_coo(g)
    force = not is_feasible(g, part, k, eps)
    part = R.refine_kway(g, part, k, eps, rounds=cfg.refine_rounds,
                         seed=seed, coo=coo, force_balance=force)
    if cfg.multi_try:
        part = R.multi_try_refine(g, part, k, eps, tries=cfg.multi_try,
                                  rounds=max(4, cfg.refine_rounds // 2),
                                  seed=seed, coo=coo)
    if cfg.use_flow and g.n <= cfg.flow_max_n and k <= 16:
        part = R.flow_refine_all_pairs(g, part, k, eps, seed=seed)
    return part


def _initial_partition(g: Graph, k: int, eps: float, cfg: KaffpaConfig,
                       seed: int) -> np.ndarray:
    def refine2(sub: Graph, two: np.ndarray, frac0: float) -> np.ndarray:
        fr = np.asarray([frac0, 1.0 - frac0])
        return R.refine_kway(sub, two, 2, eps, rounds=cfg.refine_rounds,
                             seed=seed, fractions=fr)
    best, best_cut = None, np.inf
    for t in range(cfg.initial_tries):
        part = I.recursive_bisection(g, k, seed=seed + 101 * t,
                                     refine_fn=refine2 if g.n <= 20000 else None)
        part = _refine_level(g, part, k, eps, cfg, seed + t)
        c = edge_cut(g, part)
        if c < best_cut and is_feasible(g, part, k, eps):
            best, best_cut = part, c
        elif best is None:
            best = part
    return best


def multilevel_partition(g: Graph, k: int, eps: float, cfg: KaffpaConfig,
                         seed: int) -> np.ndarray:
    levels = _build_hierarchy(g, k, cfg, seed)
    g_c, _ = levels[-1]
    part_c = _initial_partition(g_c, k, eps, cfg, seed)
    return _uncoarsen(levels, part_c, k, eps, cfg, seed)


def vcycle(g: Graph, part: np.ndarray, k: int, eps: float, cfg: KaffpaConfig,
           seed: int) -> np.ndarray:
    """Iterated multilevel: re-coarsen protecting the current partition's cut
    edges, use it as the coarsest initial partition, refine on the way up.
    Quality is non-decreasing (§2.1)."""
    src = g.edge_sources()
    forbidden = part[src] != part[g.adjncy]
    levels = _build_hierarchy(g, k, cfg, seed, forbidden=forbidden)
    # project the current partition down the protected hierarchy
    part_c = part
    for li in range(1, len(levels)):
        _, cl = levels[li]
        # all members of a cluster share a block (cut edges were protected)
        nc = levels[li][0].n
        pc = np.zeros(nc, dtype=np.int64)
        pc[cl] = part_c
        part_c = pc
    part_c = _refine_level(levels[-1][0], part_c, k, eps, cfg, seed)
    out = _uncoarsen(levels, part_c, k, eps, cfg, seed)
    if edge_cut(g, out) <= edge_cut(g, part) and is_feasible(g, out, k, eps):
        return out
    return part


def kaffpa(g: Graph, k: int, eps: float = 0.03, preset: str = "eco",
           seed: int = 0, time_limit: float = 0.0,
           input_partition: Optional[np.ndarray] = None,
           enforce_balance: bool = False,
           balance_edges: bool = False) -> np.ndarray:
    """The ``kaffpa`` program (paper §4.1)."""
    if balance_edges:
        g = g.with_edge_balanced_weights()
    cfg = PRESETS[preset]
    if k <= 1:
        return np.zeros(g.n, dtype=np.int64)
    t0 = time.monotonic()
    if input_partition is not None:
        best = np.asarray(input_partition, dtype=np.int64)
        best = _refine_level(g, best, k, eps, cfg, seed)
    else:
        best = multilevel_partition(g, k, eps, cfg, seed)
    for cyc in range(1, cfg.vcycles):
        best = vcycle(g, best, k, eps, cfg, seed + 7919 * cyc)
    # repeated calls under a time budget (paper --time_limit)
    trial = 1
    while time_limit > 0 and time.monotonic() - t0 < time_limit:
        cand = multilevel_partition(g, k, eps, cfg, seed + 104729 * trial)
        if (edge_cut(g, cand) < edge_cut(g, best)
                and is_feasible(g, cand, k, eps)):
            best = cand
        trial += 1
    if enforce_balance and not is_feasible(g, best, k, eps):
        best = R.refine_kway(g, best, k, eps, rounds=30, seed=seed,
                             force_balance=True)
    return best
