"""KaFFPa — the multilevel partitioner (paper §2.1, §4.1).

Preconfigurations follow the paper's use-case table: {fast, eco, strong} for
mesh-like graphs (matching coarsening) and {fastsocial, ecosocial,
strongsocial} for social networks (size-constrained LP coarsening, §2.4).

`strong` additionally runs pairwise max-flow refinement on small levels and
an iterated V-cycle with cut-edge-protected re-coarsening (§2.1, Walshaw
iterated multilevel — quality is non-decreasing because refinement never
worsens and protected coarsening keeps the current partition representable).

Since PR 2 the multilevel loop itself lives in the shared engine
(core/multilevel.py); this module provides the graph `Medium` adapter and
the ``kaffpa`` program entry.  The engine owns per-level device views: the
COO (and ELL, when the Pallas kernel path is active) views are built once
per hierarchy level and reused across refinement rounds, initial tries,
V-cycles and time-budget restarts.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.csr import Graph, to_coo, to_ell
from repro.core import coarsen as C
from repro.core import initial as I
from repro.core import multilevel as ML
from repro.core import refine as R
from repro.core.partition import edge_cut, is_feasible


@dataclasses.dataclass
class KaffpaConfig:
    coarsening: str = "matching"        # matching | lp
    lp_iters: int = 8
    refine_rounds: int = 10
    multi_try: int = 0                  # localized-search restarts per level
    use_flow: bool = False              # pairwise max-flow refinement
    flow_max_n: int = 6000
    initial_tries: int = 4
    vcycles: int = 1                    # iterated multilevel cycles
    contraction_stop_factor: int = 40   # stop coarsening at ~factor*k nodes
    cluster_weight_factor: float = 3.0  # max cluster weight = W/(factor*k)
    stop_n_floor: int = 64              # never coarsen below this many nodes
    use_kernel: Optional[bool] = None   # None = Pallas on TPU, COO fallback

    @property
    def batch_floor(self) -> int:
        """Shared pow2 batch bucket (DESIGN.md §12): single refines pad up
        to the tournament width so both run one compiled program."""
        from repro.core.csr import _pow2_pad
        return _pow2_pad(max(self.initial_tries, 1), 1)


PRESETS = {
    "fast":         KaffpaConfig(coarsening="matching", refine_rounds=6,
                                 initial_tries=2),
    "eco":          KaffpaConfig(coarsening="matching", refine_rounds=10,
                                 multi_try=2, initial_tries=4),
    "strong":       KaffpaConfig(coarsening="matching", refine_rounds=14,
                                 multi_try=3, use_flow=True, initial_tries=6,
                                 vcycles=2),
    "fastsocial":   KaffpaConfig(coarsening="lp", refine_rounds=6,
                                 initial_tries=2),
    "ecosocial":    KaffpaConfig(coarsening="lp", refine_rounds=10,
                                 multi_try=2, initial_tries=4),
    "strongsocial": KaffpaConfig(coarsening="lp", refine_rounds=14,
                                 multi_try=3, use_flow=True, initial_tries=6,
                                 vcycles=2),
}


class GraphMedium(ML.ViewCache):
    """The graph adapter for the shared multilevel engine.

    ``recorder`` (an ``obs.Recorder``) opts this medium's engine runs into
    observability; it rides ``EngineParams`` and survives contraction."""

    def __init__(self, g: Graph, cfg: KaffpaConfig, recorder=None):
        self.g = g
        self.cfg = cfg
        self.recorder = recorder
        self.use_kernel = (R.default_use_kernel() if cfg.use_kernel is None
                           else cfg.use_kernel)

    # -- structure ---------------------------------------------------------
    @property
    def n(self) -> int:
        return self.g.n

    @property
    def params(self) -> ML.EngineParams:
        cfg = self.cfg
        return ML.EngineParams(
            initial_tries=cfg.initial_tries, vcycles=cfg.vcycles,
            contraction_stop_factor=cfg.contraction_stop_factor,
            cluster_weight_factor=cfg.cluster_weight_factor,
            stop_n_floor=cfg.stop_n_floor, recorder=self.recorder)

    def total_vwgt(self) -> int:
        return self.g.total_vwgt()

    def cluster(self, max_cluster_weight: float, seed: int,
                protect: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
        g = self.g
        forbidden = None
        if protect:
            forbidden = ML.protect_cut_mask(g.edge_sources(), g.adjncy,
                                            protect)
        if self.cfg.coarsening == "lp":
            return C.lp_clustering(g, max_cluster_weight,
                                   iters=self.cfg.lp_iters, seed=seed,
                                   forbidden=forbidden)
        return C.heavy_edge_matching(g, seed=seed,
                                     max_cluster_weight=max_cluster_weight,
                                     forbidden=forbidden)

    def contract(self, clusters: np.ndarray):
        coarse, cl = C.contract(self.g, clusters)
        return GraphMedium(coarse, self.cfg, recorder=self.recorder), cl

    # -- device views ------------------------------------------------------
    def build_views(self):
        coo = to_coo(self.g)
        ell = to_ell(self.g, row_tile=coo.n_pad) if self.use_kernel else None
        return coo, ell

    # -- refinement --------------------------------------------------------
    def refine(self, part: np.ndarray, k: int, eps: float, seed: int,
               force_balance: Optional[bool] = None) -> np.ndarray:
        g, cfg = self.g, self.cfg
        coo, ell = self.views
        if force_balance is None:
            force_balance = not is_feasible(g, part, k, eps)
        out = R.refine_kway(g, part, k, eps, rounds=cfg.refine_rounds,
                            seed=seed, coo=coo, ell=ell,
                            use_kernel=self.use_kernel,
                            force_balance=force_balance,
                            batch_floor=cfg.batch_floor)
        rec = ML.recorder_of(self)
        if rec.enabled:
            rec.count("refine/rounds", cfg.refine_rounds)
            rec.count("refine/moves",
                      int(np.sum(out != np.asarray(part, dtype=np.int64))))
            if force_balance:
                rec.count("refine/forced_balance")
        return self.polish(out, k, eps, seed)

    def refine_batch(self, parts: Sequence[np.ndarray], k: int, eps: float,
                     seed: int, keys=None) -> List[np.ndarray]:
        coo, ell = self.views
        return R.refine_kway_batch(self.g, list(parts), k, eps,
                                   rounds=self.cfg.refine_rounds, seed=seed,
                                   coo=coo, ell=ell,
                                   use_kernel=self.use_kernel, keys=keys,
                                   batch_floor=self.cfg.batch_floor)

    def polish(self, part: np.ndarray, k: int, eps: float,
               seed: int) -> np.ndarray:
        g, cfg = self.g, self.cfg
        coo, _ = self.views
        if cfg.multi_try:
            part = R.multi_try_refine(g, part, k, eps, tries=cfg.multi_try,
                                      rounds=max(4, cfg.refine_rounds // 2),
                                      seed=seed, coo=coo,
                                      batch_floor=cfg.batch_floor,
                                      rounds_bucket=cfg.refine_rounds)
        if cfg.use_flow and g.n <= cfg.flow_max_n and k <= 16:
            part = R.flow_refine_all_pairs(g, part, k, eps, seed=seed)
        return part

    # -- initial partitioning ----------------------------------------------
    def initial_candidates(self, k: int, eps: float,
                           seed: int) -> List[np.ndarray]:
        g, cfg = self.g, self.cfg

        def refine2(sub: Graph, two: np.ndarray, frac0: float) -> np.ndarray:
            fr = np.asarray([frac0, 1.0 - frac0])
            return R.refine_kway(sub, two, 2, eps, rounds=cfg.refine_rounds,
                                 seed=seed, fractions=fr,
                                 batch_floor=cfg.batch_floor)

        fn = refine2 if g.n <= 20000 else None
        return [I.recursive_bisection(g, k, seed=seed + 101 * t, refine_fn=fn)
                for t in range(cfg.initial_tries)]

    # -- objective ---------------------------------------------------------
    def objective(self, part: np.ndarray) -> float:
        return float(edge_cut(self.g, part))

    def imbalance(self, part: np.ndarray, k: int) -> float:
        from repro.core.partition import balance
        return balance(self.g, part, k)

    def is_feasible(self, part: np.ndarray, k: int, eps: float) -> bool:
        return is_feasible(self.g, part, k, eps)


def multilevel_partition(g: Graph, k: int, eps: float, cfg: KaffpaConfig,
                         seed: int) -> np.ndarray:
    return ML.multilevel(GraphMedium(g, cfg), k, eps, seed)


def vcycle(g: Graph, part: np.ndarray, k: int, eps: float, cfg: KaffpaConfig,
           seed: int) -> np.ndarray:
    """Iterated multilevel: re-coarsen protecting the current partition's cut
    edges, use it as the coarsest initial partition, refine on the way up.
    Quality is non-decreasing (§2.1)."""
    return ML.vcycle(GraphMedium(g, cfg), part, k, eps, seed)


def kaffpa(g: Graph, k: int, eps: float = 0.03, preset: str = "eco",
           seed: int = 0, time_limit: float = 0.0,
           input_partition: Optional[np.ndarray] = None,
           enforce_balance: bool = False,
           balance_edges: bool = False, report=None) -> np.ndarray:
    """The ``kaffpa`` program (paper §4.1).

    ``report`` is an optional ``obs.Recorder`` capturing spans, counters
    and the per-cycle quality trajectory of this run (DESIGN.md §11)."""
    if balance_edges:
        g = g.with_edge_balanced_weights()
    cfg = PRESETS[preset]
    if k <= 1:
        return np.zeros(g.n, dtype=np.int64)
    medium = GraphMedium(g, cfg, recorder=report)
    best = ML.run(medium, k, eps, seed, time_limit=time_limit,
                  input_partition=input_partition)
    if enforce_balance and not is_feasible(g, best, k, eps):
        best = R.refine_kway(g, best, k, eps, rounds=30, seed=seed,
                             force_balance=True)
    return best
