"""ParHIP — distributed-memory parallel partitioning via shard_map (§2.5).

The MPI design of ParHIP maps onto JAX collectives (DESIGN.md §2):

  * nodes (and their out-edges) are block-distributed over the mesh axis
    ``nodes`` — exactly ParHIP's vertex distribution;
  * each LP round reads the *replicated* label vector (the ghost-label
    exchange becomes one all-gather inserted by SPMD partitioning), computes
    new labels for owned nodes only, and enforces the size constraint with a
    per-shard slice of the *global* remaining capacity (psum'd histogram) —
    so the constraint holds globally without a sequential arbiter;
  * cluster-size histograms and cut values are ``psum`` reductions.

The same round function serves both phases: clustering (labels over [0, n))
for coarsening and k-way refinement during uncoarsening.  Preconfigurations
{ultrafast,fast,eco}×{mesh,social} select rounds/iterations (§4.3.1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro import obs
from repro.core.csr import Graph, _pow2_pad
from repro.core import coarsen as C
from repro.core import kaffpa as K
from repro.core.partition import edge_cut, is_feasible

_NEG = -1e30
_NOISE = 1e-4
_GAIN_EPS = 1e-3


@dataclasses.dataclass
class ShardedGraph:
    """Host container: node-block-distributed COO (global ids)."""
    src: np.ndarray     # (S, emax) int32, padding points at row 0 w/ w=0
    dst: np.ndarray     # (S, emax) int32
    w: np.ndarray       # (S, emax) float32
    vwgt: np.ndarray    # (S, rows) float32
    n: int
    rows: int

    @property
    def n_shards(self) -> int:
        return self.src.shape[0]

    @property
    def n_pad(self) -> int:
        return self.n_shards * self.rows


def shard_graph(g: Graph, n_shards: int, row_mult: int = 8) -> ShardedGraph:
    n = g.n
    rows = _pow2_pad(max((n + n_shards - 1) // n_shards, 1), row_mult)
    n_pad = rows * n_shards
    src_h = g.edge_sources()
    owner = src_h // rows
    emax = int(np.bincount(owner, minlength=n_shards).max()) if len(src_h) else 1
    emax = _pow2_pad(max(emax, 1), 8)
    src = np.zeros((n_shards, emax), dtype=np.int32)
    dst = np.zeros((n_shards, emax), dtype=np.int32)
    w = np.zeros((n_shards, emax), dtype=np.float32)
    for s in range(n_shards):
        ids = np.flatnonzero(owner == s)
        src[s, :] = s * rows              # padding: own first row, w == 0
        dst[s, :] = s * rows
        src[s, :len(ids)] = src_h[ids]
        dst[s, :len(ids)] = g.adjncy[ids]
        w[s, :len(ids)] = g.adjwgt[ids]
    vw = np.zeros((n_shards, rows), dtype=np.float32)
    flat = np.zeros(n_pad, dtype=np.float32)
    flat[:n] = g.vwgt
    vw[:] = flat.reshape(n_shards, rows)
    return ShardedGraph(src, dst, w, vw, n, rows)


def _kway_round_local(src, dst, w, vwgt, labels, sizes_g, cap, key, parity,
                      rows: int, k: int, n_shards: int, axis: str):
    """Body run per shard under shard_map. labels: full replicated (n_pad,).

    Rank-2 inputs arrive as (1, ·) local blocks — flatten to local vectors.
    """
    src, dst, w, vwgt = (a.reshape(-1) for a in (src, dst, w, vwgt))
    me = jax.lax.axis_index(axis)
    off = me * rows
    lab_own = jax.lax.dynamic_slice(labels, (off,), (rows,))
    tgt = labels[dst]
    aff = jnp.zeros((rows, k), jnp.float32).at[src - off, tgt].add(w)
    noise = jax.random.uniform(jax.random.fold_in(key, me), (rows, k),
                               jnp.float32, 0.0, _NOISE)
    own = jnp.take_along_axis(aff, lab_own[:, None].astype(jnp.int32),
                              axis=1)[:, 0]
    gain = aff - own[:, None] + noise
    gain = gain.at[jnp.arange(rows), lab_own].set(_NEG)
    room = sizes_g[None, :] + vwgt[:, None] <= cap[None, :]
    gain = jnp.where(room, gain, _NEG)
    best_gain = jnp.max(gain, axis=1)
    best_tgt = jnp.argmax(gain, axis=1).astype(lab_own.dtype)
    gid = off + jnp.arange(rows)
    want = (best_gain > _GAIN_EPS) & ((gid + parity) % 2 == 0)
    proposal = jnp.where(want, best_tgt, lab_own)
    # local capped acceptance against this shard's slice of global capacity
    cap_local = sizes_g + (cap - sizes_g) / n_shards
    from repro.core.lp import capped_accept
    new_lab = capped_accept(lab_own, proposal, vwgt, sizes_g, cap_local,
                            jnp.where(want, best_gain, _NEG))
    return new_lab


@functools.partial(jax.jit,
                   static_argnames=("rows", "k", "rounds", "n_shards",
                                    "axis", "mesh"))
def _parhip_refine_jit(mesh: Mesh, src, dst, w, vwgt, labels0, cap, key,
                       rows: int, k: int, rounds: int, n_shards: int,
                       axis: str = "nodes"):
    spec_e = P(axis, None)
    spec_r = P()

    def sizes_of(labels):
        return jnp.zeros((k,), jnp.float32).at[labels].add(
            vwgt.reshape(-1))

    round_fn = shard_map(
        functools.partial(_kway_round_local, rows=rows, k=k,
                          n_shards=n_shards, axis=axis),
        mesh=mesh,
        in_specs=(spec_e, spec_e, spec_e, P(axis, None), spec_r, spec_r,
                  spec_r, spec_r, spec_r),
        out_specs=P(axis),
        check_vma=False,
    )

    def body(carry, key_r):
        labels, parity = carry
        sizes = sizes_of(labels)
        new_labels = round_fn(src, dst, w, vwgt, labels, sizes, cap, key_r,
                              parity)
        return (new_labels, parity + 1), jnp.int32(0)

    keys = jax.random.split(key, rounds)
    (labels, _), _ = jax.lax.scan(body, (labels0, jnp.int32(0)), keys)
    return labels


def parhip_refine(g: Graph, part: np.ndarray, k: int, eps: float,
                  mesh: Mesh, rounds: int = 8, seed: int = 0,
                  axis: str = "nodes") -> np.ndarray:
    """Distributed k-way LP refinement (never applied blindly: caller keeps
    the better of in/out)."""
    n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                            if a == axis]))
    rec = obs.current()
    sg = shard_graph(g, n_shards)
    labels0 = np.zeros(sg.n_pad, dtype=np.int32)
    labels0[:g.n] = part
    total = g.total_vwgt()
    cap = jnp.full((k,), (1.0 + eps) * np.ceil(total / k), jnp.float32)
    # vwgt reshaped flat for rows owned by shards; padding rows weight 0
    with rec.span("parhip_refine", n=g.n, rounds=rounds, shards=n_shards):
        out = _parhip_refine_jit(mesh, jnp.asarray(sg.src),
                                 jnp.asarray(sg.dst),
                                 jnp.asarray(sg.w), jnp.asarray(sg.vwgt),
                                 jnp.asarray(labels0), cap,
                                 jax.random.PRNGKey(seed), sg.rows, k,
                                 rounds, n_shards, axis)
        cand = np.asarray(out)[:g.n].astype(np.int64)
    rec.count("parhip/dist_rounds", rounds)
    rec.count("parhip/psum_rounds", rounds)   # one sizes-histogram psum/round
    if (edge_cut(g, cand) <= edge_cut(g, part)
            and is_feasible(g, cand, k, eps)):
        return cand
    rec.count("parhip/rounds_rejected")
    return part


PARHIP_PRESETS = {
    "ultrafastmesh":   dict(preset="fast", rounds=4),
    "fastmesh":        dict(preset="fast", rounds=8),
    "ecomesh":         dict(preset="eco", rounds=12),
    "ultrafastsocial": dict(preset="fastsocial", rounds=4),
    "fastsocial":      dict(preset="fastsocial", rounds=8),
    "ecosocial":       dict(preset="ecosocial", rounds=12),
}


def parhip(g: Graph, k: int, eps: float = 0.03,
           preconfiguration: str = "fastmesh", seed: int = 0,
           mesh: Optional[Mesh] = None,
           vertex_degree_weights: bool = False, report=None) -> np.ndarray:
    """The ``parhip`` program (§4.3.1).

    Host-orchestrated multilevel with the distributed LP round as the
    refinement engine at every level; the coarsest graph is partitioned by
    the (evolutionary-grade) sequential path, as in the paper.  ``report``
    is an optional ``obs.Recorder`` (DESIGN.md §11).
    """
    if vertex_degree_weights:
        g = Graph(g.xadj, g.adjncy, 1 + g.degrees(), g.adjwgt)
    pc = PARHIP_PRESETS[preconfiguration]
    cfg = K.PRESETS[pc["preset"]]
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), ("nodes",))
    from repro.core import multilevel as ML
    with obs.use(report):
        rec = obs.current()
        with rec.span("parhip", n=g.n, k=k,
                      preconfiguration=preconfiguration):
            levels = ML.build_hierarchy(K.GraphMedium(g, cfg), k, seed)
            part = ML.initial_partition(levels[-1], k, eps, seed)

            def refine_level(g_fine: Graph, part: np.ndarray,
                             li: int) -> np.ndarray:
                part = parhip_refine(g_fine, part, k, eps, mesh,
                                     rounds=pc["rounds"], seed=seed + li)
                if not is_feasible(g_fine, part, k, eps):
                    from repro.core import refine as R
                    part = R.refine_kway(g_fine, part, k, eps, rounds=6,
                                         seed=seed + li, force_balance=True)
                    rec.count("parhip/repairs")
                return part

            for li in range(len(levels) - 1, 0, -1):
                part = C.project(part, levels[li].cl)
                fine = levels[li - 1].medium.g
                with rec.span("parhip_level", level=li - 1, n=fine.n):
                    part = refine_level(fine, part, li)
                if rec.enabled:
                    rec.point("parhip", level=li - 1,
                              objective=float(edge_cut(fine, part)))
            if len(levels) == 1:
                # single-level hierarchy (n <= stop_n): the loop above is
                # empty — still run the distributed refiner and the
                # feasibility repair at level 0 instead of returning the raw
                # initial partition
                with rec.span("parhip_level", level=0, n=g.n):
                    part = refine_level(g, part, 0)
                if rec.enabled:
                    rec.point("parhip", level=0,
                              objective=float(edge_cut(g, part)))
    return part
