"""KaFFPaE / KaBaPE — the distributed evolutionary partitioner (paper §2.2).

Island model: every island keeps a population of partitions and applies
*combine* and *mutation* operators built from KaFFPa itself.

Combine (the paper's key operator): coarsening is modified so that no cut
edge of either parent is contracted — both parents stay representable at the
coarsest level, the better parent seeds the initial partition, and refinement
(which never worsens) assembles good parts of both.  Clusters are split by
the parents' block signatures before contraction, which *guarantees* the
invariant (DESIGN.md §2).

The MPI rumor-spreading exchange is modelled by the island topology: after
every generation each island pushes its best individual to a uniformly
random other island (exactly the randomized rumor-spreading step; with
shard_map islands this becomes a collective_permute — see parhip.py for the
collective formulation of the distributed phases).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core.csr import Graph
from repro.core import coarsen as C
from repro.core import kaffpa as K
from repro.core import refine as R
from repro.core.partition import edge_cut, is_feasible, comm_volume
from repro.core.kabape import kabape_refine


@dataclasses.dataclass
class Individual:
    part: np.ndarray
    fitness: float


def _fitness(g: Graph, part: np.ndarray, k: int,
             optimize_comm_volume: bool) -> float:
    if optimize_comm_volume:
        return float(comm_volume(g, part, k).max())
    return float(edge_cut(g, part))


def combine(g: Graph, pa: np.ndarray, pb: np.ndarray, k: int, eps: float,
            cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """The KaFFPaE combine operator.

    ``pb`` may be *any* domain-specific clustering/partition (the paper
    stresses this flexibility) — only ``pa`` must be a feasible k-partition.
    The offspring never has a worse cut than the better *valid* parent: the
    better one seeds the protected coarsest level and refinement never
    worsens.
    """
    if pb.max() < k and edge_cut(g, pb) < edge_cut(g, pa):
        pa, pb = pb, pa              # seed from the better valid parent
    src = g.edge_sources()
    forbidden = (pa[src] != pa[g.adjncy]) | (pb[src] != pb[g.adjncy])
    # build a protected hierarchy; split every cluster by (pa, pb) signature
    levels = [(g, None)]
    cur, cur_pa, cur_pb = g, pa, pb
    stop_n = max(cfg.contraction_stop_factor * k, 64)
    lvl = 0
    cur_forbidden = forbidden
    while cur.n > stop_n:
        max_cw = max(1.0, cur.total_vwgt() / (cfg.cluster_weight_factor * k))
        mode = "lp" if cfg.coarsening == "lp" else "matching"
        if mode == "matching":
            clusters = C.heavy_edge_matching(cur, seed=seed + 31 * lvl,
                                             max_cluster_weight=max_cw,
                                             forbidden=cur_forbidden)
        else:
            clusters = C.lp_clustering(cur, max_cw, seed=seed + 31 * lvl,
                                       forbidden=cur_forbidden)
        # split clusters by parent signatures → parents stay representable
        sig = clusters * (k * k) + cur_pa * k + cur_pb
        coarse, cl = C.contract(cur, sig)
        if coarse.n >= cur.n * 0.95:
            break
        levels.append((coarse, cl))
        # push parents + forbidden mask to coarse level
        nc = coarse.n
        npa = np.zeros(nc, dtype=np.int64)
        npb = np.zeros(nc, dtype=np.int64)
        npa[cl] = cur_pa
        npb[cl] = cur_pb
        csrc = coarse.edge_sources()
        cur_forbidden = ((npa[csrc] != npa[coarse.adjncy])
                         | (npb[csrc] != npb[coarse.adjncy]))
        cur, cur_pa, cur_pb = coarse, npa, npb
        lvl += 1
    # the better parent seeds the coarsest level
    part_c = cur_pa
    part_c = K._refine_level(levels[-1][0], part_c, k, eps, cfg, seed)
    out = K._uncoarsen(levels, part_c, k, eps, cfg, seed)
    return out


def mutate(g: Graph, part: np.ndarray, k: int, eps: float,
           cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """Mutation = V-cycle with a fresh seed (paper: KaFFPa provides it)."""
    return K.vcycle(g, part, k, eps, cfg, seed)


def kaffpaE(g: Graph, k: int, eps: float = 0.03, preset: str = "fast",
            n_islands: int = 4, population: int = 4,
            time_limit: float = 10.0, seed: int = 0,
            optimize_comm_volume: bool = False,
            enable_kabape: bool = False,
            kabaE_internal_bal: float = 0.01,
            quickstart: bool = False,
            on_generation: Optional[Callable] = None) -> np.ndarray:
    """The ``kaffpaE`` program (paper §4.2).

    time_limit == 0 → only the initial population is created (paper
    semantics).  With ``enable_kabape`` offspring get the KaBaPE
    negative-cycle polish at the strict balance constraint.
    """
    cfg = K.PRESETS[preset]
    rng = np.random.default_rng(seed)
    t0 = time.monotonic()
    fit = lambda p: _fitness(g, p, k, optimize_comm_volume)  # noqa: E731

    islands: list[list[Individual]] = []
    pop0 = max(1, population // 2) if quickstart else population
    for isl in range(n_islands):
        pop = []
        for j in range(pop0):
            p = K.multilevel_partition(g, k, eps, cfg,
                                       seed + 1009 * isl + 31 * j)
            pop.append(Individual(p, fit(p)))
        islands.append(pop)
    if quickstart:
        # each island created a few; distribute them among all islands
        every = [ind for pop in islands for ind in pop]
        for isl in range(n_islands):
            extra = rng.choice(len(every), size=population - pop0,
                               replace=False)
            islands[isl].extend(Individual(every[e].part.copy(),
                                           every[e].fitness) for e in extra)

    gen = 0
    while time.monotonic() - t0 < time_limit:
        gen += 1
        for isl in range(n_islands):
            pop = islands[isl]
            if rng.random() < 0.9 and len(pop) >= 2:
                # tournament parents
                ia, ib = rng.choice(len(pop), size=2, replace=False)
                pa = min(pop[ia], pop[ib], key=lambda x: x.fitness)
                others = [p for j, p in enumerate(pop) if j not in (ia, ib)]
                pb = min(others, key=lambda x: x.fitness) if others else pa
                child = combine(g, pa.part, pb.part, k, eps, cfg,
                                seed + 7919 * gen + isl)
            else:
                src = pop[int(rng.integers(len(pop)))]
                child = mutate(g, src.part, k, eps, cfg,
                               seed + 104729 * gen + isl)
            if enable_kabape:
                child = kabape_refine(g, child, k, eps,
                                      internal_bal=kabaE_internal_bal,
                                      seed=seed + gen)
            f = fit(child)
            worst = max(range(len(pop)), key=lambda j: pop[j].fitness)
            if f <= pop[worst].fitness:
                pop[worst] = Individual(child, f)
        # rumor spreading: each island pushes its best to a random island
        for isl in range(n_islands):
            best = min(islands[isl], key=lambda x: x.fitness)
            tgt = int(rng.integers(n_islands))
            if tgt != isl:
                w = max(range(len(islands[tgt])),
                        key=lambda j: islands[tgt][j].fitness)
                if best.fitness < islands[tgt][w].fitness:
                    islands[tgt][w] = Individual(best.part.copy(),
                                                 best.fitness)
        if on_generation is not None:
            on_generation(gen, min(i.fitness for pop in islands for i in pop))

    allind = [i for pop in islands for i in pop]
    feas = [i for i in allind if is_feasible(g, i.part, k, eps)]
    pool = feas if feas else allind
    return min(pool, key=lambda x: x.fitness).part
