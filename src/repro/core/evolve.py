"""KaFFPaE / KaBaPE — the distributed evolutionary partitioner (paper §2.2).

Island model: every island keeps a population of partitions and applies
*combine* and *mutation* operators built from KaFFPa itself.

Combine (the paper's key operator): coarsening is modified so that no cut
edge of either parent is contracted — both parents stay representable at the
coarsest level, the better parent seeds the initial partition, and refinement
(which never worsens) assembles good parts of both.  The shared multilevel
engine implements this medium-generically (core/multilevel.py): clusters are
split by the parents' block signatures before contraction, which
*guarantees* the invariant (DESIGN.md §2/§7).

The MPI rumor-spreading exchange is modelled by the island topology: after
every generation each island pushes its best individual to a uniformly
random other island (exactly the randomized rumor-spreading step; with
shard_map islands this becomes a collective_permute — see parhip.py for the
collective formulation of the distributed phases).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core.csr import Graph
from repro.core import kaffpa as K
from repro.core import multilevel as ML
from repro.core.partition import edge_cut, is_feasible, comm_volume
from repro.core.kabape import kabape_refine


@dataclasses.dataclass
class Individual:
    part: np.ndarray
    fitness: float


def _fitness(g: Graph, part: np.ndarray, k: int,
             optimize_comm_volume: bool) -> float:
    if optimize_comm_volume:
        return float(comm_volume(g, part, k).max())
    return float(edge_cut(g, part))


def combine(g: Graph, pa: np.ndarray, pb: np.ndarray, k: int, eps: float,
            cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """The KaFFPaE combine operator.

    ``pb`` may be *any* domain-specific clustering/partition (the paper
    stresses this flexibility) — only ``pa`` must be a feasible k-partition.
    The offspring never has a worse cut than the better *valid* parent: the
    better one seeds the protected coarsest level and refinement never
    worsens.  Delegates to the shared engine's medium-generic combine.
    """
    return ML.combine(K.GraphMedium(g, cfg), pa, pb, k, eps, seed)


def mutate(g: Graph, part: np.ndarray, k: int, eps: float,
           cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """Mutation = V-cycle with a fresh seed (paper: KaFFPa provides it)."""
    return K.vcycle(g, part, k, eps, cfg, seed)


def kaffpaE(g: Graph, k: int, eps: float = 0.03, preset: str = "fast",
            n_islands: int = 4, population: int = 4,
            time_limit: float = 10.0, seed: int = 0,
            optimize_comm_volume: bool = False,
            enable_kabape: bool = False,
            kabaE_internal_bal: float = 0.01,
            quickstart: bool = False,
            on_generation: Optional[Callable] = None) -> np.ndarray:
    """The ``kaffpaE`` program (paper §4.2).

    time_limit == 0 → only the initial population is created (paper
    semantics).  With ``enable_kabape`` offspring get the KaBaPE
    negative-cycle polish at the strict balance constraint.
    """
    cfg = K.PRESETS[preset]
    rng = np.random.default_rng(seed)
    t0 = time.monotonic()
    fit = lambda p: _fitness(g, p, k, optimize_comm_volume)  # noqa: E731
    # one medium for the whole evolution: level-0 device views are built
    # once and shared across every multilevel restart / combine / V-cycle
    medium = K.GraphMedium(g, cfg)

    islands: list[list[Individual]] = []
    pop0 = max(1, population // 2) if quickstart else population
    for isl in range(n_islands):
        pop = []
        for j in range(pop0):
            p = ML.multilevel(medium, k, eps, seed + 1009 * isl + 31 * j)
            pop.append(Individual(p, fit(p)))
        islands.append(pop)
    if quickstart:
        # each island created a few; distribute them among all islands
        every = [ind for pop in islands for ind in pop]
        need = population - pop0
        for isl in range(n_islands):
            # the pool can be smaller than the draw (e.g. n_islands=1,
            # population=3 → pool 1, need 2): fall back to sampling with
            # replacement — the copies diverge under combine/mutation
            extra = rng.choice(len(every), size=need,
                               replace=need > len(every))
            islands[isl].extend(Individual(every[e].part.copy(),
                                           every[e].fitness) for e in extra)

    gen = 0
    while time.monotonic() - t0 < time_limit:
        gen += 1
        for isl in range(n_islands):
            pop = islands[isl]
            if rng.random() < 0.9 and len(pop) >= 2:
                # tournament parents
                ia, ib = rng.choice(len(pop), size=2, replace=False)
                pa = min(pop[ia], pop[ib], key=lambda x: x.fitness)
                others = [p for j, p in enumerate(pop) if j not in (ia, ib)]
                pb = min(others, key=lambda x: x.fitness) if others else pa
                child = ML.combine(medium, pa.part, pb.part, k, eps,
                                   seed + 7919 * gen + isl)
            else:
                src = pop[int(rng.integers(len(pop)))]
                child = ML.vcycle(medium, src.part, k, eps,
                                  seed + 104729 * gen + isl)
            if enable_kabape:
                child = kabape_refine(g, child, k, eps,
                                      internal_bal=kabaE_internal_bal,
                                      seed=seed + gen)
            f = fit(child)
            worst = max(range(len(pop)), key=lambda j: pop[j].fitness)
            if f <= pop[worst].fitness:
                pop[worst] = Individual(child, f)
        # rumor spreading: each island pushes its best to a random island
        for isl in range(n_islands):
            best = min(islands[isl], key=lambda x: x.fitness)
            tgt = int(rng.integers(n_islands))
            if tgt != isl:
                w = max(range(len(islands[tgt])),
                        key=lambda j: islands[tgt][j].fitness)
                if best.fitness < islands[tgt][w].fitness:
                    islands[tgt][w] = Individual(best.part.copy(),
                                                 best.fitness)
        if on_generation is not None:
            on_generation(gen, min(i.fitness for pop in islands for i in pop))

    allind = [i for pop in islands for i in pop]
    feas = [i for i in allind if is_feasible(g, i.part, k, eps)]
    pool = feas if feas else allind
    return min(pool, key=lambda x: x.fitness).part
