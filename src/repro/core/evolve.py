"""KaFFPaE / KaBaPE — the distributed evolutionary partitioner (paper §2.2).

Island model: every island keeps a population of partitions and applies
*combine* and *mutation* operators built from KaFFPa itself.

Combine (the paper's key operator): coarsening is modified so that no cut
edge of either parent is contracted — both parents stay representable at the
coarsest level, the better parent seeds the initial partition, and refinement
(which never worsens) assembles good parts of both.  The shared multilevel
engine implements this medium-generically (core/multilevel.py): clusters are
split by the parents' block signatures before contraction, which
*guarantees* the invariant (DESIGN.md §2/§7).

Since PR 5 the island loop itself lives in the medium-generic memetic
engine (core/memetic, DESIGN.md §10) — ``kaffpaE`` is the `GraphMedium`
front: the MPI rumor-spreading exchange is the seeded migration ring
(collective_permute when the islands are laid out as shards on a device
mesh, a bit-identical host roll otherwise), and the KaBaPE variant rides
the same driver with the negative-cycle child polish and the balanced
replacement rule.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.csr import Graph
from repro.core import kaffpa as K
from repro.core import memetic as MEM
from repro.core import multilevel as ML
from repro.core.memetic import Individual, IslandState  # noqa: F401 (compat)
from repro.core.partition import comm_volume, edge_cut
from repro.core.kabape import kabape_refine


def _fitness(g: Graph, part: np.ndarray, k: int,
             optimize_comm_volume: bool) -> float:
    if optimize_comm_volume:
        return float(comm_volume(g, part, k).max())
    return float(edge_cut(g, part))


def combine(g: Graph, pa: np.ndarray, pb: np.ndarray, k: int, eps: float,
            cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """The KaFFPaE combine operator.

    ``pb`` may be *any* domain-specific clustering/partition (the paper
    stresses this flexibility) — only ``pa`` must be a feasible k-partition.
    The offspring never has a worse cut than the better *valid* parent: the
    better one seeds the protected coarsest level and refinement never
    worsens.  Delegates to the shared engine's medium-generic combine.
    """
    return ML.combine(K.GraphMedium(g, cfg), pa, pb, k, eps, seed)


def mutate(g: Graph, part: np.ndarray, k: int, eps: float,
           cfg: K.KaffpaConfig, seed: int) -> np.ndarray:
    """Mutation = V-cycle with a fresh seed (paper: KaFFPa provides it)."""
    return K.vcycle(g, part, k, eps, cfg, seed)


def kaffpaE(g: Graph, k: int, eps: float = 0.03, preset: str = "fast",
            n_islands: int = 4, population: int = 4,
            time_limit: float = 10.0, seed: int = 0,
            optimize_comm_volume: bool = False,
            enable_kabape: bool = False,
            kabaE_internal_bal: float = 0.01,
            quickstart: bool = False,
            on_generation: Optional[Callable] = None,
            mesh=None, migrate: bool = True,
            generations: Optional[int] = None) -> np.ndarray:
    """The ``kaffpaE`` program (paper §4.2), on the memetic engine.

    time_limit == 0 → only the initial population is created (paper
    semantics); ``generations`` selects a deterministic generation count
    instead of the wall-clock budget.  With ``enable_kabape`` offspring get
    the KaBaPE negative-cycle polish at the strict balance constraint and
    replacement evicts infeasible members first.  ``mesh`` lays the islands
    out as shards for collective_permute migration.
    """
    MEM.validate_memetic_params(n_islands, population, time_limit,
                                generations)
    cfg = K.PRESETS[preset]
    if k <= 1:
        return np.zeros(g.n, dtype=np.int64)
    # one medium for the whole evolution: level-0 device views are built
    # once and shared across every multilevel restart / combine / V-cycle
    medium = K.GraphMedium(g, cfg)
    fitness_fn = None
    if optimize_comm_volume:
        fitness_fn = lambda p: _fitness(g, p, k, True)        # noqa: E731
    polish_fn = None
    if enable_kabape:
        polish_fn = lambda p, s: kabape_refine(                # noqa: E731
            g, p, k, eps, internal_bal=kabaE_internal_bal, seed=s)
    mcfg = MEM.MemeticConfig(
        n_islands=n_islands, population=population, time_limit=time_limit,
        generations=generations, migrate=migrate, quickstart=quickstart,
        replacement="balanced" if enable_kabape else "worst")
    state = MEM.evolve_islands(medium, k, eps, mcfg, seed,
                               fitness_fn=fitness_fn, polish_fn=polish_fn,
                               mesh=mesh, on_generation=on_generation)
    return state.best_part()
