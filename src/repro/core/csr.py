"""Graph containers.

Host side: `Graph` — the exact CSR layout of ``kaHIP_interface.h``
(xadj / adjncy / vwgt / adjwgt, forward+backward edge stored, vertices
0-indexed).  All irregular preprocessing (IO, contraction bookkeeping,
validation) happens here in numpy.

Device side: two rectangular views suitable for TPU:
  * `EllGraph`  — padded ELL (n_pad, dmax) neighbour/weight matrices, the
    layout consumed by the Pallas affinity kernel (128-row tiles).
  * `CooGraph`  — padded directed edge list for segment-op algorithms
    (label propagation, contraction, gain computation).

Padding conventions: invalid ELL slots have ``nbr == -1`` and ``wgt == 0``;
invalid COO slots have ``src == dst == n`` (a sentinel row — segment ops use
``num_segments = n + 1`` and slice the sentinel off).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp


class GraphFormatError(ValueError):
    """Raised by the graphchecker for malformed graphs."""


def _as1d(a, dtype):
    out = np.asarray(a, dtype=dtype)
    if out.ndim != 1:
        raise GraphFormatError(f"expected 1-d array, got shape {out.shape}")
    return out


@dataclasses.dataclass
class Graph:
    """Host CSR graph (undirected; both edge directions stored)."""

    xadj: np.ndarray    # (n+1,) int64, offsets into adjncy
    adjncy: np.ndarray  # (2m,)  int64, neighbour ids
    vwgt: np.ndarray    # (n,)   int64, node weights (>= 0)
    adjwgt: np.ndarray  # (2m,)  int64, edge weights (> 0), symmetric

    def __post_init__(self):
        self.xadj = _as1d(self.xadj, np.int64)
        self.adjncy = _as1d(self.adjncy, np.int64)
        self.vwgt = _as1d(self.vwgt, np.int64)
        self.adjwgt = _as1d(self.adjwgt, np.int64)

    # -- basic accessors ---------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.xadj) - 1

    @property
    def m(self) -> int:
        """Number of *undirected* edges."""
        return len(self.adjncy) // 2

    def degrees(self) -> np.ndarray:
        return np.diff(self.xadj)

    def neighbors(self, v: int) -> np.ndarray:
        return self.adjncy[self.xadj[v]:self.xadj[v + 1]]

    def edge_weights(self, v: int) -> np.ndarray:
        return self.adjwgt[self.xadj[v]:self.xadj[v + 1]]

    def total_vwgt(self) -> int:
        return int(self.vwgt.sum())

    def total_ewgt(self) -> int:
        return int(self.adjwgt.sum()) // 2

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_edges(n: int,
                   u: Sequence[int],
                   v: Sequence[int],
                   w: Optional[Sequence[int]] = None,
                   vwgt: Optional[Sequence[int]] = None,
                   dedup: bool = True) -> "Graph":
        """Build from an undirected edge list (each edge given once).

        Self loops are dropped; parallel edges are merged (weights summed)
        when ``dedup`` — matching what the KaHIP graphchecker would demand.
        """
        u = _as1d(u, np.int64)
        v = _as1d(v, np.int64)
        if w is None:
            w = np.ones_like(u)
        else:
            w = _as1d(w, np.int64)
        keep = u != v
        u, v, w = u[keep], v[keep], w[keep]
        # canonical order then dedup
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        if dedup and len(lo):
            key = lo * np.int64(n) + hi
            order = np.argsort(key, kind="stable")
            key, lo, hi, w = key[order], lo[order], hi[order], w[order]
            first = np.ones(len(key), dtype=bool)
            first[1:] = key[1:] != key[:-1]
            seg = np.cumsum(first) - 1
            wsum = np.zeros(int(seg[-1]) + 1 if len(seg) else 0, dtype=np.int64)
            np.add.at(wsum, seg, w)
            lo, hi, w = lo[first], hi[first], wsum
        # both directions
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        wgt = np.concatenate([w, w])
        order = np.argsort(src * np.int64(n) + dst, kind="stable")
        src, dst, wgt = src[order], dst[order], wgt[order]
        xadj = np.zeros(n + 1, dtype=np.int64)
        np.add.at(xadj, src + 1, 1)
        xadj = np.cumsum(xadj)
        vw = np.ones(n, dtype=np.int64) if vwgt is None else _as1d(vwgt, np.int64)
        return Graph(xadj=xadj, adjncy=dst, vwgt=vw, adjwgt=wgt)

    @staticmethod
    def from_arrays(xadj, adjncy, vwgt=None, adjwgt=None) -> "Graph":
        xadj = _as1d(xadj, np.int64)
        adjncy = _as1d(adjncy, np.int64)
        n = len(xadj) - 1
        if vwgt is None:
            vwgt = np.ones(n, dtype=np.int64)
        if adjwgt is None:
            adjwgt = np.ones(len(adjncy), dtype=np.int64)
        return Graph(xadj, adjncy, _as1d(vwgt, np.int64), _as1d(adjwgt, np.int64))

    # -- graphchecker --------------------------------------------------------
    def check(self, raise_on_error: bool = True) -> list:
        """The ``graphchecker`` tool: validates all invariants §3.3 lists."""
        errs = []
        n = self.n
        if self.xadj[0] != 0 or self.xadj[-1] != len(self.adjncy):
            errs.append("xadj endpoints inconsistent with adjncy length")
        if np.any(np.diff(self.xadj) < 0):
            errs.append("xadj not monotone")
        if len(self.adjncy) and (self.adjncy.min() < 0 or self.adjncy.max() >= n):
            errs.append("neighbour id out of range")
        if len(self.vwgt) != n:
            errs.append("vwgt length mismatch")
        if np.any(self.vwgt < 0):
            errs.append("negative vertex weight")
        if len(self.adjwgt) != len(self.adjncy):
            errs.append("adjwgt length mismatch")
        if len(self.adjwgt) and np.any(self.adjwgt <= 0):
            errs.append("non-positive edge weight")
        if not errs:
            src = np.repeat(np.arange(n, dtype=np.int64), np.diff(self.xadj))
            if np.any(src == self.adjncy):
                errs.append("self loop present")
            # parallel edges: duplicate (src, dst)
            key = src * np.int64(n) + self.adjncy
            skey = np.sort(key)
            if len(skey) > 1 and np.any(skey[1:] == skey[:-1]):
                errs.append("parallel edges present")
            # symmetry of edges and weights
            fwd = np.argsort(key, kind="stable")
            rkey = self.adjncy * np.int64(n) + src
            bwd = np.argsort(rkey, kind="stable")
            if not np.array_equal(key[fwd], rkey[bwd]):
                errs.append("missing backward edge")
            elif not np.array_equal(self.adjwgt[fwd], self.adjwgt[bwd]):
                errs.append("forward/backward edge weights differ")
        if errs and raise_on_error:
            raise GraphFormatError("; ".join(errs))
        return errs

    def is_unit_weighted(self) -> bool:
        return bool(np.all(self.vwgt == 1) and np.all(self.adjwgt == 1))

    # -- derived graphs ------------------------------------------------------
    def with_edge_balanced_weights(self) -> "Graph":
        """--balance_edges: c'(v) = c(v) + deg_w(v) (paper §1)."""
        degw = np.zeros(self.n, dtype=np.int64)
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.xadj))
        np.add.at(degw, src, self.adjwgt)
        return Graph(self.xadj, self.adjncy, self.vwgt + degw, self.adjwgt)

    def edge_sources(self) -> np.ndarray:
        return np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.xadj))

    def subgraph(self, mask: np.ndarray):
        """Induced subgraph on ``mask``; returns (subgraph, old_ids)."""
        ids = np.flatnonzero(mask)
        remap = -np.ones(self.n, dtype=np.int64)
        remap[ids] = np.arange(len(ids))
        src = self.edge_sources()
        keep = mask[src] & mask[self.adjncy]
        u, v, w = remap[src[keep]], remap[self.adjncy[keep]], self.adjwgt[keep]
        fwd = u < v  # each undirected edge once
        g = Graph.from_edges(len(ids), u[fwd], v[fwd], w[fwd],
                             vwgt=self.vwgt[ids], dedup=False)
        return g, ids


# ---------------------------------------------------------------------------
# Device views
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _pow2_pad(x: int, mult: int) -> int:
    """Round up to a power-of-two multiple of ``mult`` (recompile bucketing)."""
    x = max(x, mult)
    out = mult
    while out < x:
        out *= 2
    return out


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EllGraph:
    """Padded ELL device graph — rectangular, Pallas-kernel friendly.

    Shapes are pow2-bucketed so jit caches hit across multilevel levels.
    Padding rows are isolated (vwgt 0); padding slots have nbr == n_pad-1
    and wgt == 0, so they contribute nothing to any reduction.
    """

    nbr: jax.Array    # (n_pad, dmax) int32
    wgt: jax.Array    # (n_pad, dmax) float32; 0 padding
    vwgt: jax.Array   # (n_pad,) float32; 0 padding

    @property
    def n_pad(self) -> int:
        return self.nbr.shape[0]

    @property
    def dmax(self) -> int:
        return self.nbr.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CooGraph:
    """Padded directed edge list.  Padding edges are (n_pad-1, n_pad-1, w=0)
    self-loops on a zero-weight row — invisible to every reduction."""

    src: jax.Array    # (e_pad,) int32
    dst: jax.Array    # (e_pad,) int32
    w: jax.Array      # (e_pad,) float32; 0 on padding
    vwgt: jax.Array   # (n_pad,) float32; 0 padding

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    @property
    def n_pad(self) -> int:
        return self.vwgt.shape[0]


def to_ell(g: Graph, row_tile: int = 128, d_mult: int = 8,
           dmax_cap: Optional[int] = None) -> EllGraph:
    """CSR → padded ELL. ``dmax_cap`` truncates hub rows (heaviest edges kept)."""
    n = g.n
    deg = g.degrees()
    dmax = int(deg.max()) if n else 0
    if dmax_cap is not None:
        dmax = min(dmax, dmax_cap)
    # pow2-bucketed like every other device dim (DESIGN.md §12), so levels
    # with nearby max degree share one kernel program
    dmax = _pow2_pad(max(dmax, 1), d_mult)
    n_pad = _pow2_pad(max(n, 1), row_tile)
    nbr = np.full((n_pad, dmax), n_pad - 1, dtype=np.int32)
    wgt = np.zeros((n_pad, dmax), dtype=np.float32)
    src = g.edge_sources()
    # rank of each edge within its row
    rank = np.arange(len(src)) - g.xadj[src]
    if dmax_cap is not None:
        # keep heaviest edges per row: sort by (row, -w) then recompute rank
        order = np.lexsort((-g.adjwgt, src))
        src_o, dst_o, w_o = src[order], g.adjncy[order], g.adjwgt[order]
        rank = np.arange(len(src_o)) - g.xadj[src_o]
        keep = rank < dmax
        nbr[src_o[keep], rank[keep]] = dst_o[keep]
        wgt[src_o[keep], rank[keep]] = w_o[keep]
    else:
        nbr[src, rank] = g.adjncy
        wgt[src, rank] = g.adjwgt
    vw = np.zeros(n_pad, dtype=np.float32)
    vw[:n] = g.vwgt
    return EllGraph(nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt),
                    vwgt=jnp.asarray(vw))


def to_coo(g: Graph, e_mult: int = 256, n_mult: int = 256) -> CooGraph:
    """CSR → padded COO with pow2 shape bucketing (jit-cache friendly)."""
    n, e = g.n, len(g.adjncy)
    e_pad = _pow2_pad(max(e, 1), e_mult)
    n_pad = _pow2_pad(max(n, 1), n_mult)
    src = np.full(e_pad, n_pad - 1, dtype=np.int32)
    dst = np.full(e_pad, n_pad - 1, dtype=np.int32)
    w = np.zeros(e_pad, dtype=np.float32)
    src[:e] = g.edge_sources()
    dst[:e] = g.adjncy
    w[:e] = g.adjwgt
    vw = np.zeros(n_pad, dtype=np.float32)
    vw[:n] = g.vwgt
    return CooGraph(src=jnp.asarray(src), dst=jnp.asarray(dst),
                    w=jnp.asarray(w), vwgt=jnp.asarray(vw))
