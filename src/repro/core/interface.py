"""The KaHIP library interface (paper §5) — Python mirror of
``interface/kaHIP_interface.h``.

Functions take the CSR arrays (n, vwgt, xadj, adjcwgt, adjncy) exactly as the
C API does (vwgt/adjcwgt may be None) and return the C API's output
parameters as Python values.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.core.csr import Graph

# mode constants (paper §5.2)
FAST, ECO, STRONG, FASTSOCIAL, ECOSOCIAL, STRONGSOCIAL = range(6)
_MODE_NAMES = {FAST: "fast", ECO: "eco", STRONG: "strong",
               FASTSOCIAL: "fastsocial", ECOSOCIAL: "ecosocial",
               STRONGSOCIAL: "strongsocial"}

MAPMODE_MULTISECTION = 0
MAPMODE_BISECTION = 1


def _graph(n, vwgt, xadj, adjcwgt, adjncy) -> Graph:
    return Graph.from_arrays(np.asarray(xadj), np.asarray(adjncy),
                             None if vwgt is None else np.asarray(vwgt),
                             None if adjcwgt is None else np.asarray(adjcwgt))


def kaffpa(n: int, vwgt, xadj, adjcwgt, adjncy, nparts: int,
           imbalance: float, suppress_output: bool = True, seed: int = 0,
           mode: int = ECO, report=None):
    """Main partitioner call → (edgecut, part).

    ``report`` is an optional ``obs.Recorder`` capturing spans, counters
    and the quality trajectory of this run (DESIGN.md §11).
    """
    from repro.core import kaffpa as K
    from repro.core.partition import edge_cut
    g = _graph(n, vwgt, xadj, adjcwgt, adjncy)
    part = K.kaffpa(g, nparts, imbalance, _MODE_NAMES[mode], seed=seed,
                    report=report)
    return edge_cut(g, part), part


def kaffpa_balance_NE(n: int, vwgt, xadj, adjcwgt, adjncy, nparts: int,
                      imbalance: float, suppress_output: bool = True,
                      seed: int = 0, mode: int = ECO, report=None):
    """Node+edge balanced partitioner call → (edgecut, part)."""
    from repro.core import kaffpa as K
    from repro.core.partition import edge_cut
    g = _graph(n, vwgt, xadj, adjcwgt, adjncy)
    part = K.kaffpa(g, nparts, imbalance, _MODE_NAMES[mode], seed=seed,
                    balance_edges=True, report=report)
    return edge_cut(g, part), part


def kaffpaE(n: int, vwgt, xadj, adjcwgt, adjncy, nparts: int,
            imbalance: float, time_limit: float = 10.0,
            suppress_output: bool = True, seed: int = 0, mode: int = ECO,
            n_islands: int = 4, population: int = 4, mesh=None,
            generations=None, report=None):
    """Memetic partitioner call (the ``kaffpaE`` program on the
    core/memetic island driver) → (edgecut, part).

    Validates the memetic knobs up front (``n_islands``/``population``
    must be positive, ``time_limit`` finite and >= 0 — 0 keeps the paper's
    initial-population-only semantics); ``mesh`` lays the islands out as
    shards for collective_permute migration.
    """
    from repro.core import evolve as E
    from repro.core.partition import edge_cut
    g = _graph(n, vwgt, xadj, adjcwgt, adjncy)
    with obs.use(report):
        part = E.kaffpaE(g, nparts, imbalance, _MODE_NAMES[mode],
                         n_islands=n_islands, population=population,
                         time_limit=time_limit, seed=seed, mesh=mesh,
                         generations=generations)
    return edge_cut(g, part), part


def kahypar(n: int, m: int, vwgt, ewgt, eptr, eind, nparts: int,
            imbalance: float, suppress_output: bool = True, seed: int = 0,
            mode: int = ECO, objective: str = "km1",
            vcycles: Optional[int] = None, time_limit: float = 0.0,
            report=None):
    """Hypergraph partitioner call (KaHyPar-style C API) → (objval, part).

    ``eptr``/``eind`` are the hMETIS CSR arrays (m+1 offsets, pin ids);
    ``vwgt``/``ewgt`` may be None.  ``objective`` ∈ {"km1", "cut"} selects
    connectivity (λ−1) or cut-net; ``objval`` is the objective achieved.
    ``vcycles``/``time_limit`` are the shared engine's iterated-multilevel
    and restart-budget knobs (same semantics as the kaffpa entry).
    """
    from repro.core import hypergraph as H
    hg = H.Hypergraph.from_arrays(
        n, np.asarray(eptr), np.asarray(eind),
        None if ewgt is None else np.asarray(ewgt),
        None if vwgt is None else np.asarray(vwgt))
    preset = _MODE_NAMES[mode].replace("social", "")   # no social split here
    part = H.kahypar(hg, nparts, imbalance, preset, seed=seed,
                     objective=objective, vcycles=vcycles,
                     time_limit=time_limit, report=report)
    score = H.connectivity if objective == "km1" else H.cut_net
    return score(hg, part), part


def kahyparE(n: int, m: int, vwgt, ewgt, eptr, eind, nparts: int,
             imbalance: float, time_limit: float = 10.0,
             suppress_output: bool = True, seed: int = 0, mode: int = ECO,
             objective: str = "km1", n_islands: int = 2,
             population: int = 2, generations=None, mesh=None,
             report=None):
    """Memetic hypergraph partitioner call (the ``kahyparE`` program,
    DESIGN.md §10) → (objval, part).

    Same array convention as the ``kahypar`` entry; ``objective`` ∈
    {"km1", "cut"}.  The memetic knobs are validated up front;
    ``generations`` selects a deterministic generation count instead of
    the ``time_limit`` wall-clock budget, ``mesh`` shards the islands for
    collective_permute migration (with the distributed parhyp round as the
    per-island local search on multi-device meshes).
    """
    from repro.core import hypergraph as H
    hg = H.Hypergraph.from_arrays(
        n, np.asarray(eptr), np.asarray(eind),
        None if ewgt is None else np.asarray(ewgt),
        None if vwgt is None else np.asarray(vwgt))
    preset = _MODE_NAMES[mode].replace("social", "")   # no social split here
    part = H.kahyparE(hg, nparts, imbalance, preset, seed=seed,
                      objective=objective, n_islands=n_islands,
                      population=population, time_limit=time_limit,
                      generations=generations, mesh=mesh, report=report)
    score = H.connectivity if objective == "km1" else H.cut_net
    return score(hg, part), part


def parhyp(n: int, m: int, vwgt, ewgt, eptr, eind, nparts: int,
           imbalance: float, suppress_output: bool = True, seed: int = 0,
           preconfiguration: str = "fast", objective: str = "km1",
           mesh=None, report=None):
    """Distributed hypergraph partitioner call (the shard_map ``parhyp``
    program, DESIGN.md §9) → (objval, part).

    Same array convention as the ``kahypar`` entry; ``preconfiguration``
    ∈ {"ultrafast", "fast", "eco"} selects the engine preset and the
    distributed-LP round count, ``mesh`` an optional jax Mesh — 1-D
    ``("nets",)`` or 2-D ``("nets", "verts")`` (defaults to all local
    devices on a 1-D nets axis).  Above the gather-to-one-PE floor the
    whole V-cycle (LP-clustering coarsening, contraction, refinement)
    stays device-resident; small inputs run the host-orchestrated
    multilevel with distributed refinement.
    """
    from repro.core import hypergraph as H
    hg = H.Hypergraph.from_arrays(
        n, np.asarray(eptr), np.asarray(eind),
        None if ewgt is None else np.asarray(ewgt),
        None if vwgt is None else np.asarray(vwgt))
    part = H.parhyp(hg, nparts, imbalance,
                    preconfiguration=preconfiguration, seed=seed,
                    mesh=mesh, objective=objective, report=report)
    score = H.connectivity if objective == "km1" else H.cut_net
    return score(hg, part), part


def node_separator(n: int, vwgt, xadj, adjcwgt, adjncy, nparts: int,
                   imbalance: float, suppress_output: bool = True,
                   seed: int = 0, mode: int = ECO, multilevel: bool = True,
                   memetic: bool = False, time_limit: float = 5.0,
                   n_islands: int = 2, population: int = 2, report=None):
    """→ (num_separator_vertices, separator ids).

    nparts == 2 (the recommended §5.2 setting) runs the multilevel
    separator engine (core/nodesep) which optimizes separator weight at
    every hierarchy level; ``memetic=True`` evolves separator states on
    the memetic island driver instead (DESIGN.md §10);
    ``multilevel=False`` selects the post-hoc two-step construction
    (partition, then vertex-cover the boundary — the seed-parity
    baseline).  nparts > 2 always uses the pairwise post-hoc construction.
    """
    from repro.core import kaffpa as K
    from repro.core import separator as S
    g = _graph(n, vwgt, xadj, adjcwgt, adjncy)
    if nparts == 2 and memetic:
        from repro.core.nodesep import memetic_node_separator
        with obs.use(report):
            sep, _ = memetic_node_separator(g, imbalance, _MODE_NAMES[mode],
                                            seed=seed, n_islands=n_islands,
                                            population=population,
                                            time_limit=time_limit)
        return len(sep), sep
    if nparts == 2 and multilevel:
        from repro.core.nodesep import multilevel_node_separator
        sep, _ = multilevel_node_separator(g, imbalance, _MODE_NAMES[mode],
                                           seed=seed, report=report)
        return len(sep), sep
    with obs.use(report):
        part = K.kaffpa(g, nparts, imbalance, _MODE_NAMES[mode], seed=seed)
        if nparts == 2:
            sep, _ = S.node_separator(g, imbalance, _MODE_NAMES[mode], seed,
                                      part=part)
        else:
            sep = S.partition_to_vertex_separator(g, part, nparts)
    return len(sep), sep


def reduced_nd(n: int, xadj, adjncy, suppress_output: bool = True,
               seed: int = 0, mode: int = ECO):
    """Node ordering → ordering array (ordering[v] = elimination position)."""
    from repro.core import ordering as O
    g = _graph(n, None, xadj, None, adjncy)
    order = O.reduced_nd(g, _MODE_NAMES[mode], seed=seed)
    inv = np.empty(g.n, dtype=np.int64)
    inv[order] = np.arange(g.n)
    return inv


def fast_reduced_nd(n: int, xadj, adjncy, suppress_output: bool = True,
                    seed: int = 0, mode: int = FAST):
    from repro.core import ordering as O
    g = _graph(n, None, xadj, None, adjncy)
    order = O.fast_reduced_nd(g, seed=seed)
    inv = np.empty(g.n, dtype=np.int64)
    inv[order] = np.arange(g.n)
    return inv


def process_mapping(n: int, vwgt, xadj, adjcwgt, adjncy,
                    hierarchy_parameter: Sequence[int],
                    distance_parameter: Sequence[int],
                    hierarchy_depth: int, imbalance: float,
                    suppress_output: bool = True, seed: int = 0,
                    mode_partitioning: int = ECO,
                    mode_mapping: int = MAPMODE_MULTISECTION):
    """→ (edgecut, qap, part) — §5.2 Process Mapping."""
    from repro.core import mapping as M
    from repro.core.partition import edge_cut
    g = _graph(n, vwgt, xadj, adjcwgt, adjncy)
    hierarchy = list(hierarchy_parameter)[:hierarchy_depth]
    distances = list(distance_parameter)[:hierarchy_depth]
    part, mapping, qap = M.kaffpa_with_mapping(
        g, hierarchy, distances, imbalance,
        _MODE_NAMES[mode_partitioning], seed=seed)
    # remap block ids through the processor assignment
    final = mapping[part]
    return edge_cut(g, final), qap, final
