"""Size-constrained label propagation (paper §2.4 / §4.10) — device side.

This is the batch-synchronous, TPU-native formulation of KaHIP's LP (see
DESIGN.md §2): per round every node computes its affinity to every candidate
label in parallel, then a conflict-free subset of moves is applied with a
hard size guarantee ("capped acceptance").

Two regimes:
  * clustering  — labels range over [0, n_pad) (coarsening;
    ``label_propagation`` program).  Affinity via lexsort+segment over edges.
  * k-way       — labels range over [0, k), k small (refinement).  Affinity is
    a dense (n_pad, k) histogram == A @ onehot(labels); the Pallas kernel
    (kernels/lp_affinity.py) implements exactly this product for the ELL
    layout; the COO scatter here is the jnp fallback/oracle.

All functions operate on pow2-padded arrays (see csr.CooGraph docstring), so
jit caches hit across multilevel levels.  Padding rows have zero vertex and
edge weight and never affect sizes, cuts, or gains.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CooGraph, Graph, to_coo

_NEG = -1e30
_NOISE = 1e-4          # random tie-break amplitude
_GAIN_EPS = 1e-3       # strictly-positive-gain threshold (> noise)


# ---------------------------------------------------------------------------
# capped acceptance: apply proposed moves without exceeding target capacity
# ---------------------------------------------------------------------------

def capped_accept(labels: jax.Array, proposal: jax.Array, vwgt: jax.Array,
                  sizes: jax.Array, cap: jax.Array,
                  priority: jax.Array) -> jax.Array:
    """Accept moves in priority order (desc) per target until capacity.

    Guarantee: for every target t, size[t] + accepted_inflow[t] <= cap[t]
    (outflow ignored → conservative).  Returns new labels.
    """
    n = labels.shape[0]
    moving = proposal != labels
    vw = jnp.where(moving, vwgt, 0.0)
    # sort by (target, -priority): group per target, best first
    order = jnp.lexsort((-priority, proposal))
    t_s = proposal[order]
    vw_s = vw[order]
    cums = jnp.cumsum(vw_s)
    newrun = jnp.concatenate([jnp.array([True]), t_s[1:] != t_s[:-1]])
    base = jnp.where(newrun, cums - vw_s, -jnp.inf)
    base = jax.lax.cummax(base)
    inflow = cums - base                  # inclusive inflow within target run
    ok_s = sizes[t_s] + inflow <= cap[t_s]
    ok = jnp.zeros((n,), bool).at[order].set(ok_s)
    return jnp.where(moving & ok, proposal, labels)


# ---------------------------------------------------------------------------
# k-way dense affinity (jnp oracle; Pallas kernel mirrors this on ELL)
# ---------------------------------------------------------------------------

def kway_affinity_coo(g: CooGraph, labels: jax.Array, k: int) -> jax.Array:
    """aff[v, b] = total weight of edges from v into block b.  (n_pad, k)."""
    tgt = labels[g.dst]
    return jnp.zeros((g.n_pad, k), jnp.float32).at[g.src, tgt].add(g.w)


def kway_lp_round(g: CooGraph, labels: jax.Array, sizes: jax.Array,
                  cap: jax.Array, key: jax.Array, k: int,
                  parity: jax.Array, active: Optional[jax.Array],
                  allow_zero_gain: bool, force_balance,
                  affinity_fn=None) -> tuple:
    """One batch-synchronous k-way LP/gain round; returns (labels, sizes).

    ``force_balance`` may be a Python bool or a traced boolean scalar (the
    batched tournament vmaps over it — candidates differ in feasibility).
    """
    n = g.n_pad
    aff = (affinity_fn or kway_affinity_coo)(g, labels, k)
    noise = jax.random.uniform(key, (n, k), jnp.float32, 0.0, _NOISE)
    own = jnp.take_along_axis(aff, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    gain = aff - own[:, None] + noise
    # own block is not a move target
    gain = gain.at[jnp.arange(n), labels].set(_NEG)
    # full targets are not candidates
    vw = g.vwgt
    room = sizes[None, :] + vw[:, None] <= cap[None, :]
    gain = jnp.where(room, gain, _NEG)
    best_gain = jnp.max(gain, axis=1)
    best_tgt = jnp.argmax(gain, axis=1).astype(labels.dtype)
    # traced flag (like force_balance): zero-gain admission rides the batch
    # dim instead of forking the compiled program per variant
    thresh = jnp.where(jnp.asarray(allow_zero_gain), -_GAIN_EPS, _GAIN_EPS)
    want = best_gain > thresh
    # overweight blocks push nodes out regardless of gain (when forced)
    over = sizes[labels] > cap[labels]
    want = want | (jnp.asarray(force_balance)
                   & over & (best_gain > _NEG / 2) & (vw > 0))
    # parity tie-break (avoid A<->B swap oscillation)
    node_par = (jnp.arange(n) + parity) % 2 == 0
    want = want & node_par
    if active is not None:
        want = want & active
    proposal = jnp.where(want, best_tgt, labels)
    new_labels = capped_accept(labels, proposal, vw, sizes, cap,
                               jnp.where(want, best_gain, _NEG))
    new_sizes = jnp.zeros((k,), sizes.dtype).at[new_labels].add(vw)
    return new_labels, new_sizes


# ---------------------------------------------------------------------------
# clustering LP (labels in [0, n_pad)) — lexsort+segment formulation
# ---------------------------------------------------------------------------

def _segment_affinity(g: CooGraph, labels: jax.Array, sizes: jax.Array,
                      cap: jax.Array, key: jax.Array):
    """Per node: best cluster among neighbours under the size constraint.

    Returns (best_label, best_aff, own_aff) arrays of length n_pad.
    """
    n = g.n_pad
    e = g.e_pad
    tgt = labels[g.dst]
    # sort live edges first and split runs on the live flag: real edges'
    # positions and run boundaries then depend on real edges alone — by the
    # masking contract (kernels/ops.py) padding (w == 0) edges may point
    # anywhere, and letting their placement shift the sort would leak into
    # the position-keyed tie-break noise below.  Padding edges land in
    # dead-only runs, which aff_eff masks to _NEG.
    dead = jnp.where(g.w > 0, 0, 1)
    order = jnp.lexsort((tgt, g.src, dead))    # runs of equal (src, tgt)
    src_e = g.src[order]
    lab_e = tgt[order]
    ws = g.w[order]
    live = ws > 0
    newrun = jnp.concatenate(
        [jnp.array([True]),
         (src_e[1:] != src_e[:-1]) | (lab_e[1:] != lab_e[:-1])
         | (live[1:] != live[:-1])])
    seg = jnp.cumsum(newrun) - 1                       # (e,) run index
    segsum = jnp.zeros((e,), jnp.float32).at[seg].add(ws)
    aff_run = segsum[seg]                              # per edge: run's sum
    # random tie-break, consistent within a run
    noise = jax.random.uniform(key, (e,), jnp.float32, 0.0, _NOISE)
    noise = jnp.zeros((e,), jnp.float32).at[seg].max(noise)[seg]
    aff_run = aff_run + noise
    # size constraint: target must have room (own cluster always allowed)
    own = lab_e == labels[src_e]
    room = (sizes[lab_e] + g.vwgt[src_e] <= cap[lab_e]) | own
    aff_eff = jnp.where(room & live, aff_run, _NEG)
    best = jnp.full((n,), _NEG, jnp.float32).at[src_e].max(aff_eff)
    is_best = aff_eff >= best[src_e] - 1e-9
    cand = jnp.where(is_best, lab_e, n + 1)
    best_lab = jnp.full((n,), n + 1, jnp.int32).at[src_e].min(cand)
    own_best = jnp.zeros((n,), jnp.float32).at[src_e].max(
        jnp.where(own & live, aff_run, 0.0))
    return best_lab, best, own_best


@functools.partial(jax.jit, static_argnames=("iters",))
def _cluster_lp_jit(g: CooGraph, labels0: jax.Array, cap: jax.Array,
                    key: jax.Array, iters: int):
    n = g.n_pad
    vw = g.vwgt

    def body(carry, key_r):
        labels, parity = carry
        sizes = jnp.zeros((n,), jnp.float32).at[labels].add(vw)
        k1, _ = jax.random.split(key_r)
        best_lab, best_aff, own_aff = _segment_affinity(g, labels, sizes,
                                                        cap, k1)
        improve = (best_aff > own_aff + _GAIN_EPS) & (best_lab < n)
        node_par = (jnp.arange(n) + parity) % 2 == 0
        want = improve & node_par
        proposal = jnp.where(want, best_lab, labels).astype(labels.dtype)
        pri = jnp.where(want, best_aff - own_aff, _NEG)
        new_labels = capped_accept(labels, proposal, vw, sizes, cap, pri)
        moved = jnp.sum((new_labels != labels).astype(jnp.int32))
        return (new_labels, parity + 1), moved

    keys = jax.random.split(key, iters)
    (labels, _), moved = jax.lax.scan(body, (labels0, jnp.int32(0)), keys)
    return labels, moved


def size_constrained_lp(g: Graph, max_cluster_weight: float,
                        iters: int = 10, seed: int = 0,
                        coo: Optional[CooGraph] = None) -> np.ndarray:
    """The ``label_propagation`` program: returns a clustering (host ints)."""
    coo = coo if coo is not None else to_coo(g)
    n_pad = coo.n_pad
    # host-built constants: jnp.arange/jnp.full would each compile a
    # one-op program (iota / broadcast_in_dim) per shape
    labels0 = jnp.asarray(np.arange(n_pad, dtype=np.int32))
    cap = jnp.asarray(np.full(n_pad, max_cluster_weight, np.float32))
    labels, _ = _cluster_lp_jit(coo, labels0, cap, jax.random.PRNGKey(seed),
                                iters)
    return np.asarray(labels)[:g.n]
