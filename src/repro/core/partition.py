"""Partition metrics — the ``evaluator`` / ``toolbox`` functionality.

Objectives from the paper §1:
  * edge cut           ω(E ∩ ⋃_{i<j} V_i × V_j)
  * balance            max_i c(V_i) / ⌈c(V)/k⌉  must be ≤ 1+ε
  * max communication volume (the KaFFPaE ``--mh_optimize_communication_volume``
    fitness): for block B, sum over v∈B of #distinct other blocks adjacent to v.

Both host (numpy) and device (jnp, jit-safe) versions are provided; the
device versions operate on CooGraph and are used inside refinement loops.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.csr import Graph, CooGraph


# -- host ---------------------------------------------------------------------

def edge_cut(g: Graph, part: np.ndarray) -> int:
    src = g.edge_sources()
    cut2 = g.adjwgt[part[src] != part[g.adjncy]].sum()
    return int(cut2) // 2


def block_weights(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    bw = np.zeros(k, dtype=np.int64)
    np.add.at(bw, part, g.vwgt)
    return bw


def balance(g: Graph, part: np.ndarray, k: int) -> float:
    """max block weight / ceil(total/k); feasible iff <= 1+eps."""
    bw = block_weights(g, part, k)
    lmax = int(np.ceil(g.total_vwgt() / k))
    return float(bw.max()) / max(lmax, 1)


def is_feasible(g: Graph, part: np.ndarray, k: int, eps: float) -> bool:
    return balance(g, part, k) <= 1.0 + eps + 1e-9


def boundary_nodes(g: Graph, part: np.ndarray) -> np.ndarray:
    src = g.edge_sources()
    cutedge = part[src] != part[g.adjncy]
    mask = np.zeros(g.n, dtype=bool)
    mask[src[cutedge]] = True
    return np.flatnonzero(mask)


def comm_volume(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """Per-block communication volume; objective = max over blocks."""
    src = g.edge_sources()
    other = part[g.adjncy]
    mine = part[src]
    ext = mine != other
    # distinct (v, other_block) pairs
    key = src[ext] * np.int64(k) + other[ext]
    uniq_v = np.unique(key) // k
    vol = np.zeros(k, dtype=np.int64)
    np.add.at(vol, part[uniq_v.astype(np.int64)], 1)
    return vol


def evaluate(g: Graph, part: np.ndarray, k: int, eps: float = 0.03) -> dict:
    """The ``evaluator`` report."""
    bw = block_weights(g, part, k)
    return {
        "k": k,
        "cut": edge_cut(g, part),
        "balance": balance(g, part, k),
        "feasible": is_feasible(g, part, k, eps),
        "max_block": int(bw.max()),
        "min_block": int(bw.min()),
        "boundary_nodes": int(len(boundary_nodes(g, part))),
        "max_comm_volume": int(comm_volume(g, part, k).max()) if k > 1 else 0,
    }


def edge_partition_metrics(g: Graph, edge_part: np.ndarray, k: int) -> dict:
    """Edge-partition quality: vertex replication factor (paper §2.7).

    edge_part[j] is the block of undirected edge j (edges in from_edges
    canonical lo<hi order).
    """
    src = g.edge_sources()
    fwd = src < g.adjncy
    u, v = src[fwd], g.adjncy[fwd]
    reps = np.unique(np.stack([np.concatenate([u, v]),
                               np.concatenate([edge_part, edge_part])], 1), axis=0)
    counts = np.bincount(reps[:, 0], minlength=g.n)
    sizes = np.bincount(edge_part, minlength=k)
    return {
        "replication": float(counts.sum()) / max(g.n, 1),
        "max_block_edges": int(sizes.max()),
        "balance": float(sizes.max()) / max(int(np.ceil(len(u) / k)), 1),
    }


# -- device -------------------------------------------------------------------

def edge_cut_device(g: CooGraph, labels: jnp.ndarray) -> jnp.ndarray:
    """Cut weight (counts each undirected edge once: COO stores both dirs).

    ``labels`` has length n_pad; padding edges carry w == 0 and are inert.
    """
    return jnp.sum(jnp.where(labels[g.src] != labels[g.dst], g.w, 0.0)) * 0.5


def block_weights_device(g: CooGraph, labels: jnp.ndarray, k: int) -> jnp.ndarray:
    return jnp.zeros((k,), g.vwgt.dtype).at[labels].add(g.vwgt)
