"""The unified device-resident multilevel engine (DESIGN.md §7).

One driver serves every incidence medium: KaFFPa's programs (paper §2.1,
§4.1) and the kahypar hypergraph driver are the *same* multilevel loop —
build a hierarchy, run an initial-partition tournament on the coarsest
level, uncoarsen with refinement, optionally iterate cut-protected V-cycles
and time-budget restarts.  The medium-specific pieces (how to cluster, how
to contract, which device views refinement consumes, which objective is
optimized) live behind the `Medium` protocol; `GraphMedium`
(core/kaffpa.py) and `HypergraphMedium` (core/hypergraph/driver.py) are the
two adapters.  Future media (edge partitioning via the split graph, node
separators) only need the same handful of methods.

Device-view ownership: every `Medium` caches its padded device views
(CooGraph/ELL, pin-COO/ELL-H) the first time refinement needs them, so each
hierarchy level builds its views exactly once and reuses them across
refinement rounds, initial-partition tries, V-cycles and restarts.  The
``engine/view_builds`` counter in the obs registry instruments this
invariant (``view_build_count()`` is the back-compat alias) — the
regression test pins view construction to O(levels), not O(levels×rounds).

Observability (DESIGN.md §11): the engine emits hierarchical spans
(hierarchy build, per-level coarsen, the initial tournament, per-level
uncoarsen refinement, V-cycles, restarts), counters, and quality
trajectories through the recorder resolved by `recorder_of` — either the
medium's ``EngineParams.recorder`` or the ambient ``obs.use`` context.
With no recorder installed every hook is the no-op `obs.NULL`; extra
objective evaluations are guarded by ``rec.enabled`` so the disabled path
never computes, allocates or syncs for telemetry.

Protected coarsening (V-cycles §2.1 / the KaFFPaE combine operator §2.2) is
implemented once, medium-independently: `cluster` receives the partitions
to protect (so it can avoid wasting merges across their cuts), and the
engine then splits every cluster by the block signature of the protected
partitions before contraction.  Signature splitting *guarantees* each
cluster is constant on every protected partition, so the partitions remain
exactly representable (and exactly evaluable) at every coarse level —
regardless of the medium or the clustering heuristic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro import obs


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def recorder_of(medium) -> Any:
    """The recorder engine code should emit to for this medium: the one
    plumbed through ``EngineParams.recorder``, else the ambient ``obs.use``
    recorder (``obs.NULL`` when observability is disabled)."""
    rec = medium.params.recorder
    return rec if rec is not None else obs.current()


def view_build_count() -> int:
    """Total device-view constructions since process start / last reset.

    Back-compat alias over the obs counter registry
    (``obs.metrics.get("engine/view_builds")``)."""
    return int(obs.metrics.get("engine/view_builds"))


def reset_view_build_count() -> None:
    obs.metrics.reset("engine/view_builds")


def _note_view_build() -> None:
    obs.metrics.inc("engine/view_builds")


# Program-identity registry for the one-compile engine (DESIGN.md §12):
# every batched refinement entry reports the static signature of the
# program it is about to run.  First sighting → ``engine/programs``
# (a compile is expected); repeat → ``engine/compile_cache_hits`` (the
# jit cache serves it).  ``engine/bucket_pads`` counts the padding rows
# spent to reach the shared pow2 batch bucket.
_seen_programs: set = set()


def note_program(*sig) -> None:
    if sig in _seen_programs:
        obs.metrics.inc("engine/compile_cache_hits")
    else:
        _seen_programs.add(sig)
        obs.metrics.inc("engine/programs")


def coarsen_stop_n(params, k: int) -> int:
    """Coarsening stop size shared by every multilevel driver: keep
    ~contraction_stop_factor·k nodes, floored at stop_n_floor.  Any params
    object with those two attributes (EngineParams, KahyparConfig) works."""
    return max(params.contraction_stop_factor * k, params.stop_n_floor)


def note_bucket_pad(nrows: int) -> None:
    if nrows:
        obs.metrics.inc("engine/bucket_pads", nrows)


def program_signatures() -> list:
    """Snapshot of every program signature seen this process — the input to
    the `repro.analysis` bucket-contract checker, which proves each shape
    field is a pow2 bucket and that no two signatures collide at one bucket
    (a recompile hazard)."""
    return sorted(_seen_programs)


class ViewCache:
    """Mixin: lazily build device views once per medium instance.

    A medium lives exactly as long as its hierarchy level, so caching on the
    instance makes view construction O(levels) for a multilevel run, and the
    level-0 views survive across V-cycles and time-budget restarts (the same
    top-level medium object is reused).
    """

    _views: Any = None

    def build_views(self):  # pragma: no cover - overridden by adapters
        raise NotImplementedError

    @property
    def views(self):
        if self._views is None:
            self._views = self.build_views()
            _note_view_build()
        return self._views


# ---------------------------------------------------------------------------
# the Medium protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineParams:
    """The medium-independent knobs the engine loop needs."""

    initial_tries: int = 4
    vcycles: int = 1                    # iterated multilevel cycles
    contraction_stop_factor: int = 40   # stop coarsening at ~factor*k nodes
    cluster_weight_factor: float = 3.0  # max cluster weight = W/(factor*k)
    stop_n_floor: int = 64              # never coarsen below this many nodes
    stall_factor: float = 0.95          # stop when a level shrinks < 5%
    recorder: Any = None                # obs.Recorder; None = ambient/NULL


@runtime_checkable
class Medium(Protocol):
    """What an incidence medium must expose to the multilevel engine.

    Partitions are host int64 arrays of length ``n``; ``cl`` maps are host
    int64 arrays mapping fine ids to coarse ids (projection is always
    ``coarse_part[cl]``, so the engine owns it).
    """

    @property
    def n(self) -> int: ...

    @property
    def params(self) -> EngineParams: ...

    def total_vwgt(self) -> int: ...

    def cluster(self, max_cluster_weight: float, seed: int,
                protect: Optional[Sequence[np.ndarray]] = None) -> np.ndarray:
        """Cluster ids per node (protected cuts should not be merged)."""
        ...

    def contract(self, clusters: np.ndarray) -> tuple["Medium", np.ndarray]:
        """Contract clusters → (coarse medium, fine→coarse map)."""
        ...

    @property
    def views(self) -> Any:
        """Cached device views for refinement (built once per level)."""
        ...

    def refine(self, part: np.ndarray, k: int, eps: float, seed: int,
               force_balance: Optional[bool] = None) -> np.ndarray:
        """Full per-level refinement pipeline; never worsens a feasible
        objective unless forced to restore balance."""
        ...

    def refine_batch(self, parts: Sequence[np.ndarray], k: int, eps: float,
                     seed: int) -> List[np.ndarray]:
        """Refine several candidates in one batched (vmapped) device call."""
        ...

    def polish(self, part: np.ndarray, k: int, eps: float,
               seed: int) -> np.ndarray:
        """Extra single-candidate polish for the tournament winner."""
        ...

    def initial_candidates(self, k: int, eps: float,
                           seed: int) -> List[np.ndarray]:
        """Raw initial partitions for the coarsest-level tournament."""
        ...

    def objective(self, part: np.ndarray) -> float: ...

    def imbalance(self, part: np.ndarray, k: int) -> float:
        """Max block weight over the ideal bound (feasible iff <= 1+eps) —
        the memetic engine's fitness tie-breaker."""
        ...

    def is_feasible(self, part: np.ndarray, k: int, eps: float) -> bool: ...


# ---------------------------------------------------------------------------
# hierarchy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Level:
    """One hierarchy level: the medium, the map from the finer level, and
    the protected partitions pushed down to this level (block-constant on
    every cluster by construction)."""

    medium: Medium
    cl: Optional[np.ndarray]                 # None at level 0
    protect: Optional[List[np.ndarray]] = None


def _signature_split(clusters: np.ndarray,
                     protect: Sequence[np.ndarray]) -> np.ndarray:
    """Split clusters by the protected partitions' block signatures, making
    every cluster constant on each protected partition.

    Labels are compressed per partition before mixing, so a protected
    "partition" may be any labelling (combine's ``pb`` can be an arbitrary
    domain-specific clustering with labels ≥ k) without signature
    collisions.
    """
    sig = np.asarray(clusters, dtype=np.int64)
    for p in protect:
        uniq, inv = np.unique(np.asarray(p, dtype=np.int64),
                              return_inverse=True)
        sig = sig * np.int64(len(uniq)) + inv
    return sig


def protect_cut_mask(src: np.ndarray, dst: np.ndarray,
                     protect: Optional[Sequence[np.ndarray]]) -> np.ndarray:
    """Directed-edge mask: True where any protected labelling is cut.

    Shared by the media's ``cluster`` implementations (graph adjacency,
    hypergraph rating-graph expansion) so the protection contract lives in
    one place.
    """
    mask = np.zeros(len(src), dtype=bool)
    for p in protect or ():
        p = np.asarray(p, dtype=np.int64)
        mask |= p[src] != p[dst]
    return mask


def build_hierarchy(medium: Medium, k: int, seed: int,
                    protect: Optional[Sequence[np.ndarray]] = None
                    ) -> List[Level]:
    """Coarsen until ~contraction_stop_factor·k nodes remain.

    With ``protect`` the hierarchy keeps every protected partition exactly
    representable (signature splitting), and the pushed-down copies ride on
    each `Level` so callers can seed the coarsest level from them.
    """
    p = medium.params
    rec = recorder_of(medium)
    cur_protect = list(protect) if protect else None
    levels = [Level(medium, None, cur_protect)]
    cur = medium
    stop_n = coarsen_stop_n(p, k)
    lvl = 0
    with rec.span("hierarchy", n=medium.n, k=k,
                  protected=len(cur_protect or ())):
        while cur.n > stop_n:
            with rec.span("coarsen", level=lvl, n=cur.n):
                max_cw = max(1.0,
                             cur.total_vwgt() / (p.cluster_weight_factor * k))
                clusters = cur.cluster(max_cw, seed + 31 * lvl,
                                       protect=cur_protect)
                if cur_protect:
                    clusters = _signature_split(clusters, cur_protect)
                coarse, cl = cur.contract(clusters)
            if coarse.n >= cur.n * p.stall_factor:
                break
            if cur_protect:
                # clusters are block-constant → scatter projects exactly
                pushed = []
                for part in cur_protect:
                    pc = np.zeros(coarse.n, dtype=np.int64)
                    pc[cl] = part
                    pushed.append(pc)
                cur_protect = pushed
            levels.append(Level(coarse, cl, cur_protect))
            cur = coarse
            lvl += 1
    rec.count("engine/hierarchies")
    rec.count("engine/levels", len(levels))
    return levels


# ---------------------------------------------------------------------------
# initial partitioning: batched tournament on the coarsest level
# ---------------------------------------------------------------------------

def _tournament_pick(medium: Medium, refined: Sequence[np.ndarray], k: int,
                     eps: float, seed: int) -> np.ndarray:
    """Winner tail shared by `initial_partition` and the wave variant:
    pick the best feasible candidate (best-any fallback) and polish it."""
    rec = recorder_of(medium)
    rec.count("engine/initial_tries", len(refined))
    best, best_obj = None, np.inf
    best_any, best_any_obj = None, np.inf
    for part in refined:
        obj = medium.objective(part)
        if obj < best_any_obj:
            best_any, best_any_obj = part, obj
        if obj < best_obj and medium.is_feasible(part, k, eps):
            best, best_obj = part, obj
    # no feasible candidate: seed from the best objective anyway — the
    # uncoarsening refiners force balance back (tight-eps media hit this)
    if best is None:
        best = best_any
        rec.count("engine/tournament_infeasible")
    if rec.enabled:
        rec.point("initial", n=medium.n,
                  objective=min(best_obj, best_any_obj),
                  feasible=best_obj < np.inf)
    return medium.polish(best, k, eps, seed)


def initial_partition(level: Level, k: int, eps: float, seed: int
                      ) -> np.ndarray:
    """Tournament over ``initial_tries`` candidates.

    All candidates are refined in ONE batched device call (vmap over seeds)
    so the tournament shares a single compile; the winner gets the medium's
    single-candidate polish (multi-try / flow on graphs).
    """
    medium = level.medium
    rec = recorder_of(medium)
    with rec.span("initial_tournament", n=medium.n, k=k):
        cands = medium.initial_candidates(k, eps, seed)
        refined = medium.refine_batch(cands, k, eps, seed)
        return _tournament_pick(medium, refined, k, eps, seed)


def initial_partition_wave(levels: Sequence[Level], k: int, eps: float,
                           seeds: Sequence[int]) -> List[np.ndarray]:
    """Tournaments for SEVERAL coarsest levels in batched device calls.

    Sibling subproblems (nested-dissection wave, DESIGN.md §12) usually
    land in the same pow2 shape bucket; levels whose media report the same
    ``bucket_key()`` get their stacked candidate tournaments refined by one
    ``refine_multi`` call instead of one call per subproblem.  Per level
    the result is bit-identical to ``initial_partition`` — rows carry the
    same per-level keys, so batching only changes which compiled program
    runs them.  Media without bucket_key/refine_multi fall back per level.
    """
    media = [lv.medium for lv in levels]
    if (len(levels) < 2
            or any(not hasattr(m, "bucket_key")
                   or not hasattr(m, "refine_multi") for m in media)):
        return [initial_partition(lv, k, eps, s)
                for lv, s in zip(levels, seeds)]
    cands = [m.initial_candidates(k, eps, s) for m, s in zip(media, seeds)]
    groups: dict = {}
    for i, m in enumerate(media):
        groups.setdefault(m.bucket_key(), []).append(i)
    refined: List[Optional[List[np.ndarray]]] = [None] * len(levels)
    for idx in groups.values():
        if len(idx) == 1:
            i = idx[0]
            refined[i] = media[i].refine_batch(cands[i], k, eps, seeds[i])
        else:
            outs = media[idx[0]].refine_multi(
                [media[i] for i in idx], [cands[i] for i in idx],
                k, eps, [seeds[i] for i in idx])
            for j, i in enumerate(idx):
                refined[i] = outs[j]
    picks = []
    for i, m in enumerate(media):
        with recorder_of(m).span("initial_tournament", n=m.n, k=k):
            picks.append(_tournament_pick(m, refined[i], k, eps, seeds[i]))
    return picks


# ---------------------------------------------------------------------------
# uncoarsening
# ---------------------------------------------------------------------------

def uncoarsen(levels: List[Level], part_coarse: np.ndarray, k: int,
              eps: float, seed: int) -> np.ndarray:
    rec = recorder_of(levels[0].medium)
    part = np.asarray(part_coarse, dtype=np.int64)
    with rec.span("uncoarsen", levels=len(levels)):
        for li in range(len(levels) - 1, 0, -1):
            part = part[levels[li].cl]           # project to the finer level
            fine = levels[li - 1].medium
            with rec.span("refine", level=li - 1, n=fine.n):
                part = fine.refine(part, k, eps, seed + li)
            if rec.enabled:
                rec.point("uncoarsen", level=li - 1, n=fine.n,
                          objective=fine.objective(part))
    return part


def multilevel(medium: Medium, k: int, eps: float, seed: int) -> np.ndarray:
    """One full multilevel cycle: coarsen, tournament, uncoarsen-refine."""
    with recorder_of(medium).span("multilevel", n=medium.n, k=k):
        levels = build_hierarchy(medium, k, seed)
        part_c = initial_partition(levels[-1], k, eps, seed)
        return uncoarsen(levels, part_c, k, eps, seed)


def population(medium: Medium, k: int, eps: float, seed: int, size: int,
               stride: int = 31) -> List[np.ndarray]:
    """Independent multilevel runs at strided seeds — the initial-population
    hook for the memetic island driver.  All runs share the medium's cached
    level-0 device views (and each run's tournament shares one compile), so
    growing a population is cheaper than ``size`` cold starts.

    Each member gets the preset's full V-cycle schedule, exactly as `run`
    applies it — so member j is bit-identical to ``run(medium, k, eps,
    seed + stride*j)`` without a time budget.  That identity (member 0 at
    the base seed == one single run) is what makes the memetic drivers
    structurally never worse than a single run at any preset."""
    ncyc = medium.params.vcycles
    out = []
    with recorder_of(medium).span("population", size=size):
        for j in range(size):
            s = seed + stride * j
            part = multilevel(medium, k, eps, s)
            for cyc in range(1, ncyc):
                part = vcycle(medium, part, k, eps, s + 7919 * cyc)
            out.append(part)
    return out


# ---------------------------------------------------------------------------
# iterated multilevel (V-cycles) and the evolutionary combine operator
# ---------------------------------------------------------------------------

def vcycle(medium: Medium, part: np.ndarray, k: int, eps: float,
           seed: int) -> np.ndarray:
    """Iterated multilevel: re-coarsen protecting the current partition's
    cut, seed the coarsest level with it, refine on the way up.  The result
    is accepted only if it does not worsen the objective (feasibly), so
    quality is non-decreasing across cycles (paper §2.1, Walshaw)."""
    rec = recorder_of(medium)
    part = np.asarray(part, dtype=np.int64)
    with rec.span("vcycle", n=medium.n, k=k):
        levels = build_hierarchy(medium, k, seed, protect=[part])
        coarsest = levels[-1]
        part_c = coarsest.protect[0] if coarsest.protect is not None else part
        part_c = coarsest.medium.refine(part_c, k, eps, seed)
        out = uncoarsen(levels, part_c, k, eps, seed)
        obj_out, obj_in = medium.objective(out), medium.objective(part)
        accepted = obj_out <= obj_in and medium.is_feasible(out, k, eps)
        rec.count("engine/vcycles")
        if rec.enabled:
            rec.point("vcycle", before=obj_in, after=obj_out,
                      accepted=accepted)
        if accepted:
            return out
        rec.count("engine/vcycles_rejected")
        return part


def combine(medium: Medium, pa: np.ndarray, pb: np.ndarray, k: int,
            eps: float, seed: int) -> np.ndarray:
    """The KaFFPaE combine operator (paper §2.2), medium-generic.

    ``pb`` may be *any* domain-specific clustering/partition — only ``pa``
    must be a feasible k-partition.  Both parents' cuts are protected during
    re-coarsening, the better valid parent seeds the coarsest level, and
    refinement (which never worsens) assembles good parts of both.
    """
    rec = recorder_of(medium)
    pa = np.asarray(pa, dtype=np.int64)
    pb = np.asarray(pb, dtype=np.int64)
    with rec.span("combine", n=medium.n, k=k):
        if pb.max() < k and medium.objective(pb) < medium.objective(pa):
            pa, pb = pb, pa          # seed from the better valid parent
        levels = build_hierarchy(medium, k, seed, protect=[pa, pb])
        coarsest = levels[-1]
        part_c = coarsest.protect[0] if coarsest.protect is not None else pa
        part_c = coarsest.medium.refine(part_c, k, eps, seed)
        rec.count("engine/combines")
        return uncoarsen(levels, part_c, k, eps, seed)


# ---------------------------------------------------------------------------
# the complete driver: cycles + time-budget restarts
# ---------------------------------------------------------------------------

def run(medium: Medium, k: int, eps: float, seed: int,
        vcycles: Optional[int] = None, time_limit: float = 0.0,
        input_partition: Optional[np.ndarray] = None) -> np.ndarray:
    """The shared program driver: multilevel (or refine an input partition),
    then iterated V-cycles, then repeated multilevel restarts under a time
    budget (paper ``--time_limit``), keeping the best feasible result."""
    if k <= 1:
        return np.zeros(medium.n, dtype=np.int64)
    rec = recorder_of(medium)
    t0 = time.monotonic()
    with rec.span("run", n=medium.n, k=k, eps=eps):
        if input_partition is not None:
            best = np.asarray(input_partition, dtype=np.int64)
            best = medium.refine(best, k, eps, seed)
        else:
            best = multilevel(medium, k, eps, seed)
        if rec.enabled:
            rec.point("cycles", cycle=0, objective=medium.objective(best),
                      imbalance=medium.imbalance(best, k))
        ncyc = medium.params.vcycles if vcycles is None else vcycles
        for cyc in range(1, ncyc):
            best = vcycle(medium, best, k, eps, seed + 7919 * cyc)
            if rec.enabled:
                rec.point("cycles", cycle=cyc,
                          objective=medium.objective(best),
                          imbalance=medium.imbalance(best, k))
        trial = 1
        while time_limit > 0 and time.monotonic() - t0 < time_limit:
            with rec.span("restart", trial=trial):
                cand = multilevel(medium, k, eps, seed + 104729 * trial)
            rec.count("engine/restarts")
            if (medium.objective(cand) < medium.objective(best)
                    and medium.is_feasible(cand, k, eps)):
                best = cand
            if rec.enabled:
                rec.point("restarts", trial=trial,
                          objective=medium.objective(best))
            trial += 1
    return best
