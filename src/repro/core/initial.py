"""Initial partitioning on the coarsest graph (paper §2.1).

KaHIP's initial partitioner is recursive bisection with region growing +
refinement.  The coarsest graph is small by construction, so this runs
host-side (numpy BFS); every bisection is polished by the device gain
refinement (core/refine.py) through the caller.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import Graph
from repro.core.partition import edge_cut


def bfs_grow_bisection(g: Graph, target_frac: float, seed: int = 0,
                       tries: int = 4) -> np.ndarray:
    """Greedy graph growing: BFS from a random seed until the visited set
    reaches ``target_frac`` of the total node weight; best cut of ``tries``.
    """
    rng = np.random.default_rng(seed)
    total = g.total_vwgt()
    target = target_frac * total
    best_part, best_cut = None, np.inf
    n = g.n
    for t in range(tries):
        start = int(rng.integers(0, n))
        visited = np.zeros(n, dtype=bool)
        frontier = [start]
        visited[start] = True
        acc = int(g.vwgt[start])
        # BFS with greedy frontier ordering (prefer high connectivity to the
        # grown region == low expected cut)
        while acc < target and frontier:
            nxt = []
            for v in frontier:
                for u in g.neighbors(v):
                    if not visited[u]:
                        visited[u] = True
                        nxt.append(int(u))
                        acc += int(g.vwgt[u])
                        if acc >= target:
                            break
                if acc >= target:
                    break
            frontier = nxt
            if not frontier and acc < target:
                rest = np.flatnonzero(~visited)
                if len(rest) == 0:
                    break
                s2 = int(rng.choice(rest))
                visited[s2] = True
                frontier = [s2]
                acc += int(g.vwgt[s2])
        part = (~visited).astype(np.int64)    # grown region = block 0
        cut = edge_cut(g, part)
        if cut < best_cut:
            best_cut, best_part = cut, part
    return best_part


def random_partition(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # weight-aware striping after a random shuffle: near-perfect balance
    order = rng.permutation(g.n)
    cw = np.cumsum(g.vwgt[order])
    total = cw[-1] if g.n else 0
    bounds = total * (np.arange(1, k + 1) / k)
    blk = np.searchsorted(bounds, cw, side="left").clip(0, k - 1)
    part = np.empty(g.n, dtype=np.int64)
    part[order] = blk
    return part


def recursive_bisection(g: Graph, k: int, seed: int = 0,
                        refine_fn=None) -> np.ndarray:
    """k-way via recursive bisection; ``refine_fn(g, part, k, frac)`` may
    polish each 2-way split (device refinement plugged in by kaffpa)."""
    part = np.zeros(g.n, dtype=np.int64)
    _rb(g, np.arange(g.n), k, 0, part, seed, refine_fn)
    return part


def _rb(g: Graph, ids: np.ndarray, k: int, offset: int, out: np.ndarray,
        seed: int, refine_fn) -> None:
    if k == 1 or g.n == 0:
        out[ids] = offset
        return
    k1 = k // 2
    frac = k1 / k
    frac0 = 1.0 - frac                  # weight fraction of block 0 (k-k1 parts)
    two = bfs_grow_bisection(g, frac0, seed=seed)
    if refine_fn is not None:
        two = refine_fn(g, two, frac0)  # polish the 2-way split on device
    m0 = two == 0
    sub0, ids0 = g.subgraph(m0)
    sub1, ids1 = g.subgraph(~m0)
    _rb(sub0, ids[ids0], k - k1, offset, out, seed * 2 + 1, refine_fn)
    _rb(sub1, ids[ids1], k1, offset + (k - k1), out, seed * 2 + 2, refine_fn)
