"""TPU topology ↔ KaHIP process mapping (the paper's §2.6 applied to the LM
framework, DESIGN.md §3).

The compiled train step's collective traffic is summarized as a
communication matrix over *logical mesh axes*; the physical system is a
hierarchy (chip < ICI ring < pod < DCI).  KaHIP's multisection mapping then
decides which logical axis lands on which physical level — i.e. the axis
order of ``make_production_mesh`` — by minimizing the QAP objective with the
per-level distances.

Hardware constants (TPU v5e-ish, assignment spec): 50 GB/s/link ICI,
~5× slower DCI between pods → distances 1 (intra-ring), 10 (cross-ring,
same pod), 100 (cross-pod).
"""
from __future__ import annotations

import itertools
import re
from typing import Dict, Sequence

import numpy as np

from repro.core.mapping import (processor_distance_matrix, qap_cost,
                                process_mapping)


def collective_traffic_by_axis(collective_bytes: Dict[str, float],
                               axis_sizes: Dict[str, int]) -> Dict[str, float]:
    """Per-mesh-axis bytes from the dry-run's parsed collective table
    (roofline.py emits bytes keyed by the axes each collective runs over)."""
    return {a: collective_bytes.get(a, 0.0) for a in axis_sizes}


def axis_comm_matrix(device_pairs_bytes: np.ndarray) -> np.ndarray:
    return device_pairs_bytes


def build_device_comm_matrix(axis_bytes: Dict[str, float],
                             axis_sizes: Dict[str, int]) -> np.ndarray:
    """Expand per-axis collective bytes into a device×device communication
    matrix: a collective over axis a moves bytes between devices that differ
    only in their coordinate on a (ring neighbours for all-reduce)."""
    names = list(axis_sizes)
    sizes = [axis_sizes[n] for n in names]
    k = int(np.prod(sizes))
    comm = np.zeros((k, k))
    coords = list(itertools.product(*[range(s) for s in sizes]))
    index = {c: i for i, c in enumerate(coords)}
    for ai, a in enumerate(names):
        per_link = axis_bytes.get(a, 0.0) / max(k, 1)
        if per_link <= 0:
            continue
        for c in coords:
            nxt = list(c)
            nxt[ai] = (nxt[ai] + 1) % sizes[ai]
            i, j = index[c], index[tuple(nxt)]
            comm[i, j] += per_link
            comm[j, i] += per_link
    return comm


def choose_axis_assignment(axis_bytes: Dict[str, float],
                           axis_sizes: Dict[str, int],
                           hierarchy: Sequence[int] = (16, 16, 2),
                           distances: Sequence[int] = (1, 10, 100),
                           seed: int = 0) -> dict:
    """Run the paper's mapping on the step's communication structure.

    Returns dict(mapping=…, qap=…, identity_qap=…, improvement=…).
    The identity mapping corresponds to the naive axis order; the returned
    mapping is what launch scripts should use to permute device ids.
    """
    comm = build_device_comm_matrix(axis_bytes, axis_sizes)
    k = comm.shape[0]
    assert k == int(np.prod(hierarchy)), (k, hierarchy)
    dist = processor_distance_matrix(list(hierarchy), list(distances))
    identity = np.arange(k)
    id_cost = qap_cost(comm.astype(np.int64), dist, identity)
    mapping = process_mapping(comm.astype(np.int64), list(hierarchy),
                              list(distances), seed=seed)
    m_cost = qap_cost(comm.astype(np.int64), dist, mapping)
    return {
        "mapping": mapping,
        "qap": int(m_cost),
        "identity_qap": int(id_cost),
        "improvement": 0.0 if id_cost == 0 else 1.0 - m_cost / id_cost,
    }
