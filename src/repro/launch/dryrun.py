import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × input shape × mesh) cell:
  jit(step).lower(**ShapeDtypeStruct inputs).compile()
on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh, printing
memory_analysis() (it fits) and cost_analysis() (FLOPs/bytes for §Roofline),
plus a collective-bytes table parsed from the compiled HLO.

Usage:
  python -m repro.launch.dryrun --arch minicpm_2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` and is skipped if the
file already exists (resumable).  ``--subproc`` (default with --all) runs
each cell in a fresh interpreter so compilations can't accumulate RSS.
"""
import argparse
import json
import re
import subprocess
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, cell_is_applicable,
                                get_config)
from repro.launch.mesh import make_production_mesh

PARAM_DTYPE = jnp.bfloat16
CACHE_DTYPE = jnp.bfloat16

# hardware constants (assignment): TPU v5e-class chip
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)


def _spec_tree(tree, mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def build_cell(arch_id: str, shape_name: str, extra: dict | None = None,
               cfg=None):
    """Returns (fn, args ShapeDtype pytree, in_spec pytree builder)."""
    from repro.models import transformer as T
    from repro.models import shardings as SH
    from repro.train.train_step import make_train_step, init_opt_state
    from repro.train.optimizer import OptConfig
    from repro.serve.serve_step import prefill_step, decode_step

    cfg = cfg if cfg is not None else get_config(arch_id)
    shp = SHAPES[shape_name]
    kind = shp["kind"]
    b, s = shp["global_batch"], shp["seq_len"]
    extra = extra or {}
    remat = extra.get("remat", "full")

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: T.init_params(cfg, key, PARAM_DTYPE))

    def batch_struct():
        n_text = s - cfg.n_prefix_embeds
        out = {"tokens": jax.ShapeDtypeStruct((b, n_text + 1), jnp.int32)}
        if cfg.n_prefix_embeds:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, cfg.d_model), PARAM_DTYPE)
        if cfg.enc_layers:
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_positions, cfg.d_model), PARAM_DTYPE)
        return out

    if kind == "train":
        opt_shape = jax.eval_shape(
            lambda: init_opt_state(
                jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype),
                             params_shape)))
        step = make_train_step(cfg, OptConfig(), remat=remat,
                               microbatches=int(extra.get("microbatch", 1)))
        args = (params_shape, opt_shape, batch_struct())

        def in_specs(mesh):
            axes = mesh.axis_names
            pspec = SH.param_specs(params_shape, axes)
            ospec = {"mu": pspec, "nu": pspec, "step": P()}
            bax = SH.batch_axes_for(mesh, b)
            bspec = {"tokens": P(bax, None)}
            if cfg.n_prefix_embeds:
                bspec["prefix_embeds"] = P(bax, None, None)
            if cfg.enc_layers:
                bspec["enc_frames"] = P(bax, None, None)
            return (pspec, ospec, bspec)

        def out_specs(mesh):
            axes = mesh.axis_names
            pspec = SH.param_specs(params_shape, axes)
            ospec = {"mu": pspec, "nu": pspec, "step": P()}
            return (pspec, ospec, None)
        return cfg, step, args, in_specs, out_specs

    caches_shape = jax.eval_shape(
        lambda: T.init_caches(cfg, b, s, CACHE_DTYPE))

    if kind == "prefill":
        extra_names = []
        extras = []
        if cfg.n_prefix_embeds:
            extra_names.append("prefix_embeds")
            extras.append(jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_embeds, cfg.d_model), PARAM_DTYPE))
        if cfg.enc_layers:
            extra_names.append("enc_frames")
            extras.append(jax.ShapeDtypeStruct(
                (b, cfg.enc_positions, cfg.d_model), PARAM_DTYPE))

        def step(params, tokens, caches, *rest):
            return prefill_step(params, cfg, tokens, caches,
                                **dict(zip(extra_names, rest)))
        n_text = s - cfg.n_prefix_embeds
        args = [params_shape,
                jax.ShapeDtypeStruct((b, n_text), jnp.int32), caches_shape,
                *extras]

        def in_specs(mesh):
            axes = mesh.axis_names
            bsp = SH.batch_axes_for(mesh, b)
            sp = [SH.param_specs(params_shape, axes), P(bsp, None),
                  SH.cache_specs(caches_shape, mesh, b)]
            sp += [P(bsp, None, None)] * len(extras)
            return tuple(sp)

        def out_specs(mesh):
            return (None, SH.cache_specs(caches_shape, mesh, b))
        return cfg, step, args, in_specs, out_specs

    # decode
    def step(params, last, caches, pos):
        return decode_step(params, cfg, last, caches, pos)
    args = [params_shape, jax.ShapeDtypeStruct((b, 1), jnp.int32),
            caches_shape, jax.ShapeDtypeStruct((), jnp.int32)]

    def in_specs(mesh):
        axes = mesh.axis_names
        bsp = SH.batch_axes_for(mesh, b)
        return (SH.param_specs(params_shape, axes), P(bsp, None),
                SH.cache_specs(caches_shape, mesh, b), P())

    def out_specs(mesh):
        return (None, SH.cache_specs(caches_shape, mesh, b))
    return cfg, step, args, in_specs, out_specs


_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo: str) -> dict:
    """Sum operand bytes per collective kind (+ per replica-group size)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    by_group: dict = {}
    n_ops = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s+(?:\([^)]*\)|\S+)\s+(all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)"
                      r"(?:-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in stripped:
            continue
        shapes = list(_SHAPE_RE.finditer(stripped.split("=", 1)[0]))
        if not shapes:
            shapes = list(_SHAPE_RE.finditer(stripped))
            shapes = shapes[:1]
        result_bytes = sum(_shape_bytes(s) for s in shapes)
        # replica group size
        gsize = None
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", stripped)
        if gm:
            gsize = int(gm.group(2))
        else:
            gm2 = re.search(r"replica_groups=\{\{([0-9, ]+)\}", stripped)
            if gm2:
                gsize = len(gm2.group(1).split(","))
        gsize = gsize or 1
        # operand bytes: all-gather result is gathered (operand = out/g);
        # reduce-scatter operand = out*g; others in == out
        if kind == "all-gather":
            op_bytes = result_bytes / max(gsize, 1)
        elif kind == "reduce-scatter":
            op_bytes = result_bytes * max(gsize, 1)
        else:
            op_bytes = result_bytes
        out[kind] += op_bytes
        key = f"{kind}:g{gsize}"
        by_group[key] = by_group.get(key, 0.0) + op_bytes
        n_ops += 1
    out["by_group"] = by_group
    out["n_ops"] = n_ops
    out["total_operand_bytes"] = float(sum(out[k] for k in _COLLECTIVES))
    return out


def _lower_compile(cfg, arch_id, shape_name, mesh, extra):
    """lower+compile one variant; returns (compiled, lowered)."""
    from repro.models import shardings as SH
    cfg2, step, args, in_specs_fn, out_specs_fn = build_cell(
        arch_id, shape_name, extra, cfg=cfg)
    kw = {}
    if isinstance(step, tuple):
        step, kw = step
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs_fn(mesh),
                         is_leaf=lambda x: isinstance(x, P))
    with SH.use_mesh(mesh):
        f = jax.jit(step, in_shardings=in_sh)
        lowered = f.lower(*args, **kw)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total_operand_bytes"],
            "coll_by_group": coll["by_group"]}


def corrected_costs(arch_id, shape_name, mesh, extra):
    """XLA cost_analysis counts while-loop bodies ONCE (verified) — lower
    1-unit and 2-unit depth variants and extrapolate:
        total = F1 + (trips - 1)·(F2 - F1)
    applied to flops, bytes, and collective bytes.  The attention KV scan
    and the hybrid inner scan are fully unrolled in the HLO, so the layer
    scan is the only loop left to correct (plus whisper's encoder scan,
    solved with a third variant)."""
    import dataclasses
    # variants must not wrap the work in the (while-loop) microbatch scan —
    # same total tokens at microbatch=1 gives loop-free accounting; the
    # accumulate-buffer traffic (MB × params f32 add) is added analytically
    extra = dict(extra or {})
    mb = int(extra.pop("microbatch", 1))
    cfg = get_config(arch_id)
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    trips = cfg.n_layers // unit
    v1 = dataclasses.replace(cfg, n_layers=unit,
                             enc_layers=min(cfg.enc_layers, 1))
    v2 = dataclasses.replace(cfg, n_layers=2 * unit,
                             enc_layers=min(cfg.enc_layers, 1))
    from repro.models.transformer import layer_unroll
    with layer_unroll(4):
        f1 = _cost_of(_lower_compile(v1, arch_id, shape_name, mesh, extra))
        f2 = _cost_of(_lower_compile(v2, arch_id, shape_name, mesh, extra))

    def combine(key):
        body = f2[key] - f1[key]
        return f1[key] + (trips - 1) * body

    out = {k: combine(k) for k in ("flops", "bytes", "coll")}
    if mb > 1 and SHAPES[shape_name]["kind"] == "train":
        # grad-accumulation adds MB read-modify-write passes over f32 grads
        import math
        n_chips_est = 1
        for v in mesh.shape.values():
            n_chips_est *= v
        accum = 3.0 * 4.0 * cfg.param_count() / n_chips_est
        out["bytes"] += mb * accum
    # collective per-group table, extrapolated the same way
    groups = set(f1["coll_by_group"]) | set(f2["coll_by_group"])
    out["coll_by_group"] = {
        g: f1["coll_by_group"].get(g, 0.0)
        + (trips - 1) * (f2["coll_by_group"].get(g, 0.0)
                         - f1["coll_by_group"].get(g, 0.0))
        for g in groups}
    if cfg.enc_layers > 1:
        v3 = dataclasses.replace(cfg, n_layers=unit, enc_layers=2)
        with layer_unroll(4):
            f3 = _cost_of(_lower_compile(v3, arch_id, shape_name, mesh,
                                         extra))
        for k in ("flops", "bytes", "coll"):
            out[k] += (cfg.enc_layers - 1) * (f3[k] - f1[k])
    return out


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: str, extra: dict | None = None) -> dict:
    cfg = get_config(arch_id)
    ok, why = cell_is_applicable(cfg, shape_name)
    tag = f"{arch_id}__{shape_name}__{mesh_kind}"
    if extra and extra.get("tag"):
        tag += "__" + extra["tag"]
    path = os.path.join(out_dir, tag + ".json")
    if not ok:
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
               "skipped": why}
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {tag}: {why}")
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    cfg, step, args, in_specs_fn, out_specs_fn = build_cell(
        arch_id, shape_name, extra)
    kw = {}
    if isinstance(step, tuple):
        step, kw = step
    in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), in_specs_fn(mesh),
                         is_leaf=lambda x: isinstance(x, P))
    out_sp = out_specs_fn(mesh)
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), out_sp,
                          is_leaf=lambda x: isinstance(x, P)) \
        if out_sp is not None else None
    from repro.models import shardings as SH
    jit_kwargs = dict(in_shardings=in_sh)
    if (extra or {}).get("donate"):
        # alias state buffers in/out: params+opt for train, caches for serve
        shp_kind = SHAPES[shape_name]["kind"]
        jit_kwargs["donate_argnums"] = (0, 1) if shp_kind == "train" else (2,)
    with SH.use_mesh(mesh):
        f = jax.jit(step, **jit_kwargs)
        lowered = f.lower(*args, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    corr = corrected_costs(arch_id, shape_name, mesh, extra)
    shp = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shp["kind"] == "train":
        tokens = shp["global_batch"] * shp["seq_len"]
        model_flops = 6.0 * n_active * tokens
    elif shp["kind"] == "prefill":
        tokens = shp["global_batch"] * shp["seq_len"]
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shp["global_batch"]
        model_flops = 2.0 * n_active * tokens
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "n_chips": n_chips,
        "kind": shp["kind"],
        "extra": extra or {},
        "params_total": n_total, "params_active": n_active,
        "tokens_per_step": tokens,
        "model_flops": model_flops,
        "hlo_flops_raw": float(cost.get("flops", -1.0)),
        "hlo_bytes_raw": float(cost.get("bytes accessed", -1.0)),
        "hlo_flops": corr["flops"],
        "hlo_bytes": corr["bytes"],
        "collective_bytes": corr["coll"],
        "collective_by_group": corr["coll_by_group"],
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", -1),
        },
        "collectives": coll,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ok] {tag}: flops={rec['hlo_flops']:.3e} "
          f"bytes={rec['hlo_bytes']:.3e} "
          f"coll={rec['collective_bytes']:.3e}B "
          f"model/hlo={rec['model_flops']/max(rec['hlo_flops']*rec['n_chips'],1):.2f} "
          f"({rec['lower_s']:.0f}s lower, {rec['compile_s']:.0f}s compile)")
    print("  memory:", rec["memory_analysis"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--inline", action="store_true",
                    help="run cells in-process (default: subprocess per cell)")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    extra = {"remat": args.remat}
    if args.microbatch > 1:
        extra["microbatch"] = args.microbatch
    if args.donate:
        extra["donate"] = True
    if args.tag:
        extra["tag"] = args.tag
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not args.all:
        assert args.arch and args.shape
        for mk in meshes:
            run_cell(args.arch, args.shape, mk, args.out, extra)
        return
    failures = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mk in meshes:
                tag = f"{arch}__{shape}__{mk}"
                if args.tag:
                    tag += "__" + args.tag
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[cached] {tag}")
                    continue
                if args.inline:
                    run_cell(arch, shape, mk, args.out, extra)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mk,
                       "--out", args.out, "--remat", args.remat]
                if args.tag:
                    cmd += ["--tag", args.tag]
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append(tag)
                    print(f"[FAIL] {tag}")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL CELLS OK")


if __name__ == "__main__":
    main()
