"""llama4-scout-17b-a16e [moe]: 16 experts top-1 + shared expert; early
fusion is a stub (text tokens only)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=16, n_shared_experts=1, top_k=1, d_ff_expert=8192,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
