"""gemma2-9b [dense]: local(4096)/global alternating attention, logit
softcaps, post-norms [arXiv:2408.00118; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab=256000, head_dim=256,
    window=4096, local_global_alternate=True,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    source="arXiv:2408.00118; hf",
)
