"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared + 160 routed top-6
experts [arXiv:2405.04434; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    kv_lora=512, q_lora=1536, rope_head_dim=64, nope_head_dim=128,
    v_head_dim=128, tie_embeddings=False,
    source="arXiv:2405.04434; hf",
)
