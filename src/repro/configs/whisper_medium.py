"""whisper-medium [audio]: enc-dec backbone; conv frontend is a STUB —
input_specs provides precomputed (B, 1500, d) frame embeddings
[arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    enc_layers=24, enc_positions=1500, tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
