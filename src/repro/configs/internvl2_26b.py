"""internvl2-26b [vlm]: InternViT frontend STUB (256 patch embeddings prefix)
+ InternLM2-20B-like dense GQA backbone [arXiv:2404.16821; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553,
    n_prefix_embeds=256, tie_embeddings=False,
    source="arXiv:2404.16821; hf",
)
