"""Architecture configs: one frozen dataclass drives every model family.

Each assigned architecture gets a module ``configs/<id>.py`` exporting
``CONFIG`` (the exact published shape) — ``reduced()`` derives the smoke-test
version (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    mlp_gelu: bool = False            # 2-matrix GELU MLP (starcoder2)
    # attention flavour
    rope_theta: float = 10_000.0
    window: Optional[int] = None              # sliding-window size
    local_global_alternate: bool = False      # gemma2: even layers local
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    # hybrid (zamba2): one weight-shared attention block every `attn_every`
    attn_every: int = 0
    # ssm (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv: bool = False
    # moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: Optional[int] = None
    capacity_factor: float = 1.25
    # mla (deepseek)
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_positions: int = 1500                 # stubbed frame count
    # modality frontend stub (vlm/audio): prefix embeddings fed directly
    n_prefix_embeds: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def vocab_pad(self) -> int:
        """Vocab rounded to 512 so the embedding shards on any mesh axis
        (the standard padded-vocab trick; logits beyond vocab are unused)."""
        return ((self.vocab + 511) // 512) * 512

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm" and self.rwkv:
            # rwkv6: time-mix (r,k,v,g,o) 5·d² + cm receptance d² + channel-mix
            per_layer = 6 * d * d + 2 * d * self.d_ff
        elif self.family in ("hybrid",):
            di = self.d_inner
            n = self.ssm_state
            mamba = (d * (2 * di + 2 * n * 1 + self.ssm_nheads)  # in_proj(zx)+BC+dt
                     + di * d)                                    # out_proj
            # ONE weight-shared attention+MLP block for the whole stack
            shared = 4 * d * d + 3 * d * self.d_ff
            return int(emb + self.n_layers * mamba + shared)
        else:
            if self.is_mla:
                qk = self.nope_head_dim + self.rope_head_dim
                attn = (d * self.q_lora + self.q_lora * self.n_heads * qk
                        + d * (self.kv_lora + self.rope_head_dim)
                        + self.kv_lora * self.n_heads
                        * (self.nope_head_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d)
            else:
                attn = (d * self.n_heads * self.hd
                        + 2 * d * self.n_kv_heads * self.hd
                        + self.n_heads * self.hd * d)
            nmat = 2 if self.mlp_gelu else 3
            if self.is_moe:
                dff = self.d_ff_expert or self.d_ff
                ffn = (self.n_experts + self.n_shared_experts) * nmat * d * dff \
                    + d * self.n_experts
            else:
                ffn = nmat * d * self.d_ff
            per_layer = attn + ffn
        total = emb + (self.n_layers + self.enc_layers) * per_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dff = self.d_ff_expert or self.d_ff
        inert = (self.n_experts - self.top_k) * 3 * d * dff * self.n_layers
        return self.param_count() - int(inert)

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same wiring, tiny dims."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4
                                  // max(self.n_heads, 1)) or 1),
            d_ff=128,
            head_dim=16 if self.head_dim is not None else None,
            vocab=256,
            window=min(self.window, 32) if self.window else None,
            attn_every=2 if self.attn_every else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32,
            n_experts=min(8, self.n_experts) if self.is_moe else 0,
            n_shared_experts=min(1, self.n_shared_experts),
            top_k=min(2, self.top_k) if self.is_moe else 0,
            d_ff_expert=64 if self.d_ff_expert else None,
            kv_lora=32 if self.kv_lora else 0,
            q_lora=48 if self.q_lora else 0,
            rope_head_dim=8 if self.kv_lora else 64,
            nope_head_dim=16 if self.kv_lora else 128,
            v_head_dim=16 if self.kv_lora else 128,
            enc_layers=2 if self.enc_layers else 0,
            enc_positions=32 if self.enc_layers else 1500,
            n_prefix_embeds=min(8, self.n_prefix_embeds),
        )


# shape grid (assignment): every LM arch gets these four cells
SHAPES = {
    "train_4k":    dict(seq_len=4096,    global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,   global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,   global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288,  global_batch=1,   kind="decode"),
}

ARCH_IDS = [
    "zamba2_2p7b", "whisper_medium", "internvl2_26b", "starcoder2_15b",
    "mistral_large_123b", "gemma2_9b", "minicpm_2b", "rwkv6_7b",
    "deepseek_v2_236b", "llama4_scout_17b_a16e",
]


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def cell_is_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    """Whether (arch × shape) runs, per the assignment's skip rules."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; " \
                      f"{cfg.name} is full-attention (DESIGN.md §4)"
    return True, ""
