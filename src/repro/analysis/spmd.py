"""SPMD replication checker: a race detector for distributed refinement.

Walks every `shard_map` equation in an entry's jaxpr (the `parhyp` rounds
of `hypergraph/dist.py`, the memetic ring migration) and runs a forward
dataflow analysis over the body, computing for every intermediate the set
of mesh axes it is *shard-varying* over:

  * body inputs seed from ``in_names`` (an input split over axis a is
    varying over a; a replicated input over nothing);
  * ``psum``/``pmin``/``pmax``/``all_gather`` over axis a *remove* a
    (the value becomes replicated over a);
  * ``ppermute``/``all_to_all`` keep the varying set (data moves between
    shards but stays shard-dependent);
  * ``axis_index`` *introduces* its axis;
  * everything else unions its inputs; scan/while carries run to fixpoint;
    a shard-varying cond predicate taints every branch output.

Violations: a body output whose varying set exceeds what ``out_names``
claims (the protocol requires replication there — with ``check_vma=False``
jax itself won't catch it and each shard would silently hold a different
value), and any collective whose axis name is not an axis of the mesh.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Sequence

import jax.core as core

from repro.analysis.findings import Finding
from repro.analysis.tracing import TracedEntry, eqn_label, iter_eqns

EMPTY: FrozenSet[str] = frozenset()

#: collectives that make their output replicated over the named axes
_REDUCING = ("psum", "pmin", "pmax", "all_gather")
#: collectives that permute shard-varying data (varying in, varying out)
_PERMUTING = ("ppermute", "pshuffle", "all_to_all")


def _flat_axes(names: dict) -> FrozenSet[str]:
    return frozenset(ax for axes in names.values() for ax in axes)


def _named_axes(value) -> List[str]:
    if isinstance(value, str):
        return [value]
    if isinstance(value, (tuple, list)):
        return [a for a in value if isinstance(a, str)]
    return []


class _Dataflow:
    def __init__(self, mesh_axes: FrozenSet[str], entry_name: str):
        self.mesh_axes = mesh_axes
        self.entry_name = entry_name
        self.findings: List[Finding] = []

    def _bad_axis(self, axes: Sequence[str], prim: str, path: str) -> None:
        for ax in axes:
            if ax not in self.mesh_axes:
                self.findings.append(Finding(
                    checker="spmd", severity="error", entry=self.entry_name,
                    code="bad-collective-axis", location=path,
                    message=f"{prim} over axis {ax!r} which is not an axis "
                            f"of the shard_map mesh "
                            f"{sorted(self.mesh_axes)}"))

    def run(self, jaxpr: core.Jaxpr,
            env: Dict[core.Var, FrozenSet[str]], path: str) -> None:
        read = lambda a: (EMPTY if isinstance(a, core.Literal)  # noqa: E731
                          else env.get(a, EMPTY))
        for i, eqn in enumerate(jaxpr.eqns):
            prim = eqn.primitive.name
            here = f"{path}/{eqn_label(eqn, i)}"
            ins = [read(a) for a in eqn.invars]
            union = frozenset().union(*ins) if ins else EMPTY
            if prim in _REDUCING:
                axes = _named_axes(eqn.params.get(
                    "axes", eqn.params.get("axis_name", ())))
                self._bad_axis(axes, prim, here)
                res = union - frozenset(axes)
            elif prim in _PERMUTING:
                axes = _named_axes(eqn.params.get("axis_name", ()))
                self._bad_axis(axes, prim, here)
                res = union
            elif prim == "axis_index":
                ax = eqn.params.get("axis_name")
                axes = _named_axes(ax)
                self._bad_axis(axes, prim, here)
                res = union | (frozenset(axes) & self.mesh_axes)
            elif prim == "scan":
                self._scan(eqn, ins, env, here)
                continue
            elif prim == "while":
                self._while(eqn, ins, env, here)
                continue
            elif prim == "cond":
                self._cond(eqn, ins, env, here)
                continue
            elif prim == "pjit":
                body = eqn.params["jaxpr"].jaxpr
                sub: Dict[core.Var, FrozenSet[str]] = {}
                for var, ax in zip(body.invars, ins):
                    sub[var] = ax
                self.run(body, sub, here)
                for var, bout in zip(eqn.outvars, body.outvars):
                    env[var] = (EMPTY if isinstance(bout, core.Literal)
                                else sub.get(bout, EMPTY))
                continue
            else:
                res = union
            for v in eqn.outvars:
                env[v] = res

    # -- structured control flow -------------------------------------------
    def _scan(self, eqn, ins, env, path) -> None:
        nc = eqn.params["num_consts"]
        ncarry = eqn.params["num_carry"]
        body = eqn.params["jaxpr"].jaxpr
        carry = list(ins[nc:nc + ncarry])
        sub: Dict[core.Var, FrozenSet[str]] = {}
        for _ in range(16):
            sub = {}
            seed = ins[:nc] + carry + ins[nc + ncarry:]
            for var, ax in zip(body.invars, seed):
                sub[var] = ax
            saved = list(self.findings)
            self.findings = []
            self.run(body, sub, path + ".body")
            new_findings = self.findings
            self.findings = saved
            outs = [EMPTY if isinstance(v, core.Literal) else sub.get(v, EMPTY)
                    for v in body.outvars]
            new_carry = [c | o for c, o in zip(carry, outs[:ncarry])]
            if new_carry == carry:
                self.findings.extend(new_findings)
                break
            carry = new_carry
        outs = [EMPTY if isinstance(v, core.Literal) else sub.get(v, EMPTY)
                for v in body.outvars]
        for var, ax in zip(eqn.outvars, carry + outs[ncarry:]):
            env[var] = ax

    def _while(self, eqn, ins, env, path) -> None:
        cn = eqn.params["cond_nconsts"]
        bn = eqn.params["body_nconsts"]
        cond = eqn.params["cond_jaxpr"].jaxpr
        body = eqn.params["body_jaxpr"].jaxpr
        carry = list(ins[cn + bn:])
        for _ in range(16):
            csub: Dict[core.Var, FrozenSet[str]] = {}
            for var, ax in zip(cond.invars, ins[:cn] + carry):
                csub[var] = ax
            saved = list(self.findings)
            self.findings = []
            self.run(cond, csub, path + ".cond")
            pred = (EMPTY if isinstance(cond.outvars[0], core.Literal)
                    else csub.get(cond.outvars[0], EMPTY))
            bsub: Dict[core.Var, FrozenSet[str]] = {}
            for var, ax in zip(body.invars, ins[cn:cn + bn] + carry):
                bsub[var] = ax
            self.run(body, bsub, path + ".body")
            new_findings = self.findings
            self.findings = saved
            outs = [EMPTY if isinstance(v, core.Literal)
                    else bsub.get(v, EMPTY) for v in body.outvars]
            # a shard-varying loop predicate taints every carry
            new_carry = [c | o | pred for c, o in zip(carry, outs)]
            if new_carry == carry:
                self.findings.extend(new_findings)
                break
            carry = new_carry
        for var, ax in zip(eqn.outvars, carry):
            env[var] = ax

    def _cond(self, eqn, ins, env, path) -> None:
        pred = ins[0]
        outs = None
        for bi, branch in enumerate(eqn.params["branches"]):
            bj = branch.jaxpr
            sub: Dict[core.Var, FrozenSet[str]] = {}
            for var, ax in zip(bj.invars, ins[1:]):
                sub[var] = ax
            self.run(bj, sub, f"{path}.branch[{bi}]")
            bouts = [EMPTY if isinstance(v, core.Literal)
                     else sub.get(v, EMPTY) for v in bj.outvars]
            outs = bouts if outs is None else [a | b for a, b
                                               in zip(outs, bouts)]
        for var, ax in zip(eqn.outvars, outs or []):
            env[var] = ax | pred


def check_spmd(traced: TracedEntry, entry) -> List[Finding]:
    findings: List[Finding] = []
    for site in iter_eqns(traced.closed.jaxpr):
        eqn = site.eqn
        if eqn.primitive.name != "shard_map":
            continue
        mesh = eqn.params["mesh"]
        mesh_axes = frozenset(getattr(mesh, "axis_names", ()))
        body = eqn.params["jaxpr"]
        in_names = eqn.params["in_names"]
        out_names = eqn.params["out_names"]
        flow = _Dataflow(mesh_axes, entry.name)
        env: Dict[core.Var, FrozenSet[str]] = {}
        for var, names in zip(body.invars, in_names):
            env[var] = _flat_axes(names)
        flow.run(body, env, site.path)
        findings.extend(flow.findings)
        for i, (var, names) in enumerate(zip(body.outvars, out_names)):
            if isinstance(var, core.Literal):
                continue
            claimed = _flat_axes(names)
            extra = env.get(var, EMPTY) - claimed
            if extra:
                findings.append(Finding(
                    checker="spmd", severity="error", entry=entry.name,
                    code="varying-as-replicated",
                    location=f"{site.path}.out[{i}]",
                    message=f"shard_map output {i} of {entry.name} is "
                            f"shard-varying over {sorted(extra)} but "
                            f"out_specs claims it replicated "
                            f"(axes {sorted(claimed)}) — with "
                            f"check_vma=False each shard silently holds a "
                            f"different value",
                    detail={"varying": sorted(extra),
                            "claimed": sorted(claimed)}))
    # structured control flow can re-walk bodies during fixpoint; dedupe
    seen = set()
    unique = []
    for f in findings:
        if f.key not in seen:
            seen.add(f.key)
            unique.append(f)
    return unique
