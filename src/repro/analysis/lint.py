"""Source-level lints: host syncs on the serve path, registry coverage.

**host_sync**: the serve hot loop must not synchronize with the device
except at designed sync points (the sampled token feeding python-side slot
state).  An AST walk over ``src/repro/serve/*.py`` flags ``.item()`` and
``.block_until_ready()`` calls anywhere, and device→host materialisation
(``np.asarray``/``jax.device_get``/``int(...)`` on step results) inside
``for``/``while`` bodies — except inside functions listed in the module's
``_HOST_SYNC_OK`` marker.  (Host syncs *inside* traced code show up as
ConcretizationErrors at trace time and are reported by the tracer as
``trace-error`` findings, so this lint only needs the eager glue.)

**registry**: every public driver in `core/interface.py` must map to at
least one registered entry point via `registry.DRIVER_ENTRIES` — new
drivers cannot silently opt out of analysis.
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional

from repro.analysis.findings import Finding

_SYNC_ATTRS = ("item", "block_until_ready")
_MATERIALIZE = ("asarray", "device_get", "array")


def _marker_names(tree: ast.Module) -> tuple:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "_HOST_SYNC_OK":
                    try:
                        return tuple(ast.literal_eval(node.value))
                    except ValueError:
                        return ()
    return ()


class _ServeLinter(ast.NodeVisitor):
    def __init__(self, relpath: str, allowed: tuple):
        self.relpath = relpath
        self.allowed = allowed
        self.fn_stack: List[str] = []
        self.loop_depth = 0
        self.findings: List[Finding] = []

    def _in_allowed(self) -> bool:
        return any(fn in self.allowed for fn in self.fn_stack)

    def visit_FunctionDef(self, node):
        self.fn_stack.append(node.name)
        outer_loops = self.loop_depth
        self.loop_depth = 0
        self.generic_visit(node)
        self.loop_depth = outer_loops
        self.fn_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _loop
    visit_While = _loop

    def visit_Call(self, node):
        fn = node.func
        loc = f"{self.relpath}:{node.lineno}"
        if isinstance(fn, ast.Attribute):
            if fn.attr in _SYNC_ATTRS and not self._in_allowed():
                self.findings.append(Finding(
                    checker="host_sync", severity="error", entry="serve",
                    code=f"sync-{fn.attr}", location=loc,
                    message=f".{fn.attr}() on the serve path at {loc} — a "
                            f"blocking device sync outside the designed "
                            f"sync points (_HOST_SYNC_OK)"))
            elif (fn.attr in _MATERIALIZE and self.loop_depth > 0
                    and not self._in_allowed()):
                self.findings.append(Finding(
                    checker="host_sync", severity="warning", entry="serve",
                    code="materialize-in-loop", location=loc,
                    message=f".{fn.attr}(...) inside a serve loop at {loc} "
                            f"— device→host materialisation per iteration; "
                            f"add the function to _HOST_SYNC_OK if this is "
                            f"a designed sync point"))
        self.generic_visit(node)


def _serve_dir() -> str:
    import repro.serve as S
    if getattr(S, "__file__", None):
        return os.path.dirname(os.path.abspath(S.__file__))
    return os.path.abspath(next(iter(S.__path__)))   # namespace package


def check_host_sync(serve_dir: Optional[str] = None) -> List[Finding]:
    serve_dir = serve_dir or _serve_dir()
    findings: List[Finding] = []
    for fname in sorted(os.listdir(serve_dir)):
        if not fname.endswith(".py"):
            continue
        path = os.path.join(serve_dir, fname)
        with open(path) as fh:
            tree = ast.parse(fh.read(), filename=path)
        linter = _ServeLinter(f"serve/{fname}", _marker_names(tree))
        linter.visit(tree)
        findings.extend(linter.findings)
    return findings


def check_driver_registry(driver_entries: Optional[dict] = None,
                          registry: Optional[dict] = None) -> List[Finding]:
    import inspect
    from repro.core import interface
    from repro.analysis import registry as reg
    driver_entries = (reg.DRIVER_ENTRIES if driver_entries is None
                      else driver_entries)
    registry = reg.default_registry() if registry is None else registry
    findings: List[Finding] = []
    for name in sorted(dir(interface)):
        fn = getattr(interface, name)
        if (name.startswith("_") or not inspect.isfunction(fn)
                or fn.__module__ != interface.__name__):
            continue
        entries = driver_entries.get(name)
        if not entries:
            findings.append(Finding(
                checker="registry", severity="error", entry=name,
                code="driver-unregistered", location=f"interface.{name}",
                message=f"public driver {name} has no entry in "
                        f"analysis.registry.DRIVER_ENTRIES — register a "
                        f"canonical shape spec so it cannot opt out of "
                        f"analysis"))
            continue
        for ename in entries:
            if ename not in registry:
                findings.append(Finding(
                    checker="registry", severity="error", entry=name,
                    code="driver-dangling-entry",
                    location=f"interface.{name}",
                    message=f"driver {name} maps to unknown analysis "
                            f"entry {ename!r}"))
    return findings
