"""Findings: the unit of output of every checker in `repro.analysis`.

A finding is one contract violation (or suspicious construct) at one
location in one entry point's jaxpr.  Findings serialise to a JSONL file
in the `repro.obs` journal format (DESIGN.md §11): a `kind: "recorder"`
header line followed by one event line per finding, so `obs.read_jsonl`
parses a findings file exactly like a trace journal and the two can sit
side by side in the same artifact directory.

The CI gate compares findings against a committed baseline
(`ANALYSIS_BASELINE.json`).  Baselined findings are *annotated* —
each allow entry carries the stable key plus a human reason — and any
finding whose key is not in the baseline fails the gate.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning", "info")

#: checker identifiers (the four tentpole checkers + the two lints)
CHECKERS = (
    "bucket",        # pow2 bucket / recompile-hazard contract (DESIGN §12)
    "padding",       # padding-inertness (the vw > 0 mask contract)
    "spmd",          # shard_map replication protocol (DESIGN §9)
    "hygiene",       # purity / dtype hygiene of traced regions
    "host_sync",     # AST lint: host syncs on the serve path
    "registry",      # entry-point registry coverage lint
)


@dataclasses.dataclass(frozen=True)
class Finding:
    checker: str            # one of CHECKERS
    severity: str           # one of SEVERITIES
    entry: str              # registry entry name ("" for tree-wide lints)
    code: str               # short machine code, e.g. "weak-carry"
    location: str           # jaxpr path ("scan[0].body") or file:line
    message: str            # human sentence
    detail: Optional[dict] = None

    def __post_init__(self):
        assert self.checker in CHECKERS, self.checker
        assert self.severity in SEVERITIES, self.severity

    @property
    def key(self) -> str:
        """Stable identity for baseline matching: everything except the
        message text (messages may carry volatile values like shapes)."""
        return f"{self.checker}:{self.entry}:{self.code}:{self.location}"

    def to_event(self) -> dict:
        ev = {
            "rec": "analysis",
            "kind": "finding",
            "checker": self.checker,
            "severity": self.severity,
            "entry": self.entry,
            "code": self.code,
            "location": self.location,
            "message": self.message,
            "key": self.key,
        }
        if self.detail:
            ev["detail"] = self.detail
        return ev


def write_findings_jsonl(path: str, findings: Sequence[Finding]) -> None:
    """Write findings in the obs journal format: recorder header + events.

    `obs.read_jsonl(path)` returns ``([header], [finding events])``.
    """
    per_checker: Dict[str, int] = {}
    for f in findings:
        per_checker[f.checker] = per_checker.get(f.checker, 0) + 1
    header = {
        "kind": "recorder",
        "name": "analysis",
        "counters": {f"analysis/{c}": n for c, n in sorted(per_checker.items())},
        "trajectories": {},
    }
    with open(path, "w") as fh:
        fh.write(json.dumps(header) + "\n")
        for f in findings:
            fh.write(json.dumps(f.to_event()) + "\n")


def load_baseline(path: str) -> Dict[str, str]:
    """Baseline file -> {finding key: reason}.  Missing file = empty."""
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        return {}
    assert doc.get("version") == 1, f"unknown baseline version in {path}"
    out: Dict[str, str] = {}
    for entry in doc.get("allow", []):
        out[entry["key"]] = entry.get("reason", "")
    return out


def partition_by_baseline(
    findings: Iterable[Finding], baseline: Dict[str, str]
) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (new, baselined)."""
    new: List[Finding] = []
    allowed: List[Finding] = []
    for f in findings:
        (allowed if f.key in baseline else new).append(f)
    return new, allowed
