"""Padding-inertness checker: noninterference by self-composition.

The masking contract (`kernels/ops.py` docstring, DESIGN.md §12) says the
*only* trusted padding indicator is a weight/mask of zero — padded index
slots may hold any valid id, because a sentinel like ``n_pad-1`` can alias
a real row when a dim lands exactly on its bucket.  The contract therefore
has a precise semantic reading: **the real slots of every output are a
function of the real slots of the inputs alone.**

That is a noninterference property, and the checker proves it per entry by
self-composition over the *traced* program: evaluate the entry's
ClosedJaxpr twice — once on the canonical inputs, once with deterministic
garbage written into exactly the padding slots (the entry's `PaddingSpec`
perturbation: zero-weight edges re-aimed at random vertices, masked pins
re-aimed at random nets, padding-vertex labels scrambled, padding batch
rows scrambled) — and require the projections onto real slots to be
**bit-identical**.  Any divergence means padding flowed into an accepted
move, an objective value, or a balance total, and the location is reported
with the differing output index.

Running the traced jaxpr (not the python fn) means the property is checked
for the exact program the engine ships, after jit inlining and
constant-folding.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.tracing import TracedEntry

_SEED = 0xA11A


def _eval_closed(closed, flat_args):
    import jax.core as core
    return core.jaxpr_as_fun(closed)(*flat_args)


def check_padding(traced: TracedEntry, entry) -> List[Finding]:
    if entry.padding is None:
        return []
    import jax
    rng = np.random.default_rng(_SEED)
    perturbed = entry.padding.perturb(traced.args, rng)
    base_flat = traced.flat_args
    pert_flat = jax.tree_util.tree_leaves(perturbed)
    if len(pert_flat) != len(base_flat):
        return [Finding(
            checker="padding", severity="error", entry=entry.name,
            code="bad-perturbation", location="spec",
            message=f"{entry.name}: PaddingSpec.perturb changed the arg "
                    f"tree ({len(base_flat)} -> {len(pert_flat)} leaves)")]
    out_a = _eval_closed(traced.closed, base_flat)
    out_b = _eval_closed(traced.closed, pert_flat)
    proj_a = entry.padding.project(out_a)
    proj_b = entry.padding.project(out_b)
    findings: List[Finding] = []
    for i, (a, b) in enumerate(zip(proj_a, proj_b)):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape != b.shape or not np.array_equal(a, b):
            diff = (int(np.count_nonzero(a != b))
                    if a.shape == b.shape else -1)
            findings.append(Finding(
                checker="padding", severity="error", entry=entry.name,
                code="padding-flows-into-output",
                location=f"output[{i}]",
                message=f"{entry.name}: garbage in padding slots changed "
                        f"real output {i} ({diff} differing elements) — "
                        f"padding leaked into accepted moves, objective "
                        f"values, or balance totals",
                detail={"output": i, "differing": diff}))
    return findings
