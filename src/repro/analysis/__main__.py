"""CLI: ``python -m repro.analysis`` — run all checkers, write findings
JSONL, gate against the committed baseline.

Exit status 0 when every finding is baselined (or none), 1 when new
findings exist.  ``--exercise`` (default on) first runs tiny end-to-end
driver calls so the bucket checker can cross-check real
`multilevel.note_program` signatures.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro import obs
from repro.analysis import (analyze, default_registry, exercise_drivers,
                            load_baseline, partition_by_baseline,
                            write_findings_jsonl)
from repro.analysis.findings import Finding


def _fmt(f: Finding) -> str:
    return (f"  [{f.severity:7s}] {f.checker:9s} {f.entry or '-':28s} "
            f"{f.code:26s} {f.location}\n      {f.message}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--out", default="analysis_findings.jsonl",
                    help="findings JSONL (obs read_jsonl compatible)")
    ap.add_argument("--baseline", default="ANALYSIS_BASELINE.json")
    ap.add_argument("--entries", default=None,
                    help="comma-separated entry names (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="list registered entry points and exit")
    ap.add_argument("--no-exercise", action="store_true",
                    help="skip the tiny driver runs that seed note_program "
                         "signatures for the bucket cross-check")
    args = ap.parse_args(argv)

    registry = default_registry()
    if args.list:
        for name, e in sorted(registry.items()):
            print(f"{name:28s} tags={','.join(sorted(e.tags))}"
                  + (f" drivers={','.join(e.drivers)}" if e.drivers else ""))
        return 0

    if not args.no_exercise:
        exercise_drivers()
    entries = args.entries.split(",") if args.entries else None
    findings: List[Finding] = analyze(entries=entries)
    write_findings_jsonl(args.out, findings)
    baseline = load_baseline(args.baseline)
    new, allowed = partition_by_baseline(findings, baseline)
    obs.metrics.set_gauge("analysis/new_violations", len(new))

    checked = sorted(registry) if entries is None else entries
    print(f"repro.analysis: {len(checked)} entry points, "
          f"{len(findings)} findings ({len(allowed)} baselined, "
          f"{len(new)} new) -> {args.out}")
    if allowed:
        print("baselined:")
        for f in allowed:
            print(f"  [allowed] {f.key}  ({baseline[f.key]})")
    if new:
        print("NEW findings:")
        for f in new:
            print(_fmt(f))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
