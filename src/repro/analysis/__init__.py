"""`repro.analysis` — jaxpr-level contract checking (DESIGN.md §14).

Four checkers over every registered entry point, traced on canonical
shape specs (nothing runs on real data; padding checks execute the traced
jaxpr on tiny instances):

  * **bucket**   — pow2 bucket dims + `note_program` signature hygiene
  * **padding**  — padding-inertness by self-composition (padding.py)
  * **spmd**     — shard_map replication dataflow (spmd.py)
  * **hygiene**  — callbacks in hot scans, f64, weak-type promotions

plus two source lints: **host_sync** (serve path) and **registry**
(driver coverage).  `python -m repro.analysis` runs everything, writes an
obs-journal-compatible findings JSONL and gates against
`ANALYSIS_BASELINE.json`.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.analysis.findings import (CHECKERS, Finding, load_baseline,
                                     partition_by_baseline,
                                     write_findings_jsonl)
from repro.analysis.registry import (DRIVER_ENTRIES, EntryPoint, PaddingSpec,
                                     default_registry)

__all__ = [
    "CHECKERS", "DRIVER_ENTRIES", "EntryPoint", "Finding", "PaddingSpec",
    "analyze", "analyze_entry", "default_registry", "exercise_drivers",
    "load_baseline", "partition_by_baseline", "write_findings_jsonl",
]


def analyze_entry(entry: EntryPoint) -> List[Finding]:
    """Trace one entry point and run every checker its tags request."""
    from repro.analysis import checkers, padding, spmd, tracing
    try:
        traced = tracing.trace_entry(entry)
    except Exception as exc:  # noqa: BLE001 — any trace failure is a finding
        return [Finding(
            checker="hygiene", severity="error", entry=entry.name,
            code="trace-error", location="trace",
            message=f"{entry.name} failed to trace: "
                    f"{type(exc).__name__}: {exc}")]
    out: List[Finding] = []
    if "bucket" in entry.tags:
        out.extend(checkers.check_bucket(traced, entry))
    if "hygiene" in entry.tags:
        out.extend(checkers.check_hygiene(traced, entry))
    if "spmd" in entry.tags:
        out.extend(spmd.check_spmd(traced, entry))
    if "padding" in entry.tags:
        try:
            out.extend(padding.check_padding(traced, entry))
        except Exception as exc:  # noqa: BLE001
            out.append(Finding(
                checker="padding", severity="error", entry=entry.name,
                code="eval-error", location="eval",
                message=f"{entry.name} padding self-composition failed to "
                        f"evaluate: {type(exc).__name__}: {exc}"))
    return out


def exercise_drivers() -> None:
    """Run tiny end-to-end driver calls so `multilevel.note_program` holds
    real program signatures for the bucket cross-check (the signatures are
    recorded per process; a fresh CLI run would otherwise see none)."""
    from repro.core import interface as I
    from repro.analysis.registry import _ring_graph, _tiny_hypergraph
    g = _ring_graph()
    I.kaffpa(g.n, g.vwgt, g.xadj, g.adjwgt, g.adjncy, 2, 0.1, seed=0,
             mode=I.FAST)
    hg = _tiny_hypergraph()
    I.kahypar(hg.n, hg.m, hg.vwgt, hg.ewgt, hg.eptr, hg.eind, 2, 0.1,
              seed=0, mode=I.FAST)
    I.node_separator(g.n, g.vwgt, g.xadj, g.adjwgt, g.adjncy, 2, 0.2,
                     seed=0, mode=I.FAST)


def analyze(entries: Optional[Sequence[str]] = None,
            registry: Optional[Dict[str, EntryPoint]] = None,
            lints: bool = True,
            program_registry: bool = True) -> List[Finding]:
    """Run every checker; returns findings (counters land in obs.metrics)."""
    from repro.analysis import checkers, lint
    registry = default_registry() if registry is None else registry
    names = sorted(registry) if entries is None else list(entries)
    findings: List[Finding] = []
    for name in names:
        findings.extend(analyze_entry(registry[name]))
    if program_registry:
        from repro.core import multilevel as ML
        findings.extend(
            checkers.check_program_registry(ML.program_signatures()))
    if lints:
        findings.extend(lint.check_host_sync())
        findings.extend(lint.check_driver_registry())
    per_checker: Dict[str, int] = {}
    for f in findings:
        per_checker[f.checker] = per_checker.get(f.checker, 0) + 1
    obs.metrics.inc("analysis/violations", len(findings))
    for c, n in per_checker.items():
        obs.metrics.inc(f"analysis/{c}", n)
    return findings
