"""Bucket-contract and purity/dtype-hygiene checkers.

**bucket** (DESIGN.md §12): every declared batch dim / padded extent of an
engine entry must be a pow2 bucket, every shape field of a
`multilevel.note_program` signature must be pow2, and no two distinct
signatures may land on the same bucket projection — two programs at one
bucket means a shape leaked past the bucketing and will recompile.

**hygiene**: traced regions must stay pure and dtype-stable —

  * no `pure_callback` / `debug_callback` / `io_callback` inside a
    scan/while body, except primitives an entry explicitly allowlists
    (the `moe.observe_gates` tap);
  * no float64/complex128 aval anywhere (the engine is strictly f32);
  * no weak-typed scan carry (a bare python scalar like ``jnp.inf`` in a
    carry is re-promoted against the strong side every round — the
    recompile/promotion hazard class fixed in this PR) and no weak-typed
    program output escaping the trace.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.tracing import TracedEntry, iter_eqns, scan_carry_avals

CALLBACK_PRIMITIVES = ("pure_callback", "debug_callback", "io_callback")
_BAD_DTYPES = ("float64", "complex128")


def is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


# ---------------------------------------------------------------------------
# bucket contract
# ---------------------------------------------------------------------------

def check_bucket(traced: TracedEntry, entry) -> List[Finding]:
    out: List[Finding] = []
    if entry.bucket_dims is None:
        return out
    for dim, size in sorted(entry.bucket_dims(traced.args).items()):
        if not is_pow2(int(size)):
            out.append(Finding(
                checker="bucket", severity="error", entry=entry.name,
                code="non-pow2-dim", location=f"dim:{dim}",
                message=f"{entry.name}: dim {dim}={size} is not a pow2 "
                        f"bucket (DESIGN.md §12)",
                detail={"dim": dim, "size": int(size)}))
    return out


#: per-family positions of shape fields in `multilevel.note_program`
#: signatures (the fields that must be pow2 buckets); `k_pad` additionally
#: must be a pow2 ≥ 4 (hypergraph k bucket floor).
PROGRAM_SHAPE_FIELDS: Dict[str, Dict[str, Tuple[int, ...]]] = {
    # ("kway", n_pad, e_pad, k, rounds_bucket, b_pad, use_kernel)
    "kway": {"shape": (1, 2, 5)},
    # ("hyper", n_pad, e_pad, p_pad, k_pad, rounds, objective, b_pad, uk)
    "hyper": {"shape": (1, 2, 3, 7), "k_pad": (4,)},
    # ("sep", n_pad, e_pad, rounds, b_pad, use_kernel)
    "sep": {"shape": (1, 2, 4)},
    "sepmulti": {"shape": (1, 2, 4)},
}


def _pow2_ceil(x: int) -> int:
    out = 1
    while out < x:
        out *= 2
    return out


def check_program_registry(signatures: Iterable[tuple]) -> List[Finding]:
    """Cross-check recorded `note_program` signatures: pow2 shape fields,
    and no two distinct signatures at one bucket projection (a recompile
    hazard — the second signature compiles a program the bucketing was
    supposed to share)."""
    out: List[Finding] = []
    buckets: Dict[tuple, tuple] = {}
    for sig in sorted(signatures):
        fam = sig[0]
        spec = PROGRAM_SHAPE_FIELDS.get(fam)
        if spec is None:
            out.append(Finding(
                checker="bucket", severity="warning", entry="engine",
                code="unknown-program-family", location=f"sig:{fam}",
                message=f"note_program family {fam!r} has no shape-field "
                        f"spec in the analyzer; add it to "
                        f"PROGRAM_SHAPE_FIELDS",
                detail={"sig": list(map(str, sig))}))
            continue
        for pos in spec["shape"]:
            if not is_pow2(int(sig[pos])):
                out.append(Finding(
                    checker="bucket", severity="error", entry="engine",
                    code="non-pow2-signature-field",
                    location=f"sig:{fam}[{pos}]",
                    message=f"program signature {sig} field {pos} = "
                            f"{sig[pos]} is not pow2",
                    detail={"sig": list(map(str, sig)), "pos": pos}))
        for pos in spec.get("k_pad", ()):
            if not (is_pow2(int(sig[pos])) and int(sig[pos]) >= 4):
                out.append(Finding(
                    checker="bucket", severity="error", entry="engine",
                    code="bad-k-bucket", location=f"sig:{fam}[{pos}]",
                    message=f"program signature {sig} k_pad = {sig[pos]} "
                            f"is not a pow2 >= 4",
                    detail={"sig": list(map(str, sig)), "pos": pos}))
        shape_pos = set(spec["shape"]) | set(spec.get("k_pad", ()))
        bucket = tuple(
            _pow2_ceil(int(v)) if i in shape_pos else v
            for i, v in enumerate(sig))
        prev = buckets.get(bucket)
        if prev is not None and prev != sig:
            out.append(Finding(
                checker="bucket", severity="error", entry="engine",
                code="bucket-collision", location=f"sig:{fam}",
                message=f"two program signatures share one bucket — "
                        f"recompile hazard: {prev} vs {sig}",
                detail={"a": list(map(str, prev)),
                        "b": list(map(str, sig))}))
        buckets.setdefault(bucket, sig)
    return out


# ---------------------------------------------------------------------------
# purity / dtype hygiene
# ---------------------------------------------------------------------------

def check_hygiene(traced: TracedEntry, entry) -> List[Finding]:
    out: List[Finding] = []
    jaxpr = traced.closed.jaxpr
    for site in iter_eqns(jaxpr):
        prim = site.eqn.primitive.name
        if prim in CALLBACK_PRIMITIVES:
            if site.in_loop and prim not in entry.allow_callbacks:
                out.append(Finding(
                    checker="hygiene", severity="error", entry=entry.name,
                    code="callback-in-loop", location=site.path,
                    message=f"{prim} inside a scan/while body of "
                            f"{entry.name} — a host round-trip per "
                            f"iteration (allowlist via the entry's "
                            f"allow_callbacks if intentional)"))
            elif not site.in_loop and prim not in entry.allow_callbacks:
                out.append(Finding(
                    checker="hygiene", severity="warning", entry=entry.name,
                    code="callback", location=site.path,
                    message=f"{prim} in the traced region of "
                            f"{entry.name}"))
        for v in site.eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _BAD_DTYPES:
                out.append(Finding(
                    checker="hygiene", severity="error", entry=entry.name,
                    code="wide-dtype", location=site.path,
                    message=f"{dt} value produced by {prim} in "
                            f"{entry.name} — the engine is strictly "
                            f"f32/int32"))
                break
        if prim == "scan":
            for i, aval in enumerate(scan_carry_avals(site.eqn)):
                if getattr(aval, "weak_type", False):
                    out.append(Finding(
                        checker="hygiene", severity="error",
                        entry=entry.name, code="weak-carry",
                        location=f"{site.path}.carry[{i}]",
                        message=f"weak-typed scan carry {i} "
                                f"({aval.dtype}) in {entry.name} — a bare "
                                f"python scalar (e.g. jnp.inf) in the "
                                f"carry; use an explicit dtype like "
                                f"jnp.float32(...)"))
    for i, v in enumerate(jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if getattr(aval, "weak_type", False):
            out.append(Finding(
                checker="hygiene", severity="warning", entry=entry.name,
                code="weak-output", location=f"outvar[{i}]",
                message=f"weak-typed output {i} escapes the traced region "
                        f"of {entry.name}"))
    return out
