"""Tracing entry points to jaxprs and walking them.

`trace_entry` runs `jax.make_jaxpr` on an entry point's canonical inputs
(registry.py) — abstract evaluation only, nothing is compiled or
executed.  The walker yields every equation in the program together with
a human-readable path ("scan[3].body/pjit[0]{_refine_scan}") and the
loop/shard_map context the checkers key off.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.core as core

#: primitives whose body jaxprs execute repeatedly (hot-loop context)
LOOP_PRIMITIVES = ("scan", "while")


@dataclasses.dataclass
class TracedEntry:
    name: str
    closed: core.ClosedJaxpr       # the traced program
    flat_args: List[Any]           # concrete leaves, make_jaxpr arg order
    fn: Any                        # the callable that was traced
    args: Tuple[Any, ...]          # original (pytree) arguments


def trace_entry(entry) -> TracedEntry:
    """Abstractly evaluate one registry entry to a ClosedJaxpr."""
    fn, args = entry.build()
    closed = jax.make_jaxpr(fn)(*args)
    flat = jax.tree_util.tree_leaves(args)
    return TracedEntry(name=entry.name, closed=closed, flat_args=flat,
                       fn=fn, args=args)


def _jaxpr_of(value) -> Optional[core.Jaxpr]:
    if isinstance(value, core.ClosedJaxpr):
        return value.jaxpr
    if isinstance(value, core.Jaxpr):
        return value
    return None


def sub_jaxprs(eqn: core.JaxprEqn) -> Iterator[Tuple[str, core.Jaxpr]]:
    """Yield (param name, body jaxpr) for every sub-jaxpr of an equation.

    Covers pjit/scan/while (`jaxpr` as ClosedJaxpr or Jaxpr), cond
    (`branches` tuple), and custom-call params that carry jaxprs.
    """
    for pname, value in eqn.params.items():
        j = _jaxpr_of(value)
        if j is not None:
            yield pname, j
            continue
        if isinstance(value, (tuple, list)):
            for i, item in enumerate(value):
                j = _jaxpr_of(item)
                if j is not None:
                    yield f"{pname}[{i}]", j


def eqn_label(eqn: core.JaxprEqn, index: int) -> str:
    name = eqn.params.get("name")
    prim = eqn.primitive.name
    return f"{prim}[{index}]" + (f"{{{name}}}" if name else "")


@dataclasses.dataclass(frozen=True)
class EqnSite:
    eqn: core.JaxprEqn
    path: str                 # "scan[2].body/pjit[0]{foo}"
    in_loop: bool             # inside a scan/while body
    loop_depth: int


def iter_eqns(jaxpr: core.Jaxpr, path: str = "", in_loop: bool = False,
              loop_depth: int = 0) -> Iterator[EqnSite]:
    """Depth-first walk over every equation, including all sub-jaxprs."""
    for i, eqn in enumerate(jaxpr.eqns):
        label = eqn_label(eqn, i)
        here = f"{path}/{label}" if path else label
        yield EqnSite(eqn=eqn, path=here, in_loop=in_loop,
                      loop_depth=loop_depth)
        body_is_loop = eqn.primitive.name in LOOP_PRIMITIVES
        for pname, sub in sub_jaxprs(eqn):
            sub_path = f"{here}.{pname}"
            yield from iter_eqns(
                sub, sub_path,
                in_loop=in_loop or body_is_loop,
                loop_depth=loop_depth + (1 if body_is_loop else 0))


def scan_carry_avals(eqn: core.JaxprEqn) -> Sequence[core.AbstractValue]:
    """Carry avals of a scan equation (body-jaxpr invars, post-consts)."""
    assert eqn.primitive.name == "scan"
    nc = eqn.params["num_consts"]
    ncarry = eqn.params["num_carry"]
    body = eqn.params["jaxpr"].jaxpr
    return [v.aval for v in body.invars[nc:nc + ncarry]]


def all_avals(jaxpr: core.Jaxpr) -> Iterator[Tuple[str, core.AbstractValue]]:
    """Every aval in the program with a location tag (recursive)."""
    for v in jaxpr.invars:
        yield "invar", v.aval
    for site in iter_eqns(jaxpr):
        for v in site.eqn.outvars:
            if isinstance(v, core.DropVar):
                continue
            yield site.path, v.aval
