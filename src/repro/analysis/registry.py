"""Entry-point registry: every jax-traceable program in the repo, with a
canonical (tiny, deterministic) shape spec the checkers trace it on.

The host drivers in `core/interface.py` are numpy orchestration loops; the
contracts live in the jitted inner programs they route through (the
one-compile engine programs of DESIGN.md §12, the shard_map rounds of §9,
the serve steps of §13).  So the registry registers those inner programs,
and `DRIVER_ENTRIES` maps every public driver to the entries that cover it
— the registry-hygiene lint fails when a public driver has no entry.

Each entry declares which checkers apply via `tags`, the dims that must be
pow2 buckets (`bucket_dims`), and — for entries with padded containers — a
`PaddingSpec`: a perturbation writing deterministic garbage into padding
slots only (per the masking contract in `kernels/ops.py`:
`PADDING_CONTRACT`) plus a projection selecting the *real* slots of the
outputs.  The padding-inertness checker requires the projected outputs to
be bit-identical under perturbation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PaddingSpec:
    """Noninterference spec: `perturb(args, rng)` returns args with garbage
    in padding slots only; `project(flat_outputs)` keeps the real slots."""
    perturb: Callable[[Tuple, np.random.Generator], Tuple]
    project: Callable[[Sequence], Sequence]


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    build: Callable[[], Tuple[Callable, Tuple]]   # -> (fn, args)
    tags: frozenset                               # checkers that apply
    bucket_dims: Optional[Callable[[Tuple], Dict[str, int]]] = None
    padding: Optional[PaddingSpec] = None
    allow_callbacks: Tuple[str, ...] = ()         # primitive names allowed
    drivers: Tuple[str, ...] = ()                 # interface.py publics


# ---------------------------------------------------------------------------
# canonical instances (host-side, deterministic)
# ---------------------------------------------------------------------------

def _ring_graph(n: int = 24, stride: int = 7):
    """Ring + chord graph: connected, irregular weights, tiny."""
    from repro.core.csr import Graph
    nbrs = [[] for _ in range(n)]
    for i in range(n):
        for j in ((i + 1) % n, (i + stride) % n):
            nbrs[i].append(j)
            nbrs[j].append(i)
    xadj = np.zeros(n + 1, dtype=np.int64)
    adjncy, adjwgt = [], []
    for i in range(n):
        xadj[i + 1] = xadj[i] + len(nbrs[i])
        adjncy.extend(nbrs[i])
        adjwgt.extend(1.0 + ((i + j) % 3) for j in nbrs[i])
    return Graph.from_arrays(xadj, np.asarray(adjncy, np.int64),
                             vwgt=1.0 + np.arange(n) % 2,
                             adjwgt=np.asarray(adjwgt, np.float64))


def _tiny_hypergraph(n: int = 20, m: int = 12):
    from repro.core.hypergraph.container import Hypergraph
    eptr = [0]
    eind = []
    for j in range(m):
        pins = {j % n, (j * 5 + 1) % n, (j * 3 + 7) % n, (j + n // 2) % n}
        eind.extend(sorted(pins))
        eptr.append(len(eind))
    return Hypergraph.from_arrays(
        n, np.asarray(eptr, np.int64), np.asarray(eind, np.int64),
        ewgt=1.0 + np.arange(m) % 2, vwgt=np.ones(n))


def _one_device_mesh(axis: str):
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]), (axis,))


def _garble(idx: np.ndarray, where: np.ndarray, hi: int,
            rng: np.random.Generator) -> np.ndarray:
    """Copy of ``idx`` with slots selected by ``where`` replaced by random
    valid ids in [0, hi) — the padding garbage injection."""
    out = np.array(idx)
    k = int(np.count_nonzero(where))
    if k:
        out[np.asarray(where)] = rng.integers(0, hi, size=k, dtype=out.dtype)
    return out


def _np(x):
    return np.asarray(x)


# ---------------------------------------------------------------------------
# engine entries (graph / hypergraph / separator refinement, LP clustering)
# ---------------------------------------------------------------------------

def _build_kway(use_kernel: bool):
    import jax
    from repro.core import refine as R
    from repro.core.csr import to_coo, to_ell
    g = _ring_graph()
    coo = to_coo(g)
    k, rounds, b = 3, 4, 3
    b_pad = R.batch_bucket(b)
    labs = np.zeros((b, coo.n_pad), np.int32)
    for i in range(b):
        labs[i, :g.n] = (np.arange(g.n) * (i + 1)) % k
    labs = R._pad_rows(labs, b_pad)
    rkeys = np.stack([R._round_keys(jax.random.PRNGKey(i), rounds, rounds)
                      for i in range(b_pad)])
    cap = np.asarray(R._caps_for(g, k, 0.10), np.float32)
    nrounds = np.full(b_pad, rounds, np.int32)
    zero = np.zeros(b_pad, bool)
    force = np.zeros(b_pad, bool)
    active = np.ones((b_pad, coo.n_pad), bool)
    base = (coo, labs, cap, rkeys, nrounds, zero, force, active)
    if not use_kernel:
        def fn(coo, labs, cap, rkeys, nr, z, f, a):
            return R._refine_scan_batch(coo, labs, cap, rkeys, nr, z, f, a,
                                        k, rounds)
        return fn, base
    ell = to_ell(g, row_tile=coo.n_pad)

    def fnk(coo, labs, cap, rkeys, nr, z, f, a, ell):
        return R._refine_scan_batch(coo, labs, cap, rkeys, nr, z, f, a,
                                    k, rounds, ell=ell, use_kernel=True)
    return fnk, base + (ell,)


def _kway_bucket_dims(args):
    coo, labs = args[0], args[1]
    dims = {"n_pad": coo.n_pad, "e_pad": coo.e_pad, "batch": labs.shape[0]}
    if len(args) > 8:                      # kernel variant carries the ELL
        dims["ell_dmax"] = args[8].nbr.shape[1]
    return dims


def _perturb_coo(coo, rng):
    """Garbage in CooGraph padding slots: w==0 edges may point anywhere."""
    import dataclasses as dc
    import jax.numpy as jnp
    pad = _np(coo.w) == 0
    n_pad = coo.n_pad
    return dc.replace(
        coo,
        src=jnp.asarray(_garble(_np(coo.src), pad, n_pad, rng)),
        dst=jnp.asarray(_garble(_np(coo.dst), pad, n_pad, rng)))


def _perturb_kway(args, rng):
    coo = args[0]
    n = 24                                  # real vertices of _ring_graph()
    labs = np.array(args[1])
    k = 3
    labs[:, n:] = rng.integers(0, k, size=labs[:, n:].shape, dtype=labs.dtype)
    labs[3:] = rng.integers(0, k, size=labs[3:].shape, dtype=labs.dtype)
    return (_perturb_coo(coo, rng), labs) + tuple(args[2:])


def _project_kway(outs):
    labels, cuts = outs[0], outs[1]
    return [_np(labels)[:3, :24], _np(cuts)[:3]]


def _perturb_ell(ell, rng):
    import dataclasses as dc
    import jax.numpy as jnp
    pad = _np(ell.wgt) == 0
    return dc.replace(
        ell, nbr=jnp.asarray(_garble(_np(ell.nbr), pad, ell.nbr.shape[0],
                                     rng)))


def _perturb_kway_kernel(args, rng):
    out = _perturb_kway(args[:8], rng)
    return out + (_perturb_ell(args[8], rng),)


def _build_cluster_lp():
    import jax
    from repro.core import lp as L
    from repro.core.csr import to_coo
    g = _ring_graph()
    coo = to_coo(g)
    cap = np.full(coo.n_pad, 6.0 * g.n, np.float32)
    labs = np.arange(coo.n_pad, dtype=np.int32)
    key = np.asarray(jax.random.PRNGKey(7))

    def fn(coo, labs, cap, key):
        return L._cluster_lp_jit(coo, labs, cap, key, 4)
    return fn, (coo, labs, cap, key)


def _perturb_cluster_lp(args, rng):
    coo, labs = args[0], np.array(args[1])
    labs[24:] = rng.integers(0, coo.n_pad, size=labs[24:].shape,
                             dtype=labs.dtype)
    return (_perturb_coo(coo, rng), labs) + tuple(args[2:])


def _project_cluster_lp(outs):
    return [_np(outs[0])[:24]]


def _build_hyper(objective: str):
    import jax
    from repro.core.hypergraph import refine as HR
    from repro.core.hypergraph.container import to_pincoo
    from repro.core.refine import _pad_rows
    hg = _tiny_hypergraph()
    hc = to_pincoo(hg)
    k, rounds, b = 3, 4, 2
    k_pad = HR.k_bucket(k)
    b_pad = 2
    labs = np.zeros((b, hc.n_pad), np.int32)
    for i in range(b):
        labs[i, :hg.n] = (np.arange(hg.n) + i) % k
    labs = _pad_rows(labs, b_pad)
    cap = np.zeros(k_pad, np.float32)
    cap[:k] = np.asarray(HR._caps_for(hg, k, 0.10), np.float32)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), b_pad))
    force = np.zeros(b_pad, bool)

    def fn(hc, labs, cap, keys, force):
        return HR._hyper_refine_scan_batch(hc, labs, cap, keys, force,
                                           k_pad, rounds, objective, False)
    return fn, (hc, labs, cap, keys, force)


def _hyper_bucket_dims(args):
    hc, labs, cap = args[0], args[1], args[2]
    return {"n_pad": hc.n_pad, "e_pad": hc.e_pad, "p_pad": hc.p_pad,
            "k_pad": cap.shape[0], "batch": labs.shape[0]}


def _perturb_pincoo(hc, rng):
    import dataclasses as dc
    import jax.numpy as jnp
    pad = _np(hc.mask) == 0
    return dc.replace(
        hc,
        pv=jnp.asarray(_garble(_np(hc.pv), pad, hc.n_pad, rng)),
        pe=jnp.asarray(_garble(_np(hc.pe), pad, hc.e_pad, rng)))


def _perturb_hyper(args, rng):
    hc = args[0]
    labs = np.array(args[1])
    labs[:, 20:] = rng.integers(0, 3, size=labs[:, 20:].shape,
                                dtype=labs.dtype)
    return (_perturb_pincoo(hc, rng), labs) + tuple(args[2:])


def _project_hyper(outs):
    return [_np(outs[0])[:, :20], _np(outs[1])]


def _build_sep():
    import jax
    from repro.core.nodesep import refine as SR
    from repro.core.csr import to_coo
    g = _ring_graph()
    coo = to_coo(g)
    rounds, b = 4, 2
    labs = np.full((b, coo.n_pad), 2, np.int32)     # everything separator
    labs[:, :g.n] = np.arange(g.n)[None, :] % 2
    labs[0, : g.n // 2] = 2
    cap = np.asarray(SR.separator_caps(g, 0.20), np.float32)
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), b))
    force = np.zeros(b, bool)

    def fn(coo, labs, cap, keys, force):
        return SR._sep_refine_scan_batch(coo, labs, cap, keys, force, rounds)
    return fn, (coo, labs, cap, keys, force)


def _perturb_sep(args, rng):
    coo = args[0]
    labs = np.array(args[1])
    labs[:, 24:] = rng.integers(0, 3, size=labs[:, 24:].shape,
                                dtype=labs.dtype)
    return (_perturb_coo(coo, rng), labs) + tuple(args[2:])


def _project_sep(outs):
    return [_np(outs[0])[:, :24], _np(outs[1])]


# ---------------------------------------------------------------------------
# distributed / memetic entries (shard_map)
# ---------------------------------------------------------------------------

def _two_device_mesh_11():
    """1-device 2-D (nets, verts) mesh — the canonical 2-D layout spec."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("nets", "verts"))


def _build_parhyp(two_d: bool = False):
    import jax
    from repro.core.hypergraph import dist as D
    from repro.core.hypergraph.refine import _caps_for, _pad_caps, k_bucket
    hg = _tiny_hypergraph()
    sh = D.shard_hypergraph(hg, (1, 1) if two_d else 1)
    mesh = _two_device_mesh_11() if two_d else _one_device_mesh("nets")
    k, rounds = 3, 4
    k_pad = k_bucket(k)
    cap = np.asarray(_pad_caps(_caps_for(hg, k, 0.10), k_pad), np.float32)
    labels0 = np.zeros(sh.n_pad, np.int32)
    labels0[:hg.n] = np.arange(hg.n) % k
    key = np.asarray(jax.random.PRNGKey(11))
    force = np.asarray(False)

    def fn(pv, pe, mask, netw, esize, vwgt, labels0, cap, key, force):
        return D._parhyp_refine_jit(mesh, pv, pe, mask, netw, esize, vwgt,
                                    labels0, cap, key, force, sh.rows_v,
                                    sh.n_col, sh.e_rows, k_pad, rounds,
                                    "km1")
    return fn, (sh.pv, sh.pe, sh.mask, sh.netw, sh.esize, sh.vwgt,
                labels0, cap, key, force)


def _parhyp_bucket_dims(args):
    pv, netw, vwgt = args[0], args[3], args[5]
    return {"p_shard": pv.shape[1], "e_pad": netw.shape[0],
            "n_pad": vwgt.shape[0], "k_pad": args[7].shape[0]}


def _perturb_parhyp(args, rng):
    pv, pe, mask = (np.array(a) for a in args[:3])
    n_pad, e_pad = args[5].shape[0], args[3].shape[0]
    pad = mask == 0
    pv = _garble(pv, pad, n_pad, rng)
    pe = _garble(pe, pad, e_pad, rng)
    labels0 = np.array(args[6])
    labels0[20:] = rng.integers(0, 3, size=labels0[20:].shape,
                                dtype=labels0.dtype)
    return (pv, pe, mask) + tuple(args[3:6]) + (labels0,) + tuple(args[7:])


def _project_parhyp(outs):
    return [_np(outs[0])[:20], _np(outs[1]), _np(outs[2])]


def _build_parhyp_cluster():
    from repro.core.hypergraph import dist as D
    hg = _tiny_hypergraph()
    sh = D.shard_hypergraph(hg, 1)
    mesh = _one_device_mesh("nets")
    labels0 = np.arange(sh.n_pad, dtype=np.int32)
    capv = np.full(sh.n_pad, 8.0, np.float32)
    parity0 = np.int32(0)

    def fn(pv, pe, mask, netw, esize, vwgt, labels0, capv, parity0):
        return D._parhyp_cluster_jit(mesh, pv, pe, mask, netw, esize, vwgt,
                                     labels0, capv, parity0, sh.rows_v,
                                     sh.n_col, sh.e_rows, 4)
    return fn, (sh.pv, sh.pe, sh.mask, sh.netw, sh.esize, sh.vwgt,
                labels0, capv, parity0)


def _cluster_bucket_dims(args):
    pv, netw, vwgt = args[0], args[3], args[5]
    return {"p_shard": pv.shape[1], "e_pad": netw.shape[0],
            "n_pad": vwgt.shape[0]}


def _perturb_parhyp_cluster(args, rng):
    pv, pe, mask = (np.array(a) for a in args[:3])
    n_pad, e_pad = args[5].shape[0], args[3].shape[0]
    pad = mask == 0
    pv = _garble(pv, pad, n_pad, rng)
    pe = _garble(pe, pad, e_pad, rng)
    # padding vertices (vwgt 0) may start in any singleton cluster
    labels0 = np.array(args[6])
    labels0[20:] = rng.integers(20, n_pad, size=labels0[20:].shape,
                                dtype=labels0.dtype)
    return (pv, pe, mask) + tuple(args[3:6]) + (labels0,) + tuple(args[7:])


def _project_parhyp_cluster(outs):
    return [_np(outs[0])[:20], _np(outs[1])]


def _build_parhyp_contract():
    from repro.core.hypergraph import dist as D
    hg = _tiny_hypergraph()
    sh = D.shard_hypergraph(hg, 1)
    mesh = _one_device_mesh("nets")
    labels = (np.arange(sh.n_pad, dtype=np.int32) // 2) * 2

    def fn(pv, pe, mask, netw, vwgt, labels):
        return D._parhyp_contract_jit(mesh, pv, pe, mask, netw, vwgt,
                                      labels, sh.n_col, sh.e_rows)
    return fn, (sh.pv, sh.pe, sh.mask, sh.netw, sh.vwgt, labels)


def _perturb_parhyp_contract(args, rng):
    pv, pe, mask = (np.array(a) for a in args[:3])
    n_pad, e_pad = args[4].shape[0], args[3].shape[0]
    pad = mask == 0
    pv = _garble(pv, pad, n_pad, rng)
    pe = _garble(pe, pad, e_pad, rng)
    labels = np.array(args[5])
    labels[20:] = rng.integers(20, n_pad, size=labels[20:].shape,
                               dtype=labels.dtype)
    return (pv, pe, mask) + tuple(args[3:5]) + (labels,)


def _project_parhyp_contract(outs):
    # coarse_of of padding vertices depends on their (free) input labels;
    # every other output is fully determined by the real slots
    pv2, pe2, mask2, netw2, esize2, cvw, coarse_of, nc, hi = outs
    return [_np(pv2), _np(pe2), _np(mask2), _np(netw2), _np(esize2),
            _np(cvw), _np(coarse_of)[:20], _np(nc), _np(hi)]


def _build_migrate():
    from repro.core.memetic import migrate as MG
    mesh = _one_device_mesh(MG.AXIS)
    parts = np.arange(4 * 32, dtype=np.int32).reshape(4, 32)

    def fn(parts):
        return MG._ring_roll_jit(mesh, parts, 1, 4, 1)
    return fn, (parts,)


# ---------------------------------------------------------------------------
# kernel entries (public Pallas wrappers)
# ---------------------------------------------------------------------------

def _build_lp_affinity():
    from repro.core.csr import to_ell
    from repro.kernels import ops
    g = _ring_graph()
    ell = to_ell(g)
    labels = np.arange(ell.nbr.shape[0], dtype=np.int32) % 4

    def fn(nbr, wgt, labels):
        return ops.lp_affinity(nbr, wgt, labels, 4)
    return fn, (ell.nbr, ell.wgt, labels)


def _perturb_lp_affinity(args, rng):
    nbr, wgt = _np(args[0]), _np(args[1])
    return (_garble(nbr, wgt == 0, nbr.shape[0], rng),) + tuple(args[1:])


def _build_sep_affinity():
    from repro.core.csr import to_ell
    from repro.kernels import ops
    g = _ring_graph()
    ell = to_ell(g)
    labels = np.arange(ell.nbr.shape[0], dtype=np.int32) % 3

    def fn(nbr, wgt, vwgt, labels):
        return ops.sep_affinity(nbr, wgt, vwgt, labels)
    return fn, (ell.nbr, ell.wgt, ell.vwgt, labels)


def _perturb_sep_affinity(args, rng):
    nbr, wgt = _np(args[0]), _np(args[1])
    return (_garble(nbr, wgt == 0, nbr.shape[0], rng),) + tuple(args[1:])


def _build_pin_count():
    from repro.core.hypergraph.container import to_ell_h
    from repro.kernels import ops
    eh = to_ell_h(_tiny_hypergraph())
    labels = np.arange(eh.n_pad, dtype=np.int32) % 4

    def fn(pins, pin_mask, netw, labels):
        return ops.pin_count(pins, pin_mask, netw, labels, 4)
    return fn, (eh.pins, eh.pin_mask, eh.netw, labels)


def _perturb_pin_count(args, rng):
    pins, mask = _np(args[0]), _np(args[1])
    n_pad = args[3].shape[0]
    return (_garble(pins, mask == 0, n_pad, rng),) + tuple(args[1:])


def _build_pin_affinity():
    from repro.core.hypergraph.container import to_ell_h
    from repro.kernels import ops
    eh = to_ell_h(_tiny_hypergraph())
    labels = np.arange(eh.n_pad, dtype=np.int32) % 4

    def fn(vnets, pins, pin_mask, netw, labels):
        return ops.pin_affinity(vnets, pins, pin_mask, netw, labels, 4)
    return fn, (eh.vnets, eh.pins, eh.pin_mask, eh.netw, labels)


def _perturb_pin_affinity(args, rng):
    vnets, pins, mask, netw = (_np(a) for a in args[:4])
    n_pad = args[4].shape[0]
    pins = _garble(pins, mask == 0, n_pad, rng)
    # vnets padding slots point at *a* zero-weight net (contract); move them
    # to a random other zero-weight net
    zero_nets = np.flatnonzero(netw == 0)
    vn = np.array(vnets)
    pad = np.isin(vn, zero_nets)
    k = int(np.count_nonzero(pad))
    vn[pad] = rng.choice(zero_nets, size=k)
    return (vn, pins) + tuple(args[2:])


def _build_ssd():
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    bh, l, p, n = 2, 128, 4, 4
    x = rng.standard_normal((bh, l, p)).astype(np.float32)
    ld = -np.abs(rng.standard_normal((bh, l)).astype(np.float32))
    b = rng.standard_normal((bh, l, n)).astype(np.float32)
    c = rng.standard_normal((bh, l, n)).astype(np.float32)

    def fn(x, ld, b, c):
        return ops.ssd_scan(x, ld, b, c, chunk=64)
    return fn, (x, ld, b, c)


# ---------------------------------------------------------------------------
# serve entries
# ---------------------------------------------------------------------------

def _serve_setup(arch: str, slots: int):
    import jax
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    caches = T.init_caches(cfg, slots, 16)
    return cfg, params, caches


def _build_prefill_step1():
    from repro.serve import batching as B
    cfg, params, caches = _serve_setup("minicpm_2b", 1)

    def fn(params, tok, caches, pos):
        return B._step1(params, cfg, tok, caches, pos)
    return fn, (params, np.ones((1, 1), np.int32), caches,
                np.int32(0))


def _build_decode_slots():
    from repro.serve import batching as B
    cfg, params, caches = _serve_setup("minicpm_2b", 2)

    def fn(params, toks, pos, caches):
        return B._decode_slots(params, cfg, toks, pos, caches)
    return fn, (params, np.zeros(2, np.int32), np.zeros(2, np.int32),
                caches)


def _build_moe_gate_tap():
    from repro.models import moe
    from repro.serve import batching as B
    cfg, params, caches = _serve_setup("deepseek_v2_236b", 1)

    def fn(params, toks, pos, caches):
        # the allowlisted observability tap: observe_gates installs a
        # debug_callback inside the decoder layer scan at trace time
        with moe.observe_gates(lambda *_: None):
            return B._decode_slots(params, cfg, toks, pos, caches)
    return fn, (params, np.zeros(1, np.int32), np.zeros(1, np.int32),
                caches)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

_T = frozenset

ENTRIES: Tuple[EntryPoint, ...] = (
    EntryPoint(
        name="engine/kway_refine",
        build=functools.partial(_build_kway, False),
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=_kway_bucket_dims,
        padding=PaddingSpec(_perturb_kway, _project_kway),
        drivers=("kaffpa", "kaffpa_balance_NE", "kaffpaE", "reduced_nd",
                 "fast_reduced_nd", "process_mapping"),
    ),
    EntryPoint(
        name="engine/kway_refine_kernel",
        build=functools.partial(_build_kway, True),
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=_kway_bucket_dims,
        padding=PaddingSpec(_perturb_kway_kernel, _project_kway),
        drivers=("kaffpa",),
    ),
    EntryPoint(
        name="engine/cluster_lp",
        build=_build_cluster_lp,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"n_pad": args[0].n_pad,
                                  "e_pad": args[0].e_pad},
        padding=PaddingSpec(_perturb_cluster_lp, _project_cluster_lp),
        drivers=("kaffpa", "kahypar", "node_separator"),
    ),
    EntryPoint(
        name="engine/hyper_refine_km1",
        build=functools.partial(_build_hyper, "km1"),
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=_hyper_bucket_dims,
        padding=PaddingSpec(_perturb_hyper, _project_hyper),
        drivers=("kahypar", "kahyparE"),
    ),
    EntryPoint(
        name="engine/hyper_refine_cut",
        build=functools.partial(_build_hyper, "cut"),
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=_hyper_bucket_dims,
        padding=PaddingSpec(_perturb_hyper, _project_hyper),
        drivers=("kahypar", "kahyparE"),
    ),
    EntryPoint(
        name="engine/sep_refine",
        build=_build_sep,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"n_pad": args[0].n_pad,
                                  "e_pad": args[0].e_pad,
                                  "batch": args[1].shape[0]},
        padding=PaddingSpec(_perturb_sep, _project_sep),
        drivers=("node_separator", "reduced_nd", "fast_reduced_nd"),
    ),
    EntryPoint(
        name="dist/parhyp_round",
        build=_build_parhyp,
        tags=_T({"bucket", "padding", "spmd", "hygiene"}),
        bucket_dims=_parhyp_bucket_dims,
        padding=PaddingSpec(_perturb_parhyp, _project_parhyp),
        drivers=("parhyp",),
    ),
    EntryPoint(
        name="dist/parhyp_round_2d",
        build=functools.partial(_build_parhyp, True),
        tags=_T({"bucket", "padding", "spmd", "hygiene"}),
        bucket_dims=_parhyp_bucket_dims,
        padding=PaddingSpec(_perturb_parhyp, _project_parhyp),
        drivers=("parhyp",),
    ),
    EntryPoint(
        name="dist/cluster_round",
        build=_build_parhyp_cluster,
        tags=_T({"bucket", "padding", "spmd", "hygiene"}),
        bucket_dims=_cluster_bucket_dims,
        padding=PaddingSpec(_perturb_parhyp_cluster,
                            _project_parhyp_cluster),
        drivers=("parhyp",),
    ),
    EntryPoint(
        name="dist/contract",
        build=_build_parhyp_contract,
        tags=_T({"bucket", "padding", "spmd", "hygiene"}),
        bucket_dims=_cluster_bucket_dims,
        padding=PaddingSpec(_perturb_parhyp_contract,
                            _project_parhyp_contract),
        drivers=("parhyp",),
    ),
    EntryPoint(
        name="memetic/migrate_ring",
        build=_build_migrate,
        tags=_T({"spmd", "hygiene"}),
        drivers=("kaffpaE", "kahyparE"),
    ),
    EntryPoint(
        name="kernels/lp_affinity",
        build=_build_lp_affinity,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"n_pad": args[0].shape[0],
                                  "dmax": args[0].shape[1]},
        padding=PaddingSpec(_perturb_lp_affinity,
                            lambda outs: [_np(outs[0])[:24]]),
    ),
    EntryPoint(
        name="kernels/sep_affinity",
        build=_build_sep_affinity,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"n_pad": args[0].shape[0],
                                  "dmax": args[0].shape[1]},
        padding=PaddingSpec(_perturb_sep_affinity,
                            lambda outs: [_np(outs[0])[:24]]),
    ),
    EntryPoint(
        name="kernels/pin_count",
        build=_build_pin_count,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"e_pad": args[0].shape[0],
                                  "pmax": args[0].shape[1]},
        padding=PaddingSpec(_perturb_pin_count,
                            lambda outs: [_np(outs[0])[:12],
                                          _np(outs[1])[:12]]),
    ),
    EntryPoint(
        name="kernels/pin_affinity",
        build=_build_pin_affinity,
        tags=_T({"bucket", "padding", "hygiene"}),
        bucket_dims=lambda args: {"n_pad": args[0].shape[0],
                                  "dvmax": args[0].shape[1],
                                  "e_pad": args[1].shape[0],
                                  "pmax": args[1].shape[1]},
        padding=PaddingSpec(_perturb_pin_affinity,
                            lambda outs: [_np(outs[0])[:20]]),
    ),
    EntryPoint(
        name="kernels/ssd_scan",
        build=_build_ssd,
        tags=_T({"bucket", "hygiene"}),
        bucket_dims=lambda args: {"seq": args[0].shape[1]},
    ),
    EntryPoint(
        name="serve/prefill_step1",
        build=_build_prefill_step1,
        tags=_T({"hygiene"}),
    ),
    EntryPoint(
        name="serve/decode_slots",
        build=_build_decode_slots,
        tags=_T({"hygiene"}),
    ),
    EntryPoint(
        name="serve/moe_gate_tap",
        build=_build_moe_gate_tap,
        tags=_T({"hygiene"}),
        allow_callbacks=("debug_callback",),
    ),
)


def default_registry() -> Dict[str, EntryPoint]:
    return {e.name: e for e in ENTRIES}


#: public driver (interface.py) -> entry names that cover its traced core;
#: the registry-hygiene lint fails when a driver is missing here or names
#: an unknown entry.
DRIVER_ENTRIES: Dict[str, Tuple[str, ...]] = {
    "kaffpa": ("engine/kway_refine", "engine/kway_refine_kernel",
               "engine/cluster_lp"),
    "kaffpa_balance_NE": ("engine/kway_refine",),
    "kaffpaE": ("engine/kway_refine", "memetic/migrate_ring"),
    "kahypar": ("engine/hyper_refine_km1", "engine/hyper_refine_cut",
                "engine/cluster_lp"),
    "kahyparE": ("engine/hyper_refine_km1", "memetic/migrate_ring"),
    "parhyp": ("dist/parhyp_round", "dist/parhyp_round_2d",
               "dist/cluster_round", "dist/contract"),
    "node_separator": ("engine/sep_refine", "engine/cluster_lp"),
    "reduced_nd": ("engine/sep_refine", "engine/kway_refine"),
    "fast_reduced_nd": ("engine/sep_refine", "engine/kway_refine"),
    "process_mapping": ("engine/kway_refine",),
}
