"""`repro.analysis` — the jaxpr contract checkers (DESIGN.md §14).

Each checker gets a deliberately-broken fixture it must flag (unbucketed
batch, padding vertex force-moved into balance totals, shard-varying Φ
consumed as replicated, callback in a scan body, weak-typed carry), plus a
clean-tree regression: the full registry must produce zero findings above
the committed baseline.  The pin tests at the bottom anchor the real
violations this PR fixed (weak `jnp.inf` scan carries, the fori_loop
weak-int carry inside the Pallas kernels, position-dependent tie-break
noise in `_segment_affinity`).
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.analysis import (analyze, analyze_entry, default_registry,
                            load_baseline, partition_by_baseline,
                            write_findings_jsonl)
from repro.analysis import checkers, lint, padding as padmod, spmd, tracing
from repro.analysis.findings import Finding
from repro.analysis.registry import (DRIVER_ENTRIES, EntryPoint, PaddingSpec,
                                     _perturb_coo, _ring_graph)
from repro.compat import shard_map


def _codes(findings):
    return sorted(f.code for f in findings)


def _entry(name, fn, args, tags, **kw):
    return EntryPoint(name=name, build=lambda: (fn, args),
                      tags=frozenset(tags), **kw)


# ---------------------------------------------------------------------------
# bucket checker
# ---------------------------------------------------------------------------

def test_bucket_flags_unbucketed_batch():
    """A vmapped scan over a non-pow2 batch dim violates DESIGN §12."""
    x = np.ones((3, 8), np.float32)       # batch 3: not a pow2 bucket

    def fn(x):
        def body(c, row):
            return c + row.sum(), None
        return jax.vmap(lambda r: jax.lax.scan(
            body, jnp.float32(0.0), r[:, None])[0])(x)

    e = _entry("fixture/unbucketed", fn, (x,), {"bucket"},
               bucket_dims=lambda args: {"batch": args[0].shape[0],
                                         "cols": args[0].shape[1]})
    found = analyze_entry(e)
    assert "non-pow2-dim" in _codes(found)
    assert any(f.detail == {"dim": "batch", "size": 3} for f in found)


def test_bucket_program_registry_cross_check():
    bad = checkers.check_program_registry(
        [("kway", 100, 256, 2, 8, 3, False)])
    assert _codes(bad).count("non-pow2-signature-field") == 2  # 100 and 3
    # two distinct signatures at one bucket projection: recompile hazard
    coll = checkers.check_program_registry(
        [("kway", 128, 256, 2, 8, 4, False),
         ("kway", 100, 256, 2, 8, 3, False)])
    assert "bucket-collision" in _codes(coll)
    # identical pow2 signatures share one program: clean
    ok = checkers.check_program_registry(
        [("kway", 128, 256, 2, 8, 4, False),
         ("hyper", 128, 128, 256, 4, 6, "km1", 2, False),
         ("sep", 256, 256, 6, 2, False)])
    assert ok == []


# ---------------------------------------------------------------------------
# padding-inertness checker
# ---------------------------------------------------------------------------

def _broken_refine_entry():
    """The PR-7 bug class, seeded deliberately: balance totals count
    *vertices* instead of vertex weight (so zero-weight padding rows enter
    the totals) and the overweight push lacks the ``vw > 0`` gate (so
    padding vertices are force-moved)."""
    from repro.core.csr import to_coo
    g = _ring_graph()
    coo = to_coo(g)
    n = g.n
    labels0 = (np.arange(coo.n_pad) % 2).astype(np.int32)

    def fn(coo, labels0):
        k = 2

        def body(labels, _):
            # BUG: .add(1.0) counts padding vertices into balance totals
            sizes = jnp.zeros((k,), jnp.float32).at[labels].add(1.0)
            aff = jnp.zeros((coo.n_pad, k), jnp.float32).at[
                coo.src, labels[coo.dst]].add(coo.w)
            own = jnp.take_along_axis(
                aff, labels[:, None].astype(jnp.int32), 1)[:, 0]
            gain = aff - own[:, None]
            gain = gain.at[jnp.arange(coo.n_pad), labels].set(-1e30)
            best = jnp.argmax(gain, 1).astype(labels.dtype)
            # BUG: force-move from the overweight block without vw > 0
            over = sizes[labels] > sizes.sum() / k
            return jnp.where(over, best, labels), None

        labels, _ = jax.lax.scan(body, labels0, None, length=3)
        sizes = jnp.zeros((2,), jnp.float32).at[labels].add(1.0)
        return labels, sizes

    def perturb(args, rng):
        coo, labels = args
        labs = np.array(labels)
        labs[n:] = rng.integers(0, 2, size=labs[n:].shape, dtype=labs.dtype)
        return (_perturb_coo(coo, rng), labs)

    return _entry("fixture/padding_force_move", fn, (coo, labels0),
                  {"padding"},
                  padding=PaddingSpec(
                      perturb, lambda outs: [np.asarray(outs[0])[:n],
                                             np.asarray(outs[1])]))


def test_padding_flags_force_moved_padding_vertex():
    found = analyze_entry(_broken_refine_entry())
    assert "padding-flows-into-output" in _codes(found)


# ---------------------------------------------------------------------------
# SPMD replication checker
# ---------------------------------------------------------------------------

def _phi_entry(reduce_phi: bool):
    """A miniature parhyp Φ histogram round.  With ``reduce_phi=False`` the
    per-shard partial is returned through ``out_specs=P()`` — claimed
    replicated while still shard-varying (check_vma=False hides it from
    jax itself)."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("nets",))
    pv = np.zeros((1, 8), np.int32)
    pe = np.zeros((1, 8), np.int32)
    mask = np.ones((1, 8), np.float32)
    labels = np.zeros(16, np.int32)

    def local(pv, pe, mask, labels):
        cnt = jnp.zeros((4, 2), jnp.float32).at[
            pe.reshape(-1), labels[pv.reshape(-1)]].add(mask.reshape(-1))
        if reduce_phi:
            cnt = jax.lax.psum(cnt, "nets")
        return cnt

    def fn(pv, pe, mask, labels):
        return shard_map(local, mesh=mesh,
                         in_specs=(P("nets", None), P("nets", None),
                                   P("nets", None), P()),
                         out_specs=P(), check_vma=False)(pv, pe, mask,
                                                         labels)

    return _entry(f"fixture/phi_{reduce_phi}", fn, (pv, pe, mask, labels),
                  {"spmd"})


def test_spmd_flags_unreduced_phi_as_replicated():
    found = analyze_entry(_phi_entry(reduce_phi=False))
    assert "varying-as-replicated" in _codes(found)
    assert any(f.detail["varying"] == ["nets"] for f in found)


def test_spmd_accepts_psummed_phi():
    assert analyze_entry(_phi_entry(reduce_phi=True)) == []


def test_spmd_axis_index_introduces_varyingness():
    mesh = Mesh(np.array(jax.devices()[:1]), ("islands",))

    def fn(x):
        def local(x):
            return x.sum() + jax.lax.axis_index("islands").astype(jnp.float32)
        return shard_map(local, mesh=mesh, in_specs=P("islands"),
                         out_specs=P(), check_vma=False)(x)

    found = analyze_entry(_entry("fixture/axis_index", fn,
                                 (np.ones(4, np.float32),), {"spmd"}))
    assert "varying-as-replicated" in _codes(found)


# ---------------------------------------------------------------------------
# purity / dtype hygiene checker
# ---------------------------------------------------------------------------

def _callback_entry(allow=()):
    def fn(x):
        def body(c, _):
            jax.debug.callback(lambda v: None, c)
            return c + x.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0.0), None, length=4)
        return out

    return _entry("fixture/callback", fn, (np.ones(3, np.float32),),
                  {"hygiene"}, allow_callbacks=allow)


def test_hygiene_flags_callback_in_scan_body():
    found = analyze_entry(_callback_entry())
    assert "callback-in-loop" in _codes(found)


def test_hygiene_allowlist_admits_observe_gates_style_tap():
    found = analyze_entry(_callback_entry(allow=("debug_callback",)))
    assert "callback-in-loop" not in _codes(found)


def test_hygiene_flags_weak_carry():
    def fn(x):
        def body(c, _):
            return (c[0] + 1, jnp.minimum(c[1], 0.5)), None
        (a, b), _ = jax.lax.scan(body, (jnp.int32(0), jnp.inf), None,
                                 length=3)
        return a, b + x.sum()

    found = analyze_entry(_entry("fixture/weak", fn,
                                 (np.ones(3, np.float32),), {"hygiene"}))
    assert "weak-carry" in _codes(found)


# ---------------------------------------------------------------------------
# lints
# ---------------------------------------------------------------------------

def test_host_sync_lint(tmp_path):
    bad = tmp_path / "glue.py"
    bad.write_text(
        "_HOST_SYNC_OK = (\"designed\",)\n"
        "def hot(xs):\n"
        "    total = 0\n"
        "    for x in xs:\n"
        "        total += x.item()\n"
        "        y = np.asarray(x)\n"
        "    return total\n"
        "def designed(x):\n"
        "    return int(np.asarray(x))\n")
    found = lint.check_host_sync(serve_dir=str(tmp_path))
    codes = _codes(found)
    assert "sync-item" in codes
    assert "materialize-in-loop" in codes
    # the allowlisted designed sync point (line 9) is not flagged
    assert not any(f.location.endswith(":9") for f in found)


def test_serve_tree_passes_host_sync_lint():
    assert lint.check_host_sync() == []


def test_driver_registry_lint_clean_and_complete():
    assert lint.check_driver_registry() == []
    # every mapped entry must exist in the registry
    reg = default_registry()
    for entries in DRIVER_ENTRIES.values():
        for name in entries:
            assert name in reg


def test_driver_registry_lint_flags_unregistered_driver():
    incomplete = {k: v for k, v in DRIVER_ENTRIES.items() if k != "kaffpa"}
    found = lint.check_driver_registry(driver_entries=incomplete)
    assert any(f.code == "driver-unregistered" and f.entry == "kaffpa"
               for f in found)


# ---------------------------------------------------------------------------
# findings plumbing: JSONL obs-compat, baseline gate, counters
# ---------------------------------------------------------------------------

def test_findings_jsonl_readable_by_obs(tmp_path):
    f1 = Finding(checker="bucket", severity="error", entry="e", code="c",
                 location="l", message="m", detail={"x": 1})
    f2 = Finding(checker="spmd", severity="warning", entry="e2", code="c2",
                 location="l2", message="m2")
    path = str(tmp_path / "findings.jsonl")
    write_findings_jsonl(path, [f1, f2])
    headers, events = obs.read_jsonl(path)
    assert headers[0]["name"] == "analysis"
    assert headers[0]["counters"] == {"analysis/bucket": 1,
                                      "analysis/spmd": 1}
    assert [e["key"] for e in events] == [f1.key, f2.key]
    assert events[0]["severity"] == "error"


def test_baseline_partition(tmp_path):
    f = Finding(checker="bucket", severity="error", entry="e", code="c",
                location="l", message="m")
    base = tmp_path / "base.json"
    base.write_text(json.dumps(
        {"version": 1, "allow": [{"key": f.key, "reason": "known"}]}))
    new, allowed = partition_by_baseline([f], load_baseline(str(base)))
    assert new == [] and allowed == [f]
    new2, _ = partition_by_baseline([f], load_baseline(str(base) + ".nope"))
    assert new2 == [f]


def test_analyze_increments_obs_counters():
    before = obs.metrics.get("analysis/violations")
    found = analyze(entries=["kernels/ssd_scan"], lints=False,
                    program_registry=False)
    assert found == []
    # clean entry: counter unchanged; broken fixture path covered above
    assert obs.metrics.get("analysis/violations") == before
    reg = {"fixture/callback": _callback_entry()}
    found = analyze(entries=["fixture/callback"], registry=reg,
                    lints=False, program_registry=False)
    assert found
    assert obs.metrics.get("analysis/violations") > before
    assert obs.metrics.get("analysis/hygiene") >= 1


# ---------------------------------------------------------------------------
# clean-tree regression + pins for the violations fixed in this PR
# ---------------------------------------------------------------------------

def test_clean_tree_zero_findings_above_baseline():
    """The acceptance gate, in-process: every registered entry point plus
    the lints produce no findings beyond ANALYSIS_BASELINE.json."""
    findings = analyze()
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    baseline = load_baseline(os.path.join(root, "ANALYSIS_BASELINE.json"))
    new, _ = partition_by_baseline(findings, baseline)
    assert new == [], [f.key for f in new]


def test_pin_no_weak_carry_in_hyper_refine():
    """Pins the jnp.float32(jnp.inf) fix in hypergraph/refine.py and
    hypergraph/dist.py: weak f32 carries came from bare jnp.inf."""
    reg = default_registry()
    for name in ("engine/hyper_refine_km1", "dist/parhyp_round"):
        found = analyze_entry(reg[name])
        assert not [f for f in found if f.code == "weak-carry"], name


def test_pin_no_weak_carry_in_pallas_kernels():
    """Pins the fori_loop → strong-counter-scan fix in kernels/: the
    python-int fori_loop bounds seeded a weak int32 carry."""
    reg = default_registry()
    for name in ("kernels/lp_affinity", "kernels/pin_count",
                 "engine/kway_refine_kernel"):
        found = analyze_entry(reg[name])
        assert not [f for f in found if f.code == "weak-carry"], name


def test_pin_cluster_lp_padding_inert():
    """Pins the _segment_affinity fix: tie-break noise is now drawn per
    original edge id and zeroed on padding edges, so garbage in zero-weight
    edges cannot perturb real clustering decisions."""
    reg = default_registry()
    assert analyze_entry(reg["engine/cluster_lp"]) == []


def test_pin_cluster_lp_noise_still_tiebreaks():
    """The fix must not have killed the tie-break: two runs with different
    keys still produce valid (and generally different) clusterings."""
    from repro.core import lp as L
    from repro.core.csr import to_coo
    g = _ring_graph()
    coo = to_coo(g)
    labs = np.arange(coo.n_pad, dtype=np.int32)
    cap = np.full(coo.n_pad, 6.0 * g.n, np.float32)
    out1, _ = L._cluster_lp_jit(coo, jnp.asarray(labs), jnp.asarray(cap),
                                jax.random.PRNGKey(0), 4)
    out1 = np.asarray(out1)[:g.n]
    # every vertex joined a cluster led by a real vertex
    assert out1.min() >= 0 and out1.max() < coo.n_pad
    # clustering is non-trivial: fewer clusters than vertices
    assert len(np.unique(out1)) < g.n
