"""The shared multilevel engine (core/multilevel.py): view caching,
V-cycle non-worsening on both media, engine parity with the pre-refactor
drivers, hypergraph V-cycles/time budget, and the large-net star fallback."""
import dataclasses

import numpy as np
import pytest

from repro.core import multilevel as ML
from repro.core.kaffpa import GraphMedium, PRESETS, kaffpa
from repro.core.partition import edge_cut, is_feasible
from repro.core.hypergraph import (Hypergraph, HypergraphMedium, kahypar,
                                   clique_expansion, coarsen_level,
                                   connectivity)
from repro.core.hypergraph import PRESETS as HPRESETS
from repro.core.hypergraph import metrics as HM
from repro.io.generators import (barabasi_albert, grid2d, planted_hypergraph)


GRID24 = grid2d(24, 24)
HP200 = planted_hypergraph(200, 300, blocks=4, seed=7)


# -- device-view caching ------------------------------------------------------

@pytest.mark.parametrize("make_medium", [
    lambda: GraphMedium(GRID24, PRESETS["eco"]),
    lambda: HypergraphMedium(HP200, HPRESETS["eco"], "km1"),
], ids=["graph", "hypergraph"])
def test_view_builds_are_O_levels_not_O_levels_x_rounds(make_medium):
    """Regression: device views are constructed once per hierarchy level,
    independent of refinement rounds / tries (pre-engine, the hypergraph
    uncoarsening rebuilt pin-COO/ELL on every _refine_level call)."""
    medium = make_medium()
    levels = ML.build_hierarchy(medium, 4, seed=0)
    before = ML.view_build_count()
    part_c = ML.initial_partition(levels[-1], 4, 0.03, seed=0)
    part = ML.uncoarsen(levels, part_c, 4, 0.03, seed=0)
    built = ML.view_build_count() - before
    assert built <= len(levels), (built, len(levels))
    # a second full uncoarsening pass over the same hierarchy (many more
    # refinement calls) must not construct a single additional view
    before = ML.view_build_count()
    part2 = ML.uncoarsen(levels, part_c, 4, 0.03, seed=1)
    assert ML.view_build_count() == before
    assert len(part) == medium.n and len(part2) == medium.n


# -- V-cycle non-worsening ----------------------------------------------------

def test_vcycle_non_worsening_graph():
    medium = GraphMedium(GRID24, PRESETS["eco"])
    part = ML.multilevel(medium, 4, 0.03, seed=2)
    cut = edge_cut(GRID24, part)
    for cyc in range(3):
        part = ML.vcycle(medium, part, 4, 0.03, seed=11 + cyc)
        c = edge_cut(GRID24, part)
        assert c <= cut, (c, cut)
        assert is_feasible(GRID24, part, 4, 0.03)
        cut = c


@pytest.mark.parametrize("objective", ["km1", "cut"])
def test_vcycle_non_worsening_hypergraph(objective):
    medium = HypergraphMedium(HP200, HPRESETS["eco"], objective)
    part = ML.multilevel(medium, 4, 0.03, seed=2)
    obj = medium.objective(part)
    for cyc in range(3):
        part = ML.vcycle(medium, part, 4, 0.03, seed=11 + cyc)
        o = medium.objective(part)
        assert o <= obj, (o, obj)
        assert HM.is_feasible(HP200, part, 4, 0.03)
        obj = o


# -- hypergraph V-cycles + time budget (engine features for free) ------------

def test_kahypar_vcycles_and_time_limit():
    hg = planted_hypergraph(400, 600, blocks=4, seed=11)
    base = kahypar(hg, 4, 0.03, "eco", seed=1)
    more = kahypar(hg, 4, 0.03, "eco", seed=1, vcycles=3, time_limit=1.0)
    assert HM.is_feasible(hg, more, 4, 0.03)
    # same seed → same first cycle; V-cycles never worsen and restarts only
    # replace the incumbent with strictly better feasible candidates
    assert connectivity(hg, more) <= connectivity(hg, base)


# -- engine parity with the pre-refactor drivers ------------------------------

# Reference objectives measured at the PR-2 seed (pre-refactor drivers) on
# the exact instances/seeds below; the engine must stay within tolerance.
PRE_REFACTOR_REFS = {
    "kaffpa_eco_grid32_k4": 92,        # edge cut
    "kaffpa_strong_grid32_k4": 89,     # edge cut
    "kaffpa_ecosocial_ba2k_k8": 4561,  # edge cut
    "kahypar_eco_hp400_k4": 106,       # (λ−1)
}


def test_engine_parity_graph_mesh():
    g = grid2d(32, 32)
    p = kaffpa(g, 4, 0.03, "eco", seed=3)
    assert is_feasible(g, p, 4, 0.03)
    assert edge_cut(g, p) <= PRE_REFACTOR_REFS["kaffpa_eco_grid32_k4"] * 1.15
    p = kaffpa(g, 4, 0.03, "strong", seed=3)
    assert is_feasible(g, p, 4, 0.03)
    assert edge_cut(g, p) <= \
        PRE_REFACTOR_REFS["kaffpa_strong_grid32_k4"] * 1.15


def test_engine_parity_graph_social():
    g = barabasi_albert(2048, 4, seed=1)
    p = kaffpa(g, 8, 0.03, "ecosocial", seed=1)
    assert is_feasible(g, p, 8, 0.03)
    assert edge_cut(g, p) <= \
        PRE_REFACTOR_REFS["kaffpa_ecosocial_ba2k_k8"] * 1.15


def test_engine_parity_hypergraph():
    hg = planted_hypergraph(400, 600, blocks=4, seed=11)
    p = kahypar(hg, 4, 0.03, "eco", seed=1)
    assert HM.is_feasible(hg, p, 4, 0.03)
    assert connectivity(hg, p) <= \
        PRE_REFACTOR_REFS["kahypar_eco_hp400_k4"] * 1.15


# -- medium-generic combine ---------------------------------------------------

def test_combine_hypergraph_offspring_not_worse():
    """The engine's combine works on any medium — KaHyParE for free."""
    medium = HypergraphMedium(HP200, HPRESETS["fast"], "km1")
    pa = ML.multilevel(medium, 4, 0.03, seed=1)
    pb = ML.multilevel(medium, 4, 0.03, seed=2)
    child = ML.combine(medium, pa, pb, 4, 0.03, seed=5)
    better = min(medium.objective(pa), medium.objective(pb))
    assert medium.objective(child) <= better
    assert HM.is_feasible(HP200, child, 4, 0.03)


def test_combine_accepts_arbitrary_clustering_pb():
    """``pb`` may be any labelling (labels ≥ k): the signature split must
    not collide, so ``pa`` stays representable and the child never loses to
    the only valid parent."""
    medium = GraphMedium(GRID24, PRESETS["fast"])
    pa = ML.multilevel(medium, 4, 0.03, seed=1)
    pb = np.arange(GRID24.n, dtype=np.int64) // 24   # 24 column clusters > k
    child = ML.combine(medium, pa, pb, 4, 0.03, seed=3)
    assert edge_cut(GRID24, child) <= edge_cut(GRID24, pa)
    assert is_feasible(GRID24, child, 4, 0.03)


def test_kahypar_rejects_bad_objective_even_for_trivial_k():
    with pytest.raises(ValueError):
        kahypar(HP200, 1, 0.03, "fast", objective="bogus")


# -- batched tournament refinement -------------------------------------------

def test_refine_batch_matches_feasibility_and_quality():
    from repro.core.refine import refine_kway_batch
    from repro.core.initial import random_partition
    parts = [random_partition(GRID24, 4, seed=s) for s in range(3)]
    outs = refine_kway_batch(GRID24, parts, 4, 0.03, rounds=8, seed=1)
    assert len(outs) == 3
    for p0, p1 in zip(parts, outs):
        assert edge_cut(GRID24, p1) <= edge_cut(GRID24, p0)
        assert is_feasible(GRID24, p1, 4, 0.03)


# -- large-net star fallback --------------------------------------------------

def test_large_net_star_fallback_gives_signal():
    # a single giant net is the only structure: without the fallback the
    # rating graph is empty and coarsening stalls at the identity
    hg = Hypergraph.from_nets(64, [list(range(64))])
    off = clique_expansion(hg, max_net_size=16, large_net_fallback=False)
    assert len(off.adjncy) == 0
    on = clique_expansion(hg, max_net_size=16)
    assert len(on.adjncy) == 2 * 63          # star around the first pin
    res = coarsen_level(hg, max_cluster_weight=8, seed=0, max_net_size=16)
    assert res is not None
    coarse, cl = res
    assert coarse.n < hg.n
    assert coarse.total_vwgt() == hg.total_vwgt()


def test_planted_instance_with_giant_net_partitions_fine():
    base = planted_hypergraph(120, 180, blocks=4, seed=9)
    nets = [list(base.net_pins(e)) for e in range(base.m)]
    nets.append(list(range(120)))            # one giant net spanning all
    hg = Hypergraph.from_nets(120, nets)
    part = kahypar(hg, 4, 0.03, "fast", seed=1, objective="km1")
    assert HM.is_feasible(hg, part, 4, 0.03)
    from repro.core.hypergraph.initial import random_partition
    rnd = connectivity(hg, random_partition(hg, 4, seed=0))
    assert connectivity(hg, part) * 2 <= rnd
