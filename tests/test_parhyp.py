"""parhyp — the distributed (shard_map) hypergraph partitioner: sharding
invariants, 1-device bit-exactness vs the sequential COO oracle,
never-worse refinement, end-to-end quality, the C-API-style interface
entry, and the multi-device subprocess run."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
from jax.sharding import Mesh

from repro.core.hypergraph import (connectivity, cut_net, evaluate,
                                   is_feasible, refine_hypergraph)
from repro.core.hypergraph.container import to_pincoo
from repro.core.hypergraph.dist import (parhyp, parhyp_refine,
                                        shard_hypergraph)
from repro.core.hypergraph.initial import random_partition
from repro.io.generators import planted_hypergraph, random_hypergraph

HG = planted_hypergraph(300, 450, blocks=4, seed=7)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1() -> Mesh:
    return Mesh(np.array(jax.devices()[:1]), ("nets",))


# -- sharded container -------------------------------------------------------

def test_shard_hypergraph_conserves_pins_and_weights():
    sh = shard_hypergraph(HG, 4)
    assert sh.n_shards == 4
    assert sh.n_pad == sh.n_shards * sh.rows_v
    assert float(sh.mask.sum()) == HG.pins
    assert float(sh.vwgt.sum()) == HG.total_vwgt()
    assert float(sh.netw.sum()) == HG.total_ewgt()
    # every real (net, vertex) pin appears exactly once across all shards
    real = sh.mask.reshape(-1) > 0
    got = np.stack([sh.pe.reshape(-1)[real], sh.pv.reshape(-1)[real]], 1)
    want = np.stack([HG.pin_sources(), HG.eind], 1)
    assert np.array_equal(got[np.lexsort(got.T)], want[np.lexsort(want.T)])
    # nets are block-distributed: each net's pins live on a single shard
    owner = np.repeat(np.arange(4), sh.p_shard)[real]
    per_net = {}
    for e, s in zip(sh.pe.reshape(-1)[real], owner):
        per_net.setdefault(int(e), set()).add(int(s))
    assert all(len(s) == 1 for s in per_net.values())


def test_one_shard_layout_matches_pincoo():
    """The S=1 shard is exactly the sequential pin-COO view — the layout
    half of the bit-exactness guarantee."""
    sh = shard_hypergraph(HG, 1)
    hc = to_pincoo(HG)
    np.testing.assert_array_equal(sh.pv[0], np.asarray(hc.pv))
    np.testing.assert_array_equal(sh.pe[0], np.asarray(hc.pe))
    np.testing.assert_array_equal(sh.mask[0], np.asarray(hc.mask))
    np.testing.assert_array_equal(sh.netw, np.asarray(hc.netw))
    np.testing.assert_array_equal(sh.esize, np.asarray(hc.esize))
    np.testing.assert_array_equal(sh.vwgt, np.asarray(hc.vwgt))


# -- distributed refinement --------------------------------------------------

@pytest.mark.parametrize("objective", ["km1", "cut"])
def test_refine_bit_exact_vs_sequential_oracle(objective):
    """A fixed 1-device mesh must reproduce the sequential COO refiner
    bit-for-bit (same RNG stream, same scatter orders, same acceptance)."""
    part0 = random_partition(HG, 4, seed=1)
    a = refine_hypergraph(HG, part0, 4, rounds=6, seed=3,
                          objective=objective, use_kernel=False)
    b = parhyp_refine(HG, part0, 4, mesh=_mesh1(), rounds=6, seed=3,
                      objective=objective)
    assert np.array_equal(a, b)


def test_refine_never_worse_and_improves_random():
    part0 = random_partition(HG, 4, seed=2)
    out = parhyp_refine(HG, part0, 4, mesh=_mesh1(), rounds=8, seed=1)
    assert connectivity(HG, out) < connectivity(HG, part0)
    assert is_feasible(HG, out, 4, 0.03)


# -- the parhyp program ------------------------------------------------------

def test_parhyp_end_to_end_quality():
    part = parhyp(HG, 4, 0.03, "fast", seed=1, mesh=_mesh1())
    ev = evaluate(HG, part, 4)
    assert ev["feasible"], ev
    rnd = connectivity(HG, random_partition(HG, 4, seed=0))
    assert ev["km1"] * 2 <= rnd, (ev, rnd)


def test_parhyp_cut_objective():
    part = parhyp(HG, 4, 0.03, "ultrafast", seed=2, mesh=_mesh1(),
                  objective="cut")
    assert is_feasible(HG, part, 4, 0.03)
    rnd = cut_net(HG, random_partition(HG, 4, seed=0))
    assert cut_net(HG, part) < rnd


def test_parhyp_single_level_refines(monkeypatch):
    """Single-level hierarchies (n <= stop_n) must still run the
    distributed refiner + repair at level 0 — the parhip-bug guarantee
    parhyp carries from day one."""
    import repro.core.hypergraph.dist as D
    calls = []
    orig = D.parhyp_refine
    monkeypatch.setattr(D, "parhyp_refine",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    hg = random_hypergraph(40, 60, seed=3)
    part = D.parhyp(hg, 2, 0.03, "ultrafast", seed=1, mesh=_mesh1())
    assert calls, "level-0 refinement must run on single-level hierarchies"
    assert is_feasible(hg, part, 2, 0.03)


def test_interface_parhyp():
    from repro.core import interface
    objval, part = interface.parhyp(
        HG.n, HG.m, None, None, HG.eptr, HG.eind, 4, 0.03, seed=1,
        preconfiguration="ultrafast", mesh=_mesh1())
    assert objval == connectivity(HG, part)
    assert is_feasible(HG, part, 4, 0.03)


@pytest.mark.slow
def test_parhyp_multidevice_subprocess():
    """4 fake host devices: the genuinely sharded path must stay feasible
    and no worse than 5% over the sequential partitioner."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.io.generators import planted_hypergraph
        from repro.core.hypergraph import connectivity, is_feasible, kahypar
        from repro.core.hypergraph.dist import parhyp
        assert len(jax.devices()) == 4
        mesh = Mesh(np.array(jax.devices()), ("nets",))
        hg = planted_hypergraph(300, 450, blocks=4, seed=7)
        part = parhyp(hg, 4, 0.03, "fast", seed=1, mesh=mesh)
        assert is_feasible(hg, part, 4, 0.03)
        km1_d = connectivity(hg, part)
        km1_s = connectivity(hg, kahypar(hg, 4, 0.03, "fast", seed=1))
        assert km1_d <= 1.05 * km1_s, (km1_d, km1_s)
        print("MULTIDEV_OK", km1_d, km1_s)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


# -- 2-D (nets, verts) meshes ------------------------------------------------

def _mesh11() -> Mesh:
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("nets", "verts"))


def test_shard_hypergraph_2d_conserves_and_splits_columns():
    sh = shard_hypergraph(HG, (2, 2))
    assert (sh.s_nets, sh.s_verts, sh.n_shards) == (2, 2, 4)
    assert sh.n_pad == sh.s_verts * sh.n_col == sh.n_shards * sh.rows_v
    assert sh.e_pad == sh.s_nets * sh.e_rows
    assert float(sh.mask.sum()) == HG.pins
    real = sh.mask.reshape(-1) > 0
    got = np.stack([sh.pe.reshape(-1)[real], sh.pv.reshape(-1)[real]], 1)
    want = np.stack([HG.pin_sources(), HG.eind], 1)
    assert np.array_equal(got[np.lexsort(got.T)], want[np.lexsort(want.T)])
    # shard ie*s_verts+jv holds exactly net-row ie ∩ vertex-column jv
    shard = np.repeat(np.arange(4), sh.p_shard)[real]
    pe_r, pv_r = sh.pe.reshape(-1)[real], sh.pv.reshape(-1)[real]
    assert np.array_equal(shard // 2, pe_r // sh.e_rows)
    assert np.array_equal(shard % 2, pv_r // sh.n_col)


def test_refine_2d_one_device_layout_parity():
    """A (1,1) 2-D mesh must be bit-identical to the 1-D mesh (and so to
    the sequential oracle) — the layout-parity half of the 2-D contract."""
    part0 = random_partition(HG, 4, seed=1)
    a = parhyp_refine(HG, part0, 4, mesh=_mesh1(), rounds=6, seed=3)
    b = parhyp_refine(HG, part0, 4, mesh=_mesh11(), rounds=6, seed=3)
    assert np.array_equal(a, b)


# -- distributed coarsening --------------------------------------------------

def test_cluster_round_shard_map_matches_local_oracle():
    """The clustering round body called WITHOUT shard_map (ax=None — every
    collective an identity) is the sequential oracle; the 1-device
    shard_map run must reproduce it bit-for-bit."""
    import jax.numpy as jnp
    from repro.core.hypergraph import dist as D
    sh = shard_hypergraph(HG, 1)
    args = [jnp.asarray(a) for a in
            (sh.pv, sh.pe, sh.mask, sh.netw, sh.esize, sh.vwgt)]
    labels = jnp.asarray(np.arange(sh.n_pad, dtype=np.int32))
    capv = jnp.asarray(np.full(sh.n_pad, 40.0, np.float32))
    iters = 4
    got, _ = D._parhyp_cluster_jit(_mesh1(), *args, labels, capv,
                                   jnp.int32(0), sh.rows_v, sh.n_col,
                                   sh.e_rows, iters)
    want = labels
    for it in range(iters):
        want, _ = D._cluster_round_local(
            *args, want, capv, jnp.int32(it), rows_v=sh.rows_v,
            n_col=sh.n_col, e_rows=sh.e_rows, s_nets=1, s_verts=1,
            ax_n=None, ax_v=None)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # the round did something and respected the size cap
    assert not np.array_equal(np.asarray(got), np.arange(sh.n_pad))
    szs = np.zeros(sh.n_pad)
    np.add.at(szs, np.asarray(got), sh.vwgt)
    assert szs.max() <= 40.0


@pytest.mark.parametrize("objective", ["km1", "cut"])
def test_device_contraction_preserves_objective(objective):
    """Device contraction vs the host `coarsen.contract` oracle: for any
    coarse partition both coarse hypergraphs and the fine hypergraph agree
    exactly on the objective (contraction is objective-neutral)."""
    import jax.numpy as jnp
    from repro.core.hypergraph import dist as D
    from repro.core.hypergraph.coarsen import contract
    sh = shard_hypergraph(HG, 1)
    args = [jnp.asarray(a) for a in
            (sh.pv, sh.pe, sh.mask, sh.netw, sh.esize, sh.vwgt)]
    labels, _ = D._parhyp_cluster_jit(
        _mesh1(), *args, jnp.asarray(np.arange(sh.n_pad, dtype=np.int32)),
        jnp.asarray(np.full(sh.n_pad, 40.0, np.float32)), jnp.int32(0),
        sh.rows_v, sh.n_col, sh.e_rows, 4)
    out = D._parhyp_contract_jit(_mesh1(), args[0], args[1], args[2],
                                 args[3], args[5], labels, sh.n_col,
                                 sh.e_rows)
    pv2, pe2, mask2, netw2, esize2, cvw, coarse_of, nc, hi = out
    assert int(hi) >= int(np.sum(np.asarray(mask2) > 0))
    hg_c, ids = D._extract_coarsest(
        D._DeviceLevel(pv2, pe2, mask2, netw2, esize2, cvw))
    assert hg_c.n == int(nc) < HG.n
    assert hg_c.total_vwgt() == HG.total_vwgt()
    lab_h = np.asarray(labels)[:HG.n]
    hg_h, cl = contract(HG, lab_h)
    assert hg_h.n == hg_c.n
    score = connectivity if objective == "km1" else cut_net
    remap = np.zeros(sh.n_pad, np.int64)
    remap[ids] = np.arange(len(ids))
    co = remap[np.asarray(coarse_of)[:HG.n]]
    rng = np.random.default_rng(5)
    for trial in range(3):
        g = rng.integers(0, 4, sh.n_pad)
        fine = g[lab_h]
        f_dev = np.zeros(hg_c.n, np.int64)
        f_dev[co] = fine
        f_host = np.zeros(hg_h.n, np.int64)
        f_host[cl] = fine
        want = score(HG, fine)
        assert score(hg_c, f_dev) == want
        assert score(hg_h, f_host) == want


def test_parhyp_device_path_runs_device_coarsening():
    """With the gather-to-one-PE floor lifted, parhyp must take the
    device-resident V-cycle and record coarsening spans."""
    from repro import obs
    rec = obs.Recorder()
    part = parhyp(HG, 4, 0.03, "fast", seed=1, mesh=_mesh1(), report=rec,
                  device_min_n=0)
    assert is_feasible(HG, part, 4, 0.03)
    names = {e.get("name") for e in rec.events}
    assert "parhyp_coarsen" in names, sorted(names)
    assert rec.counters().get("parhyp/device_levels", 0) >= 2


@pytest.mark.slow
def test_parhyp_mesh_layout_parity_subprocess():
    """4 fake devices: (4,), (4,1) and (1,4) meshes must refine
    bit-identically, and a genuinely 2-D (2,2) mesh must complete the full
    device pipeline feasibly within the coarsening quality gate."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.io.generators import planted_hypergraph
        from repro.core.hypergraph import connectivity, is_feasible, kahypar
        from repro.core.hypergraph.dist import parhyp, parhyp_refine
        from repro.core.hypergraph.initial import random_partition
        assert len(jax.devices()) == 4
        devs = np.array(jax.devices())
        hg = planted_hypergraph(300, 450, blocks=4, seed=7)
        part0 = random_partition(hg, 4, seed=1)
        outs = []
        for shape, axes in (((4,), ("nets",)),
                            ((4, 1), ("nets", "verts")),
                            ((1, 4), ("nets", "verts"))):
            mesh = Mesh(devs.reshape(shape), axes)
            outs.append(parhyp_refine(hg, part0, 4, mesh=mesh, rounds=6,
                                      seed=3))
        assert np.array_equal(outs[0], outs[1])
        assert np.array_equal(outs[0], outs[2])
        mesh22 = Mesh(devs.reshape(2, 2), ("nets", "verts"))
        part = parhyp(hg, 4, 0.03, "fast", seed=1, mesh=mesh22,
                      device_min_n=0)
        assert is_feasible(hg, part, 4, 0.03)
        km1_d = connectivity(hg, part)
        km1_s = connectivity(hg, kahypar(hg, 4, 0.03, "fast", seed=1))
        assert km1_d <= 1.05 * km1_s, (km1_d, km1_s)
        print("PARITY_OK", km1_d, km1_s)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PARITY_OK" in r.stdout, r.stdout + r.stderr
