"""parhyp — the distributed (shard_map) hypergraph partitioner: sharding
invariants, 1-device bit-exactness vs the sequential COO oracle,
never-worse refinement, end-to-end quality, the C-API-style interface
entry, and the multi-device subprocess run."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
from jax.sharding import Mesh

from repro.core.hypergraph import (connectivity, cut_net, evaluate,
                                   is_feasible, refine_hypergraph)
from repro.core.hypergraph.container import to_pincoo
from repro.core.hypergraph.dist import (parhyp, parhyp_refine,
                                        shard_hypergraph)
from repro.core.hypergraph.initial import random_partition
from repro.io.generators import planted_hypergraph, random_hypergraph

HG = planted_hypergraph(300, 450, blocks=4, seed=7)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1() -> Mesh:
    return Mesh(np.array(jax.devices()[:1]), ("nets",))


# -- sharded container -------------------------------------------------------

def test_shard_hypergraph_conserves_pins_and_weights():
    sh = shard_hypergraph(HG, 4)
    assert sh.n_shards == 4
    assert sh.n_pad == sh.n_shards * sh.rows_v
    assert float(sh.mask.sum()) == HG.pins
    assert float(sh.vwgt.sum()) == HG.total_vwgt()
    assert float(sh.netw.sum()) == HG.total_ewgt()
    # every real (net, vertex) pin appears exactly once across all shards
    real = sh.mask.reshape(-1) > 0
    got = np.stack([sh.pe.reshape(-1)[real], sh.pv.reshape(-1)[real]], 1)
    want = np.stack([HG.pin_sources(), HG.eind], 1)
    assert np.array_equal(got[np.lexsort(got.T)], want[np.lexsort(want.T)])
    # nets are block-distributed: each net's pins live on a single shard
    owner = np.repeat(np.arange(4), sh.p_shard)[real]
    per_net = {}
    for e, s in zip(sh.pe.reshape(-1)[real], owner):
        per_net.setdefault(int(e), set()).add(int(s))
    assert all(len(s) == 1 for s in per_net.values())


def test_one_shard_layout_matches_pincoo():
    """The S=1 shard is exactly the sequential pin-COO view — the layout
    half of the bit-exactness guarantee."""
    sh = shard_hypergraph(HG, 1)
    hc = to_pincoo(HG)
    np.testing.assert_array_equal(sh.pv[0], np.asarray(hc.pv))
    np.testing.assert_array_equal(sh.pe[0], np.asarray(hc.pe))
    np.testing.assert_array_equal(sh.mask[0], np.asarray(hc.mask))
    np.testing.assert_array_equal(sh.netw, np.asarray(hc.netw))
    np.testing.assert_array_equal(sh.esize, np.asarray(hc.esize))
    np.testing.assert_array_equal(sh.vwgt, np.asarray(hc.vwgt))


# -- distributed refinement --------------------------------------------------

@pytest.mark.parametrize("objective", ["km1", "cut"])
def test_refine_bit_exact_vs_sequential_oracle(objective):
    """A fixed 1-device mesh must reproduce the sequential COO refiner
    bit-for-bit (same RNG stream, same scatter orders, same acceptance)."""
    part0 = random_partition(HG, 4, seed=1)
    a = refine_hypergraph(HG, part0, 4, rounds=6, seed=3,
                          objective=objective, use_kernel=False)
    b = parhyp_refine(HG, part0, 4, mesh=_mesh1(), rounds=6, seed=3,
                      objective=objective)
    assert np.array_equal(a, b)


def test_refine_never_worse_and_improves_random():
    part0 = random_partition(HG, 4, seed=2)
    out = parhyp_refine(HG, part0, 4, mesh=_mesh1(), rounds=8, seed=1)
    assert connectivity(HG, out) < connectivity(HG, part0)
    assert is_feasible(HG, out, 4, 0.03)


# -- the parhyp program ------------------------------------------------------

def test_parhyp_end_to_end_quality():
    part = parhyp(HG, 4, 0.03, "fast", seed=1, mesh=_mesh1())
    ev = evaluate(HG, part, 4)
    assert ev["feasible"], ev
    rnd = connectivity(HG, random_partition(HG, 4, seed=0))
    assert ev["km1"] * 2 <= rnd, (ev, rnd)


def test_parhyp_cut_objective():
    part = parhyp(HG, 4, 0.03, "ultrafast", seed=2, mesh=_mesh1(),
                  objective="cut")
    assert is_feasible(HG, part, 4, 0.03)
    rnd = cut_net(HG, random_partition(HG, 4, seed=0))
    assert cut_net(HG, part) < rnd


def test_parhyp_single_level_refines(monkeypatch):
    """Single-level hierarchies (n <= stop_n) must still run the
    distributed refiner + repair at level 0 — the parhip-bug guarantee
    parhyp carries from day one."""
    import repro.core.hypergraph.dist as D
    calls = []
    orig = D.parhyp_refine
    monkeypatch.setattr(D, "parhyp_refine",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    hg = random_hypergraph(40, 60, seed=3)
    part = D.parhyp(hg, 2, 0.03, "ultrafast", seed=1, mesh=_mesh1())
    assert calls, "level-0 refinement must run on single-level hierarchies"
    assert is_feasible(hg, part, 2, 0.03)


def test_interface_parhyp():
    from repro.core import interface
    objval, part = interface.parhyp(
        HG.n, HG.m, None, None, HG.eptr, HG.eind, 4, 0.03, seed=1,
        preconfiguration="ultrafast", mesh=_mesh1())
    assert objval == connectivity(HG, part)
    assert is_feasible(HG, part, 4, 0.03)


@pytest.mark.slow
def test_parhyp_multidevice_subprocess():
    """4 fake host devices: the genuinely sharded path must stay feasible
    and no worse than 5% over the sequential partitioner."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.io.generators import planted_hypergraph
        from repro.core.hypergraph import connectivity, is_feasible, kahypar
        from repro.core.hypergraph.dist import parhyp
        assert len(jax.devices()) == 4
        mesh = Mesh(np.array(jax.devices()), ("nets",))
        hg = planted_hypergraph(300, 450, blocks=4, seed=7)
        part = parhyp(hg, 4, 0.03, "fast", seed=1, mesh=mesh)
        assert is_feasible(hg, part, 4, 0.03)
        km1_d = connectivity(hg, part)
        km1_s = connectivity(hg, kahypar(hg, 4, 0.03, "fast", seed=1))
        assert km1_d <= 1.05 * km1_s, (km1_d, km1_s)
        print("MULTIDEV_OK", km1_d, km1_s)
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
