"""Hypothesis property tests on system invariants (deliverable c)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core.csr import Graph, to_coo
from repro.core.partition import (balance, block_weights, edge_cut,
                                  edge_cut_device)
from repro.core.separator import partition_to_vertex_separator, \
    verify_separator
from repro.core import lp as lp_mod
from repro.io import metis


@st.composite
def graphs(draw, max_n=24):
    n = draw(st.integers(2, max_n))
    m = draw(st.integers(1, 3 * n))
    u = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    v = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    w = draw(st.lists(st.integers(1, 9), min_size=m, max_size=m))
    return Graph.from_edges(n, u, v, w)


@given(graphs())
@settings(max_examples=40, deadline=None)
def test_from_edges_always_valid(g):
    assert g.check() == []


@given(graphs(), st.integers(2, 4), st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_cut_host_equals_device(g, k, seed):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, g.n)
    coo = to_coo(g)
    lab = np.zeros(coo.n_pad, dtype=np.int32)
    lab[:g.n] = part
    host = edge_cut(g, part)
    dev = float(edge_cut_device(coo, jnp.asarray(lab)))
    assert abs(host - dev) < 1e-3


@given(graphs(), st.integers(2, 4), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_block_weights_partition_total(g, k, seed):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, g.n)
    bw = block_weights(g, part, k)
    assert bw.sum() == g.total_vwgt()
    assert balance(g, part, k) >= bw.max() / (g.total_vwgt())


@given(graphs(max_n=16), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_separator_always_separates(g, seed):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, 2, g.n)
    sep = partition_to_vertex_separator(g, part, 2)
    assert verify_separator(g, part, sep, 2)


@given(graphs(max_n=20), st.integers(2, 30), st.integers(0, 9))
@settings(max_examples=15, deadline=None)
def test_lp_clustering_respects_any_cap(g, cap, seed):
    clusters = lp_mod.size_constrained_lp(g, float(cap), iters=4, seed=seed)
    sizes = np.bincount(clusters, weights=g.vwgt.astype(float),
                        minlength=clusters.max() + 1)
    # singleton clusters may exceed cap only if a single node does
    for cid in np.unique(clusters):
        members = np.flatnonzero(clusters == cid)
        if len(members) > 1:
            assert g.vwgt[members].sum() <= cap


@given(graphs(max_n=20))
@settings(max_examples=20, deadline=None)
def test_metis_roundtrip_property(g):
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "g.graph")
        metis.write_metis(g, p)
        g2 = metis.read_metis(p)
        assert np.array_equal(g.xadj, g2.xadj)
        assert np.array_equal(g.adjncy, g2.adjncy)
        assert np.array_equal(g.adjwgt, g2.adjwgt)
        assert np.array_equal(g.vwgt, g2.vwgt)


@given(graphs(max_n=20), st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_nodesep_refinement_invariant_every_step_every_level(g, seed):
    """Invariant: every separator-refinement step yields labels where no A
    vertex is adjacent to a B vertex — at each hierarchy level (the
    two-hop pull-in mask guarantee, DESIGN.md §8)."""
    from repro.core import multilevel as ML
    from repro.core import nodesep as NS
    cfg = NS.NodesepConfig(refine_rounds=4, bisect_rounds=4,
                           initial_tries=2, stop_n_floor=4,
                           contraction_stop_factor=2)
    medium = NS.SeparatorMedium(g, cfg)
    levels = ML.build_hierarchy(medium, 2, seed)
    for level in levels:
        gm = level.medium
        cands = gm.initial_candidates(2, 0.2, seed)
        for c in cands:
            assert NS.separator_invariant_ok(gm.g, c)
        labels = cands[0]
        coo, ell = gm.views
        for step in range(3):       # single-round steps expose every state
            labels = NS.refine_separator(gm.g, labels, 0.2, rounds=1,
                                         seed=seed + step, coo=coo, ell=ell,
                                         use_kernel=gm.use_kernel)
            assert NS.separator_invariant_ok(gm.g, labels)
        labels = gm.refine(labels, 2, 0.2, seed)    # full per-level pipeline
        assert NS.separator_invariant_ok(gm.g, labels)


@given(graphs(max_n=20), st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_separator_io_roundtrip_property(g, seed):
    import os
    import tempfile
    rng = np.random.default_rng(seed)
    part = rng.integers(0, 2, g.n)
    sep_ids = np.flatnonzero(rng.random(g.n) < 0.3)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "sep.txt")
        metis.write_separator(part, sep_ids, 2, p)
        part2, sep2 = metis.read_separator(p, k=2)
        assert np.array_equal(np.sort(sep_ids), np.sort(sep2))
        keep = np.setdiff1d(np.arange(g.n), sep_ids)
        assert np.array_equal(part[keep], part2[keep])


@given(st.integers(2, 6), st.integers(0, 999))
@settings(max_examples=20, deadline=None)
def test_capped_accept_never_overflows(k, seed):
    """Invariant: for every target, size + accepted inflow <= cap."""
    rng = np.random.default_rng(seed)
    n = 64
    labels = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    proposal = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    vwgt = jnp.asarray(rng.integers(1, 4, n), jnp.float32)
    sizes = jnp.zeros((k,), jnp.float32).at[labels].add(vwgt)
    cap = jnp.asarray(sizes + rng.integers(0, 6, k), jnp.float32)
    pri = jnp.asarray(rng.random(n), jnp.float32)
    out = np.asarray(lp_mod.capped_accept(labels, proposal, vwgt, sizes,
                                          cap, pri))
    moved_in = np.zeros(k)
    for i in range(n):
        if out[i] != int(labels[i]):
            assert out[i] == int(proposal[i])   # only proposed moves happen
            moved_in[out[i]] += float(vwgt[i])
    for t in range(k):
        assert float(sizes[t]) + moved_in[t] <= float(cap[t]) + 1e-5
