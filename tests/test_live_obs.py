"""Streaming serve telemetry (repro.obs.live, DESIGN.md §13): metric
primitives, per-slot request tracing, and the live traffic hypergraph."""
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import obs
from repro.obs.live import (EwmaRate, QuantileSketch, ServeTelemetry,
                            TrafficAccumulator, WindowedCounter,
                            NULL_TELEMETRY)


# -- streaming metric primitives --------------------------------------------

def test_windowed_counter_rollover_exact():
    # window 10s in 10 buckets of 1s; adds at t∈[0,10) all visible at t=9.5,
    # and exactly the last 10 bucket epochs are visible later
    c = WindowedCounter(window_s=10.0, buckets=10, clock=lambda: 0.0)
    for t in range(10):
        c.add(1.0, now=t + 0.5)
    assert c.total(now=9.5) == 10.0
    # at t=10.5 the t=0 bucket has rolled out
    assert c.total(now=10.5) == 9.0
    # reusing a slot zeroes the stale epoch before accumulating
    c.add(5.0, now=10.5)
    assert c.total(now=10.5) == 14.0
    # far future: everything stale
    assert c.total(now=1000.0) == 0.0
    # stale slots never leak back even when partially overwritten
    c.add(2.0, now=1000.0)
    assert c.total(now=1000.0) == 2.0
    assert c.rate(now=1000.0) == pytest.approx(0.2)


def test_windowed_counter_bucket_boundaries():
    c = WindowedCounter(window_s=4.0, buckets=4, clock=lambda: 0.0)
    c.add(1.0, now=0.0)        # bucket 0
    c.add(1.0, now=3.999)      # bucket 3
    assert c.total(now=3.999) == 2.0
    assert c.total(now=4.0) == 1.0     # bucket 0 just rolled out


def test_ewma_rate_monotone_convergence():
    # constant 2 events/sec from a cold start: estimate rises monotonically
    # toward the true rate and never overshoots
    r = EwmaRate(halflife_s=2.0, clock=lambda: 0.0)
    vals = []
    for i in range(200):
        vals.append(r.update(1.0, now=i * 0.5))
    assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:]))
    assert vals[-1] == pytest.approx(2.0, rel=1e-3)
    assert max(vals) <= 2.0 + 1e-9
    # idle decay: the gauge halves every halflife (last event at t=99.5)
    assert r.value(now=99.5 + 2.0) == pytest.approx(vals[-1] / 2, rel=1e-6)


@pytest.mark.parametrize("dist", ["uniform", "lognormal", "bimodal"])
def test_quantile_sketch_rank_error_bound(dist):
    rng = np.random.default_rng(hash(dist) % (2 ** 32))
    n, eps = 5000, 0.02
    if dist == "uniform":
        xs = rng.uniform(0, 1e6, n)
    elif dist == "lognormal":
        xs = rng.lognormal(3.0, 2.0, n)
    else:
        xs = np.concatenate([rng.normal(10, 1, n // 2),
                             rng.normal(1000, 5, n - n // 2)])
    sk = QuantileSketch(eps=eps)
    for x in xs:
        sk.add(x)
    srt = np.sort(xs)
    for q in (0.01, 0.25, 0.5, 0.9, 0.95, 0.99):
        got = sk.query(q)
        rank = np.searchsorted(srt, got, side="left")
        # GK guarantee: returned value's rank within eps*n + 1 of target
        assert abs(rank - q * n) <= eps * n + 1, (q, rank, q * n)
    assert sk.query(0.0) == srt[0] and sk.query(1.0) == srt[-1]
    # sketch stays sublinear
    assert len(sk._v) < n / 4


def test_quantile_sketch_small_and_empty():
    sk = QuantileSketch(eps=0.01)
    assert np.isnan(sk.query(0.5))
    for x in [5.0, 1.0, 3.0]:
        sk.add(x)
    assert sk.query(0.5) in (1.0, 3.0, 5.0)
    ks = set(sk.quantiles())
    assert ks == {"p50", "p95", "p99"}


# -- hypothesis property tests (skipped when hypothesis is absent; the
# -- deterministic seeded tests above/below always cover the same claims) ----

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(0, 1e9, allow_nan=False), min_size=1,
                    max_size=2000),
           st.sampled_from([0.25, 0.5, 0.75, 0.95, 0.99]))
    def test_hyp_sketch_rank_bound(xs, q):
        sk = QuantileSketch(eps=0.05)
        for x in xs:
            sk.add(x)
        srt = np.sort(xs)
        rank = np.searchsorted(srt, sk.query(q), side="left")
        assert abs(rank - q * len(xs)) <= 0.05 * len(xs) + 1

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.floats(0, 100, allow_nan=False),
                              st.floats(0.1, 10)), min_size=1, max_size=200))
    def test_hyp_windowed_counter_exact(events):
        c = WindowedCounter(window_s=8.0, buckets=8, clock=lambda: 0.0)
        now = 0.0
        for v, dt in events:
            now += dt
            c.add(v, now=now)
        idx = int(np.floor(now / c.bucket_w))
        # exact model: sum of per-epoch totals over the live epoch range
        # (the live range covers `buckets` consecutive epochs, bijective
        # modulo `buckets`, so no in-range epoch can have been evicted)
        per = {}
        t = 0.0
        for v, dt in events:
            t += dt
            e = int(np.floor(t / c.bucket_w))
            per[e] = per.get(e, 0.0) + v
        expect = sum(v for e, v in per.items() if idx - c.buckets < e <= idx)
        assert c.total(now=now) == pytest.approx(expect)

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.5, 16.0), st.floats(0.05, 2.0), st.floats(0.5, 50.0))
    def test_hyp_ewma_monotone(halflife, dt, per_event):
        r = EwmaRate(halflife_s=halflife, clock=lambda: 0.0)
        prev, true_rate = 0.0, per_event / dt
        for i in range(100):
            cur = r.update(per_event, now=(i + 1) * dt)
            assert cur >= prev - 1e-9
            prev = cur
        assert cur <= true_rate + 1e-6

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 6), st.integers(1, 5),
           st.lists(st.integers(1, 20), min_size=1, max_size=6),
           st.integers(0, 2 ** 31 - 1))
    def test_hyp_traffic_decay1_matches_batch(n_e, k, chunks, seed):
        from repro.models.moe import coactivation_graph
        k = min(k, n_e)
        rng = np.random.default_rng(seed)
        acc = TrafficAccumulator(n_e, decay=1.0)
        all_idx = []
        for t in chunks:
            gi = np.stack([rng.choice(n_e, size=k, replace=False)
                           for _ in range(t)])
            acc.observe(gi)
            all_idx.append(gi)
        ref = coactivation_graph(np.concatenate(all_idx), n_e)
        got = acc.to_graph()
        np.testing.assert_array_equal(got.xadj, ref.xadj)
        np.testing.assert_array_equal(got.adjncy, ref.adjncy)
        np.testing.assert_array_equal(got.adjwgt, ref.adjwgt)
        np.testing.assert_array_equal(got.vwgt, ref.vwgt)


# -- traffic accumulator -----------------------------------------------------

def test_traffic_decay1_equals_batch_coactivation():
    from repro.models.moe import coactivation_graph
    rng = np.random.default_rng(0)
    n_e = 8
    acc = TrafficAccumulator(n_e, decay=1.0)
    all_idx = []
    for _ in range(7):
        gi = np.stack([rng.choice(n_e, size=3, replace=False)
                       for _ in range(rng.integers(1, 30))])
        acc.observe(gi)
        all_idx.append(gi)
    ref = coactivation_graph(np.concatenate(all_idx), n_e)
    got = acc.to_graph()
    np.testing.assert_array_equal(got.xadj, ref.xadj)
    np.testing.assert_array_equal(got.adjncy, ref.adjncy)
    np.testing.assert_array_equal(got.adjwgt, ref.adjwgt)
    np.testing.assert_array_equal(got.vwgt, ref.vwgt)


def test_traffic_decay_forgets():
    acc = TrafficAccumulator(4, decay=0.5)
    acc.observe(np.array([[0, 1]] * 8))
    w_then = acc.pair[0, 1]
    for _ in range(20):
        acc.observe(np.array([[2, 3]]))
    assert acc.pair[0, 1] < 1e-4 * w_then
    assert acc.pair[2, 3] > 1.0


def test_traffic_drift_and_advise():
    rec = obs.Recorder("drift")
    acc = TrafficAccumulator(8, decay=0.9)
    rng = np.random.default_rng(1)
    # baseline traffic: pairs inside {0..3} and {4..7}
    for _ in range(50):
        a, b = rng.choice(4, 2, replace=False)
        acc.observe(np.array([[a, b], [a + 4, b + 4]]))
    acc.set_baseline()
    assert acc.drift() == pytest.approx(0.0, abs=1e-9)
    assert not acc.advise(rec, threshold=0.3)
    # traffic flips to cross-group pairs: drift must cross the threshold
    for _ in range(200):
        a, b = rng.choice(4, 2, replace=False)
        acc.observe(np.array([[a, b + 4]]))
    assert acc.drift() > 0.5
    assert acc.advise(rec, threshold=0.3)
    assert obs.metrics.gauge("serve/repartition_advised") == 1.0
    assert obs.metrics.gauge("serve/traffic_drift") > 0.5
    g_evs = [e for e in rec.events if e["ph"] == "G"]
    assert any(e["name"] == "serve/traffic_drift" for e in g_evs)


def test_traffic_snapshot_hypergraph():
    acc = TrafficAccumulator(6, decay=1.0)
    acc.observe(np.array([[0, 1], [0, 1], [2, 3]]))
    acc.observe_sets([[0, 2, 4], [1, 3, 5], [4]])    # |s|<2 dropped
    hg = acc.snapshot()
    hg.check()
    assert hg.n == 6
    # 2-pin nets for (0,1) and (2,3), plus two 3-pin co-access nets
    sizes = sorted(np.diff(hg.eptr).tolist())
    assert sizes == [2, 2, 3, 3]
    # the (0,1) net carries weight 2
    assert max(hg.ewgt) == 2


def test_traffic_set_cap():
    acc = TrafficAccumulator(100, decay=1.0, max_sets=10)
    acc.observe_sets([[i, i + 1] for i in range(50)])
    assert len(acc.sets) <= 10


# -- serve telemetry ----------------------------------------------------------

def _fake_clock():
    state = {"t": 0.0}

    def clock():
        state["t"] += 0.001
        return state["t"]
    return clock


def test_serve_telemetry_lifecycle_and_tracks(tmp_path):
    rec = obs.Recorder("serve")
    acc = TrafficAccumulator(4, decay=1.0)
    tele = ServeTelemetry(recorder=rec, traffic=acc, clock=_fake_clock(),
                          advise_every=2)
    acc.observe(np.array([[0, 1]]))
    acc.set_baseline()
    tele.enqueued(7, queue_depth=1)
    tele.started(7, slot=0, prompt_len=3, active=1)
    tele.prefilled(7, slot=0, prompt_len=3)
    for i in range(4):
        acc.observe(np.array([[2, 3]]))
        tele.step(1, active=1, queue_depth=0, step_s=0.002)
        tele.tick(7, 0, token=11 + i)
    tele.finished(7, slot=0, n_out=4)

    snap = tele.snapshot()
    # 1 prefill-argmax token + 4 decode-step tokens
    assert snap["total_tokens"] == 5 and snap["total_requests"] == 1
    assert snap["steps"] == 4
    assert snap["drift"] is not None and snap["drift"] > 0.3
    assert {"queue_us", "prefill_us", "decode_us", "e2e_us"} \
        <= set(snap["latency_us"])
    assert snap["latency_us"]["decode_us"]["p50"] == pytest.approx(2000.0)
    assert snap["tok_per_s_window"] > 0

    # periodic advise ran and exported the gauges
    g_names = {e["name"] for e in rec.events if e["ph"] == "G"}
    assert {"serve/traffic_drift", "serve/repartition_advised"} <= g_names
    assert obs.metrics.gauge("serve/repartition_advised") == 1.0

    # balanced spans on the slot track, plus per-token instants
    slot_evs = [e for e in rec.events if e.get("track") == "slot 0"]
    assert sum(e["ph"] == "B" for e in slot_evs) == \
        sum(e["ph"] == "E" for e in slot_evs) == 3
    assert sum(e["ph"] == "I" for e in slot_evs) == 4

    # chrome export: named tracks become thread_name metadata; gauges
    # become counter tracks
    trace = obs.chrome_trace([rec], registry_gauges=True)["traceEvents"]
    names = {e["args"]["name"] for e in trace
             if e.get("name") == "thread_name"}
    assert {"slot 0", "queue"} <= names
    counters = {e["name"] for e in trace if e["ph"] == "C"}
    assert "serve/tok_per_s" in counters
    path = tmp_path / "serve_trace.json"
    obs.write_chrome_trace([rec], str(path), registry_gauges=True)
    json.loads(path.read_text())


def test_null_telemetry_contract():
    t = NULL_TELEMETRY
    assert not t.enabled and t.traffic is None
    t.enqueued(0, 1)
    t.started(0, 0, 3)
    t.prefilled(0, 0)
    t.step(2, 1)
    t.tick(0, 0, 5)
    t.finished(0, 0, 2)
    assert t.snapshot() == {}


# -- MoE gate observation under jit ------------------------------------------

def test_observe_gates_streams_routing_to_accumulator():
    from repro.configs.base import get_config
    from repro.models import moe
    from repro.models import transformer as T
    cfg = get_config("deepseek_v2_236b").reduced()
    assert cfg.top_k >= 2
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    acc = TrafficAccumulator(cfg.n_experts, decay=1.0)

    fwd = jax.jit(lambda p, t: T.forward(p, cfg, t)[0])
    with moe.observe_gates(acc):
        fwd(params, tokens).block_until_ready()
    assert acc.events > 0
    assert acc.load.sum() > 0
    # decayed pair mass exists for top_k >= 2 routing
    assert (acc.pair + acc.pair.T).sum() > 0
    before = acc.events

    # clearing the observer stops the stream even for compiled programs
    fwd(params, tokens).block_until_ready()
    assert acc.events == before

    # a snapshot of observed traffic partitions cleanly
    hg = acc.snapshot()
    hg.check()
    assert hg.n == cfg.n_experts
