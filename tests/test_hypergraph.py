"""Hypergraph subsystem: container, hMETIS IO, pin-affinity kernel,
coarsening invariants, and the full kahypar multilevel driver."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.hypergraph import (Hypergraph, HypergraphFormatError,
                                   clique_expansion, connectivity, contract,
                                   cut_net, evaluate, is_feasible, kahypar,
                                   net_lambdas, refine_hypergraph,
                                   star_expansion, to_ell_h, to_pincoo)
from repro.core.hypergraph import metrics as M
from repro.core.hypergraph.initial import greedy_growing, random_partition
from repro.io import hmetis
from repro.io.generators import (grid_hypergraph, planted_hypergraph,
                                 random_hypergraph)
from repro.kernels import ops, ref


# -- container / validation --------------------------------------------------

def test_from_nets_dual_consistency():
    hg = Hypergraph.from_nets(5, [[0, 1, 2], [2, 3], [3, 4, 0]])
    assert hg.n == 5 and hg.m == 3 and hg.pins == 8
    assert hg.check() == []
    assert set(hg.incident_nets(0)) == {0, 2}
    assert set(hg.net_pins(1)) == {2, 3}


def test_checker_catches_errors():
    good = Hypergraph.from_nets(4, [[0, 1], [2, 3]])
    assert good.check() == []
    # pin id out of range
    bad = Hypergraph.from_nets(4, [[0, 1], [2, 3]])
    bad.eind = bad.eind.copy()
    bad.eind[0] = 7
    assert any("out of range" in e for e in bad.check(raise_on_error=False))
    # duplicate pin within a net
    dup = Hypergraph.from_nets(4, [[0, 0, 1]], dedup_pins=False)
    assert any("duplicate" in e for e in dup.check(raise_on_error=False))
    with pytest.raises(HypergraphFormatError):
        dup.check()
    # inconsistent dual
    skew = Hypergraph.from_nets(4, [[0, 1], [2, 3]])
    skew.vedges = skew.vedges.copy()
    skew.vedges[0] = 1
    assert any("disagree" in e for e in skew.check(raise_on_error=False))
    # non-positive net weight
    wz = Hypergraph.from_nets(4, [[0, 1]], ewgt=[0])
    assert any("net weight" in e for e in wz.check(raise_on_error=False))


@pytest.mark.parametrize("gen", [
    lambda: random_hypergraph(120, 180, seed=1, wmax=4),
    lambda: planted_hypergraph(120, 180, blocks=4, seed=1),
    lambda: grid_hypergraph(8, 8)])
def test_hypergraph_generators_valid(gen):
    hg = gen()
    assert hg.check() == []
    assert hg.n > 0 and hg.m > 0


# -- hMETIS IO ---------------------------------------------------------------

@pytest.mark.parametrize("weighted", [False, True])
def test_hmetis_roundtrip(tmp_path, weighted):
    hg = random_hypergraph(50, 70, seed=2, wmax=5 if weighted else 1)
    if weighted:
        hg.vwgt = np.random.default_rng(0).integers(1, 6, hg.n)
    p = str(tmp_path / "h.hgr")
    hmetis.write_hmetis(hg, p)
    h2 = hmetis.read_hmetis(p)
    assert np.array_equal(hg.eptr, h2.eptr)
    assert np.array_equal(hg.eind, h2.eind)
    assert np.array_equal(hg.ewgt, h2.ewgt)
    assert np.array_equal(hg.vwgt, h2.vwgt)
    assert hmetis.hypergraphchecker(p) == []


def test_hmetis_rejects_malformed(tmp_path):
    p = str(tmp_path / "bad.hgr")
    with open(p, "w") as f:
        f.write("2 3 1\n5 1 2\n")          # header says 2 nets, file has 1
    assert hmetis.hypergraphchecker(p) != []


# -- metrics -----------------------------------------------------------------

def test_objectives_on_known_partition():
    # nets: {0,1} internal, {0,2,3} spans 2 blocks, {2,3} internal to B1
    hg = Hypergraph.from_nets(4, [[0, 1], [0, 2, 3], [2, 3]],
                              ewgt=[1, 5, 2])
    part = np.array([0, 0, 1, 1])
    assert np.array_equal(net_lambdas(hg, part), [1, 2, 1])
    assert cut_net(hg, part) == 5
    assert connectivity(hg, part) == 5
    # device versions agree
    hc = to_pincoo(hg)
    lab = np.zeros(hc.n_pad, dtype=np.int32)
    lab[:4] = part
    cnt = M.pin_counts_device(hc, jnp.asarray(lab), 2)
    assert float(M.km1_device(cnt, hc.netw)) == 5.0
    assert float(M.cut_net_device(cnt, hc.netw)) == 5.0


# -- pin-affinity kernel -----------------------------------------------------

@pytest.mark.parametrize("n,m,k", [(100, 150, 2), (300, 500, 5), (64, 90, 130)])
def test_pin_affinity_kernel_bit_exact(n, m, k):
    """Pallas kernel (interpret mode on CPU) vs jnp reference vs numpy."""
    hg = random_hypergraph(n, m, seed=n + k, wmax=4)
    ell = to_ell_h(hg)
    rng = np.random.default_rng(k)
    labels = jnp.asarray(rng.integers(0, k, ell.n_pad).astype(np.int32))
    cnt, score = ops.pin_count(ell.pins, ell.pin_mask, ell.netw, labels, k)
    cnt_r, score_r = ref.pin_count_ref(labels[ell.pins], ell.pin_mask,
                                       ell.netw, k)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(score), np.asarray(score_r))
    aff = ops.pin_affinity(ell.vnets, ell.pins, ell.pin_mask, ell.netw,
                           labels, k)
    aff_r = ref.pin_affinity_ref(ell.vnets, labels[ell.pins], ell.pin_mask,
                                 ell.netw, k)
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(aff_r))
    # numpy brute force on the host container
    lab_h = np.asarray(labels)
    want = np.zeros((hg.n, k), dtype=np.float64)
    for e in range(hg.m):
        pins = hg.net_pins(e)
        for b in range(k):
            want[pins, b] += int(hg.ewgt[e]) * int((lab_h[pins] == b).sum())
    np.testing.assert_array_equal(np.asarray(aff)[:hg.n], want)


def test_refinement_kernel_path_matches_coo():
    """Pallas pin counts plugged into LP refinement must be bit-identical
    to the COO scatter path (same RNG stream)."""
    hg = planted_hypergraph(200, 300, blocks=4, seed=7)
    part0 = random_partition(hg, 4, seed=1)
    a = refine_hypergraph(hg, part0, 4, rounds=6, seed=3, use_kernel=False)
    b = refine_hypergraph(hg, part0, 4, rounds=6, seed=3, use_kernel=True)
    assert np.array_equal(a, b)


# -- coarsening --------------------------------------------------------------

def test_contract_preserves_weight_and_objectives():
    hg = planted_hypergraph(150, 220, blocks=4, seed=3, wmax=3)
    clusters = np.arange(150) // 3          # triples of vertices merge
    coarse, cl = contract(hg, clusters)
    assert coarse.check() == []
    assert coarse.total_vwgt() == hg.total_vwgt()
    assert coarse.net_sizes().min() >= 2    # single-pin nets dropped
    # any partition constant on clusters has identical objectives
    rng = np.random.default_rng(0)
    part_c = rng.integers(0, 3, coarse.n)
    part_f = part_c[cl]
    assert connectivity(coarse, part_c) == connectivity(hg, part_f)
    assert cut_net(coarse, part_c) == cut_net(hg, part_f)


def test_expansions_valid():
    hg = random_hypergraph(60, 90, seed=4, wmax=3)
    ce = clique_expansion(hg)
    assert ce.check() == [] and ce.n == hg.n
    se = star_expansion(hg)
    assert se.check() == [] and se.n == hg.n + hg.m
    assert se.m == hg.pins                  # one edge per pin


# -- initial + driver --------------------------------------------------------

def test_greedy_growing_covers_all_blocks():
    hg = planted_hypergraph(120, 180, blocks=4, seed=9)
    part = greedy_growing(hg, 4, seed=0)
    assert set(np.unique(part)) == {0, 1, 2, 3}
    assert M.balance(hg, part, 4) < 1.5     # roughly balanced by target


@pytest.mark.parametrize("k", [2, 4])
def test_kahypar_end_to_end(k):
    hg = planted_hypergraph(400, 600, blocks=4, seed=11)
    part = kahypar(hg, k, 0.03, "eco", seed=1)
    ev = evaluate(hg, part, k)
    assert ev["feasible"], ev
    rnd = connectivity(hg, random_partition(hg, k, seed=0))
    assert ev["km1"] * 2 <= rnd, (ev, rnd)  # ≥2× better than random


def test_kahypar_cut_objective():
    hg = planted_hypergraph(300, 450, blocks=4, seed=13)
    part = kahypar(hg, 4, 0.03, "fast", seed=2, objective="cut")
    assert is_feasible(hg, part, 4, 0.03)
    rnd = cut_net(hg, random_partition(hg, 4, seed=0))
    assert cut_net(hg, part) < rnd


def test_interface_kahypar():
    from repro.core import interface
    hg = planted_hypergraph(200, 300, blocks=4, seed=17)
    objval, part = interface.kahypar(
        hg.n, hg.m, None, None, hg.eptr, hg.eind, 4, 0.03, seed=1,
        mode=interface.FAST)
    assert objval == connectivity(hg, part)
    assert is_feasible(hg, part, 4, 0.03)
