"""Separators, edge partitioning, mapping, ordering, exact solver,
library interface."""
import numpy as np
import pytest

from repro.core.csr import Graph
from repro.core.edgepart import (build_spac, edge_partition,
                                 naive_edge_partition)
from repro.core.ilp import ilp_exact, ilp_improve
from repro.core.kaffpa import kaffpa
from repro.core.mapping import (processor_distance_matrix, process_mapping,
                                qap_cost, kaffpa_with_mapping)
from repro.core.ordering import (apply_reductions, fast_reduced_nd, fill_in,
                                 reduced_nd, _min_degree_order)
from repro.core.partition import edge_cut, edge_partition_metrics
from repro.core.separator import (node_separator,
                                  partition_to_vertex_separator,
                                  verify_separator)
from repro.core import interface as api
from repro.io.generators import grid2d, grid3d, barabasi_albert

GRID = grid2d(12, 12)


def test_2way_separator_valid_and_small():
    sep, part = node_separator(GRID, 0.2, "fast", seed=1)
    assert verify_separator(GRID, part, sep, 2)
    # a 12x12 grid has a 12-node column separator; VC must be <= boundary
    assert 0 < len(sep) <= 24


def test_kway_separator_valid():
    part = kaffpa(GRID, 4, 0.03, "fast", seed=1)
    sep = partition_to_vertex_separator(GRID, part, 4)
    assert verify_separator(GRID, part, sep, 4)


def test_spac_structure():
    spac, esplit = build_spac(GRID, infinity=100)
    assert spac.n == 2 * GRID.m
    assert spac.check() == []
    assert esplit.shape == (GRID.m, 2)


def test_edge_partition_beats_naive_replication():
    ep = edge_partition(GRID, 4, 0.05, "fast", seed=1)
    nv = naive_edge_partition(GRID, 4, seed=1)
    m_ep = edge_partition_metrics(GRID, ep, 4)
    m_nv = edge_partition_metrics(GRID, nv, 4)
    assert m_ep["replication"] < m_nv["replication"]


def test_edge_partition_vcycles_keep_infinity_edges_together():
    """edge_partition rides multilevel.run on a GraphMedium of the SPAC
    graph; protected re-coarsening (V-cycles) must not tear the
    infinity-weight auxiliary cycles apart — replication stays low and
    never worsens vs the single-cycle run."""
    base = edge_partition(GRID, 4, 0.05, "fast", seed=1)
    more = edge_partition(GRID, 4, 0.05, "fast", seed=1, vcycles=3)
    m_base = edge_partition_metrics(GRID, base, 4)
    m_more = edge_partition_metrics(GRID, more, 4)
    assert m_more["replication"] <= m_base["replication"] + 1e-9
    nv = edge_partition_metrics(GRID, naive_edge_partition(GRID, 4, seed=1),
                                4)
    assert m_more["replication"] < nv["replication"]


def test_distance_matrix():
    dist = processor_distance_matrix([2, 2], [1, 10])
    assert dist[0, 0] == 0
    assert dist[0, 1] == 1          # same pair, different core
    assert dist[0, 2] == 10         # different pair


def test_process_mapping_improves_clustered_pattern():
    rng = np.random.default_rng(0)
    k = 16
    comm = np.zeros((k, k), dtype=np.int64)
    # 4 chatty cliques scattered across ids — identity mapping is bad
    perm = rng.permutation(k)
    for c in range(4):
        ids = perm[c * 4:(c + 1) * 4]
        for i in ids:
            for j in ids:
                if i != j:
                    comm[i, j] = 100
    mapping = process_mapping(comm, "4:4", "1:10", seed=1)
    dist = processor_distance_matrix([4, 4], [1, 10])
    assert qap_cost(comm, dist, mapping) < qap_cost(comm, dist, np.arange(k))
    assert sorted(mapping.tolist()) == list(range(k))   # a permutation


def test_kaffpa_with_mapping():
    part, mapping, qap = kaffpa_with_mapping(GRID, "2:2", "1:10", 0.03,
                                             "fast", seed=1)
    assert sorted(np.unique(part).tolist()) == [0, 1, 2, 3]
    assert qap >= 0


def test_reductions_dynamic_graph():
    # a path graph fully reduces through degree-2 elimination
    n = 20
    path = Graph.from_edges(n, np.arange(n - 1), np.arange(1, n))
    kernel, ids, prefix, follow = apply_reductions(path, (0, 3, 4))
    assert kernel.n <= 4


def test_nd_is_permutation_and_beats_natural_on_3d():
    g = grid3d(6, 6, 6)
    order = fast_reduced_nd(g, seed=1)
    assert sorted(order.tolist()) == list(range(g.n))
    assert fill_in(g, order) < fill_in(g, np.arange(g.n))


def test_exact_solver_optimal_on_cycle():
    # 8-cycle, k=2, eps=0: optimal cut is 2
    n = 8
    g = Graph.from_edges(n, np.arange(n), (np.arange(n) + 1) % n)
    part = ilp_exact(g, 2, 0.0, timeout=30, seed=1)
    assert edge_cut(g, part) == 2


def test_ilp_improve_never_worsens():
    part = kaffpa(GRID, 4, 0.03, "fast", seed=11)
    out = ilp_improve(GRID, part, 4, timeout=15, seed=1)
    assert edge_cut(GRID, out) <= edge_cut(GRID, part)


def test_library_interface_kaffpa():
    g = GRID
    cut, part = api.kaffpa(g.n, None, g.xadj, None, g.adjncy, 2, 0.03,
                           seed=1, mode=api.FAST)
    assert cut == edge_cut(g, part)
    n_sep, sep = api.node_separator(g.n, None, g.xadj, None, g.adjncy, 2,
                                    0.2, seed=1, mode=api.FAST)
    assert n_sep == len(sep)
    ordering = api.fast_reduced_nd(g.n, g.xadj, g.adjncy, seed=1)
    assert sorted(ordering.tolist()) == list(range(g.n))
