"""Distributed paths: ParHIP shard_map (1 dev inline + 8 fake devs via
subprocess), evolutionary algorithm, mesh construction, dry-run artifacts."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.evolve import combine, kaffpaE
from repro.core.kaffpa import PRESETS, kaffpa
from repro.core.parhip import parhip, shard_graph
from repro.core.partition import edge_cut, evaluate, is_feasible
from repro.io.generators import grid2d

GRID = grid2d(16, 16)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_parhip_single_device():
    part = parhip(GRID, 4, 0.03, "fastmesh", seed=1)
    ev = evaluate(GRID, part, 4)
    assert ev["feasible"]


def test_parhip_single_level_refines(monkeypatch):
    """Regression: with a single-level hierarchy (n <= stop_n) parhip used
    to skip refinement and repair entirely, returning the raw initial
    partition — level 0 must always be refined."""
    import repro.core.parhip as PH
    calls = []
    orig = PH.parhip_refine
    monkeypatch.setattr(PH, "parhip_refine",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    small = grid2d(6, 6)                   # 36 nodes < the stop_n floor
    part = PH.parhip(small, 4, 0.03, "ultrafastmesh", seed=3)
    assert calls, "level-0 refinement must run on single-level hierarchies"
    assert is_feasible(small, part, 4, 0.03)


def test_shard_graph_partitions_edges():
    sg = shard_graph(GRID, 4)
    assert sg.n_shards == 4
    assert float(sg.w.sum()) == float(GRID.adjwgt.sum())
    assert float(sg.vwgt.sum()) == float(GRID.vwgt.sum())


@pytest.mark.slow
def test_parhip_multidevice_subprocess():
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.io.generators import grid2d
        from repro.core.parhip import parhip
        from repro.core.partition import evaluate
        assert len(jax.devices()) == 8
        mesh = Mesh(np.array(jax.devices()), ("nodes",))
        g = grid2d(16, 16)
        part = parhip(g, 4, 0.03, "ultrafastmesh", seed=2, mesh=mesh)
        ev = evaluate(g, part, 4)
        assert ev["feasible"], ev
        print("MULTIDEV_OK", ev["cut"])
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


def test_combine_preserves_both_parents_representability():
    pa = kaffpa(GRID, 4, 0.03, "fast", seed=1)
    pb = kaffpa(GRID, 4, 0.03, "fast", seed=2)
    child = combine(GRID, pa, pb, 4, 0.03, PRESETS["fast"], seed=3)
    # the combine operator must never be worse than the better parent
    assert edge_cut(GRID, child) <= min(edge_cut(GRID, pa),
                                        edge_cut(GRID, pb))
    assert is_feasible(GRID, child, 4, 0.03)


def test_kaffpaE_quickstart_tiny_population():
    """Regression: quickstart used to crash with `Cannot take a larger
    sample than population` whenever population - pop0 > n_islands * pop0
    (here: pool of 1, draw of 2)."""
    part = kaffpaE(GRID, 4, 0.03, "fast", n_islands=1, population=3,
                   time_limit=0, seed=5, quickstart=True)
    assert is_feasible(GRID, part, 4, 0.03)


def test_kaffpaE_improves_over_single_run():
    single = kaffpa(GRID, 4, 0.03, "fast", seed=9)
    evo = kaffpaE(GRID, 4, 0.03, "fast", n_islands=2, population=2,
                  time_limit=4, seed=9)
    assert edge_cut(GRID, evo) <= edge_cut(GRID, single)


def test_production_mesh_shapes():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ, PYTHONPATH=SRC),
                       capture_output=True, text=True, timeout=300)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr


RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def test_dryrun_artifacts_wellformed():
    """Integration: the dry-run sweep's JSON records are complete + sane."""
    if not os.path.isdir(RESULTS) or not os.listdir(RESULTS):
        pytest.skip("dry-run sweep not executed yet")
    for fn in os.listdir(RESULTS):
        with open(os.path.join(RESULTS, fn)) as f:
            rec = json.load(f)
        if "skipped" in rec:
            continue
        assert rec["hlo_flops"] > 0, fn
        assert rec["memory_analysis"]["temp_bytes"] >= 0, fn
        if rec["kind"] == "train":
            # corrected HLO flops must be >= plain model flops per chip
            assert rec["hlo_flops"] * rec["n_chips"] >= rec["model_flops"], fn
