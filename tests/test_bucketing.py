"""Shape-bucketed batching invariants (DESIGN.md §12).

Padding must never change numerics: batch rows padded to the pow2 floor,
masked rounds padded to the rounds bucket, zero-capacity blocks padded to
the k bucket, and stacked sibling graphs in a wave all have to produce the
results of the unpadded, sequential calls bit-for-bit.  Compile-sharing is
pinned separately: a second call at an already-seen bucket signature must
trigger zero new backend compiles.
"""
import numpy as np
import pytest

from repro import obs
from repro.core import memetic as MEM
from repro.core import multilevel as ML
from repro.core import refine as R
from repro.core.csr import Graph, to_coo
from repro.core.hypergraph import refine_hypergraph
from repro.core.initial import random_partition
from repro.core.kaffpa import GraphMedium, PRESETS as GP
from repro.core.nodesep.refine import (boundary_to_separator,
                                       refine_separator,
                                       refine_separator_batch,
                                       refine_separator_multi)
from repro.core.ordering import reduced_nd
from repro.io.generators import grid2d, random_hypergraph

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# -- batch-floor / rounds-bucket identity ------------------------------------

def test_kway_batch_floor_row_identity():
    g = grid2d(9, 11)
    part = random_partition(g, 3, seed=4)
    a = R.refine_kway(g, part, 3, 0.05, rounds=6, seed=7, batch_floor=1)
    b = R.refine_kway(g, part, 3, 0.05, rounds=6, seed=7, batch_floor=8)
    assert np.array_equal(a, b)


def test_kway_rounds_bucket_masked_rounds_are_noops():
    g = grid2d(10, 10)
    part = random_partition(g, 2, seed=1)
    a = R.refine_kway(g, part, 2, 0.05, rounds=5, seed=3)
    b = R.refine_kway(g, part, 2, 0.05, rounds=5, seed=3, rounds_bucket=12)
    assert np.array_equal(a, b)


def test_kway_batch_identity_in_tournament():
    # identical per-row keys: the batch must reproduce each solo row
    # (vmap row independence — what makes every bucket merge numerics-safe)
    import jax
    g = grid2d(8, 13)
    parts = [random_partition(g, 4, seed=s) for s in range(3)]
    keys = np.asarray(jax.random.split(jax.random.PRNGKey(2), 1))
    solo = [R.refine_kway_batch(g, [p], 4, 0.05, rounds=5, seed=2,
                                keys=keys, batch_floor=4)[0]
            for p in parts]
    batch = R.refine_kway_batch(g, parts, 4, 0.05, rounds=5, seed=2,
                                keys=np.repeat(keys, 3, axis=0),
                                batch_floor=4)
    assert all(np.array_equal(a, b) for a, b in zip(solo, batch))


@pytest.mark.parametrize("objective", ["km1", "cut"])
def test_hyper_batch_floor_identity(objective):
    hg = random_hypergraph(60, 40, seed=5)
    for k in (2, 3):
        part = np.arange(hg.n, dtype=np.int64) % k
        a = refine_hypergraph(hg, part, k, 0.1, rounds=5, seed=9,
                              objective=objective, batch_floor=1)
        b = refine_hypergraph(hg, part, k, 0.1, rounds=5, seed=9,
                              objective=objective, batch_floor=8)
        assert np.array_equal(a, b)


def test_sep_batch_floor_identity():
    g = grid2d(8, 8)
    lab = boundary_to_separator(g, random_partition(g, 2, seed=3))
    a = refine_separator(g, lab, 0.2, rounds=6, seed=5, batch_floor=1)
    b = refine_separator(g, lab, 0.2, rounds=6, seed=5, batch_floor=4)
    assert np.array_equal(a, b)


# -- wave batching pins ------------------------------------------------------

def test_sep_multi_equals_per_graph_batch():
    g1, g2 = grid2d(8, 8), grid2d(8, 8)
    c1 = [boundary_to_separator(g1, random_partition(g1, 2, seed=t))
          for t in range(2)]
    c2 = [boundary_to_separator(g2, random_partition(g2, 2, seed=9 + t))
          for t in range(2)]
    seq1 = refine_separator_batch(g1, c1, 0.2, rounds=5, seed=11)
    seq2 = refine_separator_batch(g2, c2, 0.2, rounds=5, seed=22)
    multi = refine_separator_multi([g1, g2], [c1, c2], 0.2, rounds=5,
                                   seeds=[11, 22])
    assert all(np.array_equal(a, b) for a, b in zip(seq1, multi[0]))
    assert all(np.array_equal(a, b) for a, b in zip(seq2, multi[1]))


def test_nd_wave_equals_sequential():
    g = grid2d(13, 13)
    o_seq = reduced_nd(g, preset="fast", seed=2, batch_siblings=False)
    o_wave = reduced_nd(g, preset="fast", seed=2, batch_siblings=True)
    assert np.array_equal(o_seq, o_wave)


def test_memetic_batched_generations_equal_sequential():
    g = grid2d(12, 12)
    base = dict(n_islands=2, population=2, time_limit=0.0, generations=2)
    sb = MEM.evolve_islands(GraphMedium(g, GP["fast"]), 2, 0.05,
                            MEM.MemeticConfig(**base,
                                              batched_generations=True), 7)
    ss = MEM.evolve_islands(GraphMedium(g, GP["fast"]), 2, 0.05,
                            MEM.MemeticConfig(**base,
                                              batched_generations=False), 7)
    for pa, pb in zip(sb.islands, ss.islands):
        for a, b in zip(pa, pb):
            assert np.array_equal(a.part, b.part)
            assert a.fitness == b.fitness


# -- compile sharing ---------------------------------------------------------

def test_same_bucket_triggers_no_new_compile():
    # two different graphs landing in the same (n_pad, e_pad) bucket and
    # refined at the same (k, rounds, batch) signature must share one
    # compiled program: the second call adds ZERO backend compiles
    obs.install_jax_compile_listener()
    ga, gb = grid2d(9, 10), grid2d(10, 9)
    ca, cb = to_coo(ga), to_coo(gb)
    assert (ca.n_pad, ca.e_pad) == (cb.n_pad, cb.e_pad)
    pa = random_partition(ga, 2, seed=0)
    pb = random_partition(gb, 2, seed=1)
    R.refine_kway(ga, pa, 2, 0.05, rounds=4, seed=5, coo=ca, batch_floor=4)
    before = obs.metrics.get("jax/compiles")
    hits0 = obs.metrics.get("engine/compile_cache_hits")
    R.refine_kway(gb, pb, 2, 0.05, rounds=4, seed=6, coo=cb, batch_floor=4)
    assert obs.metrics.get("jax/compiles") == before
    assert obs.metrics.get("engine/compile_cache_hits") > hits0


def test_bucket_pad_counter_counts_padding_rows():
    g = grid2d(7, 9)
    part = random_partition(g, 2, seed=0)
    before = obs.metrics.get("engine/bucket_pads")
    R.refine_kway(g, part, 2, 0.05, rounds=4, seed=1, batch_floor=8)
    # a single candidate padded to the floor of 8 adds 7 padding rows
    assert obs.metrics.get("engine/bucket_pads") - before >= 7


def test_note_program_registry():
    sig = ("test", 123, 456, 2, 4, 8, False)
    hits0 = obs.metrics.get("engine/compile_cache_hits")
    progs0 = obs.metrics.get("engine/programs")
    ML.note_program(*sig)
    ML.note_program(*sig)
    assert obs.metrics.get("engine/programs") >= progs0 + 1
    assert obs.metrics.get("engine/compile_cache_hits") >= hits0 + 1


# -- hypothesis property: padding never changes objective/feasibility --------

if HAVE_HYPOTHESIS:
    @given(st.integers(5, 9), st.integers(5, 9), st.integers(2, 4),
           st.integers(0, 99), st.integers(1, 3))
    @settings(max_examples=15, deadline=None)
    def test_property_graph_padding_identity(rows, cols, k, seed, floor_exp):
        g = grid2d(rows, cols)
        part = random_partition(g, k, seed=seed)
        a = R.refine_kway(g, part, k, 0.1, rounds=4, seed=seed,
                          batch_floor=1)
        b = R.refine_kway(g, part, k, 0.1, rounds=4, seed=seed,
                          batch_floor=2 ** floor_exp, rounds_bucket=8)
        assert np.array_equal(a, b)

    @given(st.integers(20, 50), st.integers(10, 30), st.integers(2, 4),
           st.integers(0, 99),
           st.sampled_from(["km1", "cut"]))
    @settings(max_examples=10, deadline=None)
    def test_property_hyper_padding_identity(n, m, k, seed, objective):
        hg = random_hypergraph(n, m, seed=seed)
        part = np.arange(hg.n, dtype=np.int64) % k
        a = refine_hypergraph(hg, part, k, 0.15, rounds=4, seed=seed,
                              objective=objective, batch_floor=1)
        b = refine_hypergraph(hg, part, k, 0.15, rounds=4, seed=seed,
                              objective=objective, batch_floor=4)
        assert np.array_equal(a, b)

    @given(st.integers(6, 9), st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_property_sep_padding_identity(side, seed):
        g = grid2d(side, side)
        lab = boundary_to_separator(g, random_partition(g, 2, seed=seed))
        a = refine_separator(g, lab, 0.2, rounds=4, seed=seed,
                             batch_floor=1)
        b = refine_separator(g, lab, 0.2, rounds=4, seed=seed,
                             batch_floor=4)
        assert np.array_equal(a, b)
else:  # pragma: no cover - exercised only without hypothesis
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_padding_identity():
        pass
