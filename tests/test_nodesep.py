"""The multilevel node-separator subsystem (core/nodesep, DESIGN.md §8)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import multilevel as ML
from repro.core.csr import to_coo, to_ell
from repro.core.nodesep import (PRESETS, SEP, NodesepConfig, SeparatorMedium,
                                boundary_to_separator, flow_separator_polish,
                                multilevel_node_separator, nodesep_labels,
                                refine_separator, refine_separator_batch,
                                sep_affinity_coo, sep_affinity_ell,
                                separator_invariant_ok, separator_is_feasible,
                                separator_weight, split_labels,
                                vertex_cover_polish)
from repro.core.separator import node_separator, verify_separator
from repro.io.generators import barabasi_albert, grid2d, grid3d

GRID = grid2d(16, 16)
GRID3 = grid3d(6, 6, 6)
BA = barabasi_albert(400, 3, seed=7)


def _sep_part_of(labels):
    sep, part = split_labels(labels)
    return sep, part


# -- end-to-end driver --------------------------------------------------------

@pytest.mark.parametrize("g,name", [(GRID, "grid"), (GRID3, "grid3"),
                                    (BA, "ba")], ids=["grid", "grid3", "ba"])
def test_multilevel_separator_valid(g, name):
    sep, part = multilevel_node_separator(g, 0.2, "eco", seed=1)
    assert verify_separator(g, part, sep, 2)
    labels = part.copy()
    labels[sep] = SEP
    assert separator_invariant_ok(g, labels)
    assert separator_is_feasible(g, labels, 0.2)
    assert len(sep) > 0


def test_multilevel_not_worse_than_posthoc_grid():
    """The headline claim: direct multilevel optimization matches or beats
    the post-hoc construction at equal eps and seed."""
    for eps in (0.05, 0.2):
        sep_ml, _ = multilevel_node_separator(GRID, eps, "eco", seed=1)
        sep_ph, _ = node_separator(GRID, eps, "eco", seed=1)
        assert len(sep_ml) <= len(sep_ph)


def test_grid_separator_near_optimal():
    # a 16x16 grid has a 16-node column separator
    sep, _ = multilevel_node_separator(GRID, 0.2, "eco", seed=1)
    assert len(sep) <= 18


def test_interface_entry_uses_multilevel():
    from repro.core import interface as api
    n_sep, sep = api.node_separator(GRID.n, None, GRID.xadj, None,
                                    GRID.adjncy, 2, 0.2, seed=1,
                                    mode=api.ECO)
    assert n_sep == len(sep)
    assert 0 < n_sep <= 18
    # the baseline path is still reachable
    n_ph, sep_ph = api.node_separator(GRID.n, None, GRID.xadj, None,
                                      GRID.adjncy, 2, 0.2, seed=1,
                                      mode=api.ECO, multilevel=False)
    assert n_ph == len(sep_ph) > 0


# -- refinement invariants ----------------------------------------------------

def test_refine_separator_never_worsens_and_keeps_invariant():
    two = np.zeros(GRID.n, dtype=np.int64)
    two[GRID.n // 2:] = 1
    labels = boundary_to_separator(GRID, two)
    w0 = separator_weight(GRID, labels)
    out = refine_separator(GRID, labels, 0.2, rounds=10, seed=3)
    assert separator_weight(GRID, out) <= w0
    assert separator_invariant_ok(GRID, out)
    assert separator_is_feasible(GRID, out, 0.2)


def test_refine_separator_batch_matches_single_semantics():
    cands = []
    for s in range(3):
        two = np.zeros(BA.n, dtype=np.int64)
        rng = np.random.default_rng(s)
        two[rng.permutation(BA.n)[:BA.n // 2]] = 1
        cands.append(boundary_to_separator(BA, two))
    outs = refine_separator_batch(BA, cands, 0.2, rounds=8, seed=1)
    assert len(outs) == 3
    for c, o in zip(cands, outs):
        assert separator_weight(BA, o) <= separator_weight(BA, c)
        assert separator_invariant_ok(BA, o)


def test_boundary_to_separator_invariant():
    rng = np.random.default_rng(0)
    two = rng.integers(0, 2, BA.n)
    labels = boundary_to_separator(BA, two)
    assert separator_invariant_ok(BA, labels)


def test_force_balance_restores_feasibility():
    # valid 3-label state (column 1 separates column 0 from the rest) but
    # grossly unbalanced: block 0 holds 224 of 256 vertices
    col = np.arange(GRID.n) % 16
    labels = np.where(col == 0, 1, np.where(col == 1, SEP, 0)).astype(
        np.int64)
    assert separator_invariant_ok(GRID, labels)
    out = refine_separator(GRID, labels, 0.2, rounds=30, seed=2,
                           force_balance=True)
    assert separator_invariant_ok(GRID, out)
    assert separator_is_feasible(GRID, out, 0.2)


def test_vertex_cover_polish_never_worsens():
    two = np.zeros(GRID.n, dtype=np.int64)
    two[GRID.n // 2:] = 1
    labels = boundary_to_separator(GRID, two)
    out = vertex_cover_polish(GRID, labels, 0.2)
    assert separator_weight(GRID, out) <= separator_weight(GRID, labels)
    assert separator_invariant_ok(GRID, out)


def test_flow_polish_finds_thin_separator():
    # a dumbbell: two 5-cliques joined by a single path vertex — the optimal
    # separator is that one vertex; a boundary-derived separator is larger
    from repro.core.csr import Graph
    us, vs = [], []
    for i in range(5):
        for j in range(i + 1, 5):
            us.append(i); vs.append(j)              # clique A: 0..4
            us.append(5 + i); vs.append(5 + j)      # clique B: 5..9
    us.extend([0, 10]); vs.extend([10, 5])          # bridge vertex 10
    g = Graph.from_edges(11, us, vs)
    labels = np.zeros(11, dtype=np.int64)
    labels[5:10] = 1
    labels[10] = SEP
    labels[0] = SEP                                  # fat separator {0, 10}
    labels[5] = SEP                                  # …and {5}
    out = flow_separator_polish(g, labels, eps=0.3)
    assert separator_invariant_ok(g, out)
    assert separator_weight(g, out) == 1             # just the bridge
    assert verify_separator(g, split_labels(out)[1], split_labels(out)[0], 2)


# -- engine integration -------------------------------------------------------

def test_vcycle_non_worsening_separator():
    medium = SeparatorMedium(GRID3, PRESETS["eco"])
    labels = ML.multilevel(medium, 2, 0.2, seed=2)
    w = medium.objective(labels)
    for cyc in range(2):
        labels = ML.vcycle(medium, labels, 2, 0.2, seed=11 + cyc)
        w2 = medium.objective(labels)
        assert w2 <= w
        assert medium.is_feasible(labels, 2, 0.2)
        assert separator_invariant_ok(GRID3, labels)
        w = w2


def test_view_builds_O_levels_separator_medium():
    medium = SeparatorMedium(grid2d(24, 24), PRESETS["eco"])
    levels = ML.build_hierarchy(medium, 2, seed=0)
    before = ML.view_build_count()
    part_c = ML.initial_partition(levels[-1], 2, 0.2, seed=0)
    ML.uncoarsen(levels, part_c, 2, 0.2, seed=0)
    assert ML.view_build_count() - before <= len(levels)


def test_protected_coarsening_keeps_labels_representable():
    """Signature splitting must keep the 3-label state exact at every coarse
    level: in particular no cluster ever mixes A with B."""
    g = grid2d(24, 24)
    medium = SeparatorMedium(g, PRESETS["fast"])
    labels = ML.multilevel(medium, 2, 0.2, seed=1)
    levels = ML.build_hierarchy(medium, 2, seed=5, protect=[labels])
    for lvl in levels[1:]:
        assert lvl.protect is not None
        coarse_g = lvl.medium.g
        assert separator_invariant_ok(coarse_g, lvl.protect[0])
    # projected objective is exact: coarse separator weight == fine weight
    w_fine = separator_weight(g, labels)
    w_coarse = separator_weight(levels[-1].medium.g, levels[-1].protect[0])
    assert w_fine == w_coarse


def test_time_limit_restarts_only_improve():
    base = nodesep_labels(GRID3, 0.2, "fast", seed=4)
    more = nodesep_labels(GRID3, 0.2, "fast", seed=4, time_limit=1.0)
    assert separator_weight(GRID3, more) <= separator_weight(GRID3, base)
    assert separator_invariant_ok(GRID3, more)


# -- kernel path --------------------------------------------------------------

def test_sep_affinity_kernel_bit_exact_vs_oracle():
    """The Pallas separator-gain path (interpret mode off-TPU) must be
    bit-exact vs the COO scatter oracle: integer-valued f32 sums."""
    g = grid2d(12, 12)
    coo = to_coo(g)
    ell = to_ell(g, row_tile=coo.n_pad)
    rng = np.random.default_rng(3)
    lab = np.zeros(coo.n_pad, dtype=np.int32)
    lab[:g.n] = rng.integers(0, 3, g.n)
    lab = jnp.asarray(lab)
    a = np.asarray(sep_affinity_ell(ell, lab, use_pallas=True))
    b = np.asarray(sep_affinity_coo(coo, lab))
    assert np.array_equal(a, b)


def test_sep_refinement_kernel_matches_scatter_path():
    """End-to-end: kernel-path separator refinement is bit-identical to the
    COO fallback (same RNG stream)."""
    two = np.zeros(GRID.n, dtype=np.int64)
    two[GRID.n // 2:] = 1
    labels = boundary_to_separator(GRID, two)
    a = refine_separator(GRID, labels, 0.2, rounds=6, seed=2,
                         use_kernel=False)
    b = refine_separator(GRID, labels, 0.2, rounds=6, seed=2,
                         use_kernel=True)
    assert np.array_equal(a, b)


# -- IO round trip ------------------------------------------------------------

def test_separator_io_roundtrip(tmp_path):
    from repro.io import metis
    sep, part = multilevel_node_separator(GRID, 0.2, "fast", seed=1)
    p = str(tmp_path / "sep.txt")
    metis.write_separator(part, sep, 2, p)
    part2, sep2 = metis.read_separator(p, k=2)
    assert np.array_equal(np.sort(sep), np.sort(sep2))
    non_sep = np.setdiff1d(np.arange(GRID.n), sep)
    assert np.array_equal(part[non_sep], part2[non_sep])
    # labels above k are a format error (this file has separator label 2)
    from repro.core.csr import GraphFormatError
    with pytest.raises(GraphFormatError):
        metis.read_separator(p, k=1)
    # an empty separator round-trips exactly (k is explicit, not inferred)
    metis.write_separator(part, np.zeros(0, dtype=np.int64), 2, p)
    part3, sep3 = metis.read_separator(p, k=2)
    assert len(sep3) == 0 and np.array_equal(part, part3)


def test_verify_separator_rejects_non_disconnecting_sets():
    # path 0-1-2-3-4: S={1} with blocks {0}=A, {2,3,4}=B is valid;
    # S={3} with the same labels leaves an A-B edge AND a mixed component
    from repro.core.csr import Graph
    g = Graph.from_edges(5, [0, 1, 2, 3], [1, 2, 3, 4])
    part = np.array([0, 0, 1, 1, 1])
    assert verify_separator(g, part, np.array([1]), 2)
    assert not verify_separator(g, part, np.array([3]), 2)
    # mixed component without a direct A-B edge is impossible, but the
    # component sweep also guards label bookkeeping: empty separator on a
    # connected graph with two blocks must fail
    assert not verify_separator(g, part, np.zeros(0, dtype=np.int64), 2)
