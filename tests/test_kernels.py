"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("n_pad,dmax,k", [
    (128, 8, 2), (256, 24, 5), (128, 16, 130), (384, 40, 17)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_lp_affinity_sweep(n_pad, dmax, k, dtype):
    rng = np.random.default_rng(n_pad + dmax + k)
    nbr = rng.integers(0, n_pad, (n_pad, dmax)).astype(np.int32)
    wgt = (rng.random((n_pad, dmax)) *
           (rng.random((n_pad, dmax)) > 0.3)).astype(dtype)
    labels = rng.integers(0, k, (n_pad,)).astype(np.int32)
    got = ops.lp_affinity(jnp.asarray(nbr), jnp.asarray(wgt),
                          jnp.asarray(labels), k)
    want = ref.affinity_ref(jnp.asarray(labels)[jnp.asarray(nbr)],
                            jnp.asarray(wgt), k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bh,l,p,n,chunk", [
    (2, 128, 8, 4, 64), (3, 256, 16, 8, 128), (1, 64, 32, 16, 32),
    (2, 200, 8, 8, 64)])  # l not divisible by chunk → padding path
def test_ssd_scan_sweep(bh, l, p, n, chunk):
    rng = np.random.default_rng(bh * l + p)
    x = rng.standard_normal((bh, l, p)).astype(np.float32)
    ld = (-0.05 - 0.5 * rng.random((bh, l))).astype(np.float32)
    b = (rng.standard_normal((bh, l, n)) * 0.3).astype(np.float32)
    c = (rng.standard_normal((bh, l, n)) * 0.3).astype(np.float32)
    got = ops.ssd_scan(jnp.asarray(x), jnp.asarray(ld), jnp.asarray(b),
                       jnp.asarray(c), chunk=chunk)
    want = ref.ssd_scan_ref(jnp.asarray(x), jnp.asarray(ld), jnp.asarray(b),
                            jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_ssd_chunked_jnp_matches_ref():
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 192, 16)), jnp.float32)
    ld = jnp.asarray(-0.1 - 0.4 * rng.random((4, 192)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((4, 192, 8)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((4, 192, 8)) * 0.3, jnp.float32)
    got = ssd_chunked(x, ld, b, c, chunk=64)
    want = ref.ssd_scan_ref(x, ld, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_kernel_integrated_refinement_matches_jnp():
    """The Pallas affinity kernel plugged into k-way refinement must be
    bit-identical to the COO scatter path (same RNG stream)."""
    from repro.io.generators import grid2d
    from repro.core.refine import refine_kway
    from repro.core.initial import random_partition
    from repro.core.partition import edge_cut
    g = grid2d(12, 12)
    p0 = random_partition(g, 3, seed=0)
    a = refine_kway(g, p0, 3, rounds=5, seed=2, use_kernel=False)
    b = refine_kway(g, p0, 3, rounds=5, seed=2, use_kernel=True)
    assert edge_cut(g, a) == edge_cut(g, b)


def test_online_attention_matches_dense():
    from repro.models.attention import _sdpa, _sdpa_online
    from repro.models.layers import causal_mask
    rng = np.random.default_rng(1)
    b, sq, h, hd, kvh = 2, 96, 4, 16, 2
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, kvh, hd)), jnp.float32)
    dense = _sdpa(q, k, v, causal_mask(sq, sq), None, 0.25)
    online = _sdpa_online(q, k, v, None, 0.25, q_offset=0, window=None,
                          is_causal=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(online),
                               rtol=2e-4, atol=2e-4)
    # with window + softcap
    dense_w = _sdpa(q, k, v, causal_mask(sq, sq, window=24), 30.0, 0.25)
    online_w = _sdpa_online(q, k, v, 30.0, 0.25, q_offset=0, window=24,
                            is_causal=True)
    np.testing.assert_allclose(np.asarray(dense_w), np.asarray(online_w),
                               rtol=2e-4, atol=2e-4)
