"""repro.obs (DESIGN.md §11): span nesting + JSONL round-trip, Chrome
trace export, counter registry (incl. the view_build_count aliases and
jax compile counts), the zero-cost disabled path, and the engine's quality
trajectories."""
import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import multilevel as ML
from repro.core.kaffpa import GraphMedium, PRESETS, kaffpa
from repro.core.partition import edge_cut
from repro.io.generators import grid2d

GRID16 = grid2d(16, 16)
GRID24 = grid2d(24, 24)


# -- spans + journal ----------------------------------------------------------

def test_span_nesting_and_jsonl_roundtrip(tmp_path):
    rec = obs.Recorder("t", compile_counters=False)
    with rec.span("outer", level=0):
        assert rec.span_path() == "outer"
        with rec.span("inner", level=1):
            assert rec.span_path() == "outer/inner"
            rec.count("t/hits", 2)
        rec.point("quality", cycle=0, objective=10.0)
    assert rec.span_path() == ""
    b = [e for e in rec.events if e["ph"] == "B"]
    e = [e for e in rec.events if e["ph"] == "E"]
    assert [ev["name"] for ev in b] == ["outer", "inner"]
    assert [ev["name"] for ev in e] == ["inner", "outer"]
    assert [ev["depth"] for ev in b] == [0, 1]
    # timestamps are wall-anchored microseconds, monotone within a thread
    ts = [ev["ts"] for ev in rec.events]
    assert ts == sorted(ts)
    assert abs(ts[0] / 1e6 - time.time()) < 60

    path = tmp_path / "journal.jsonl"
    n = obs.write_jsonl(rec, str(path))
    assert n == 1 + len(rec.events)
    headers, events = obs.read_jsonl(str(path))
    assert len(headers) == 1 and headers[0]["name"] == "t"
    assert headers[0]["counters"]["t/hits"] == 2
    assert headers[0]["trajectories"]["quality"] == [
        {"cycle": 0, "objective": 10.0}]
    assert [ev["ph"] for ev in events] == [ev["ph"] for ev in rec.events]


def test_span_exception_still_closes(tmp_path):
    rec = obs.Recorder("t", compile_counters=False)
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("x")
    assert rec.span_path() == ""
    phs = [e["ph"] for e in rec.events]
    assert phs == ["B", "E"]


def test_chrome_trace_valid_and_balanced(tmp_path):
    rec = obs.Recorder("cell", compile_counters=False)
    with rec.span("a"):
        with rec.span("b", n=7):
            rec.count("k/rounds", 3)
        rec.point("quality", objective=5.0, note="text-dropped")
        rec.gauge("k/depth", 2)
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(rec, str(path))
    doc = json.loads(path.read_text())          # valid JSON by construction
    tes = doc["traceEvents"]
    assert isinstance(tes, list) and tes
    b = [t for t in tes if t["ph"] == "B"]
    e = [t for t in tes if t["ph"] == "E"]
    assert len(b) == len(e) == 2
    for t in tes:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(t)
    # counter events carry cumulated values / numeric trajectory fields
    c = {t["name"]: t["args"] for t in tes if t["ph"] == "C"}
    assert c["k/rounds"] == {"value": 3}
    assert c["quality"] == {"objective": 5.0}   # non-numeric fields dropped
    assert c["k/depth"] == {"value": 2}


# -- counter registry ---------------------------------------------------------

def test_registry_thread_safe_increments():
    reg = obs.CounterRegistry()

    def work():
        for _ in range(1000):
            reg.inc("x")

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.get("x") == 8000


def test_view_build_count_rides_registry():
    before_alias = ML.view_build_count()
    before_reg = obs.metrics.get("engine/view_builds")
    assert before_alias == int(before_reg)
    medium = GraphMedium(GRID16, PRESETS["fast"])
    medium.views                      # first access builds the device views
    assert ML.view_build_count() == before_alias + 1
    assert obs.metrics.get("engine/view_builds") == before_reg + 1


def test_compile_count_on_fresh_shape():
    import jax
    import jax.numpy as jnp
    rec = obs.Recorder("compile")

    @jax.jit
    def f(x):
        return x * 3 + 1

    f(jnp.ones((13, 5))).block_until_ready()    # shape unseen by the cache
    assert rec.compile_count >= 1
    assert rec.counters().get("jax/compile_secs", 0) > 0


# -- disabled path ------------------------------------------------------------

def test_null_recorder_is_free():
    assert obs.current() is obs.NULL
    assert obs.NULL.enabled is False
    s1 = obs.NULL.span("a", big=list(range(10)))
    s2 = obs.NULL.span("b")
    assert s1 is s2                   # one shared span object, no allocation
    with s1:
        obs.NULL.count("x")
        obs.NULL.point("q", objective=1.0)
        obs.NULL.gauge("g", 2.0)


def test_use_none_is_passthrough():
    rec = obs.Recorder("ambient", compile_counters=False)
    with obs.use(rec):
        assert obs.current() is rec
        with obs.use(None):           # report=None must not clobber
            assert obs.current() is rec
    assert obs.current() is obs.NULL


def test_kaffpa_identical_with_and_without_recorder():
    p0 = kaffpa(GRID24, 4, 0.03, "fast", seed=2)
    rec = obs.Recorder("kaffpa")
    p1 = kaffpa(GRID24, 4, 0.03, "fast", seed=2, report=rec)
    assert np.array_equal(p0, p1)
    names = {e["name"] for e in rec.events if e["ph"] == "B"}
    assert {"run", "multilevel", "hierarchy", "coarsen", "uncoarsen",
            "refine"} <= names
    assert rec.counters().get("refine/rounds", 0) > 0


def test_disabled_recorder_overhead_within_noise():
    """The kaffpa fast cell with obs disabled stays within noise of itself
    (generous 1.5x bound: same call, warm caches, interleaved timing)."""
    kaffpa(GRID16, 2, 0.03, "fast", seed=3)     # warm the jit caches
    times = {"plain": [], "null_ctx": []}
    for _ in range(3):
        t0 = time.perf_counter()
        kaffpa(GRID16, 2, 0.03, "fast", seed=3)
        times["plain"].append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        with obs.use(None):
            kaffpa(GRID16, 2, 0.03, "fast", seed=3)
        times["null_ctx"].append(time.perf_counter() - t0)
    assert min(times["null_ctx"]) <= 1.5 * min(times["plain"]) + 0.05


# -- quality trajectories -----------------------------------------------------

def test_vcycle_trajectory_non_increasing():
    rec = obs.Recorder("vcycles", compile_counters=False)
    medium = GraphMedium(GRID24, PRESETS["eco"], recorder=rec)
    part = ML.run(medium, 4, 0.03, seed=1, vcycles=3)
    traj = rec.trajectory("cycles")
    assert len(traj) == 3             # cycle 0 = initial, then 2 V-cycles
    assert all(b <= a for a, b in zip(traj, traj[1:]))
    assert traj[-1] == edge_cut(GRID24, part)
    cycles = rec.trajectories["cycles"]
    assert [p["cycle"] for p in cycles] == [0, 1, 2]
    assert all("imbalance" in p for p in cycles)


def test_interface_report_kwarg():
    from repro.core import interface
    g = GRID16
    rec = obs.Recorder("iface")
    cut, part = interface.kaffpa(g.n, None, g.xadj, None, g.adjncy, 2,
                                 0.03, seed=1, mode=interface.FAST,
                                 report=rec)
    assert cut == edge_cut(g, part)
    assert any(e["name"] == "run" for e in rec.events)
    assert rec.trajectory("cycles")


# -- crash-safe journals and counter/track export (serve telemetry PR) ------

def test_read_jsonl_tolerates_truncated_tail(tmp_path):
    rec = obs.Recorder("crash")
    with rec.span("a"):
        rec.count("k", 1)
    p = str(tmp_path / "j.jsonl")
    obs.write_jsonl(rec, p)
    whole_headers, whole_events = obs.read_jsonl(p)
    raw = open(p, "rb").read()
    # chop mid-way through the final line (a crashed writer's torn record)
    open(p, "wb").write(raw[:-7])
    headers, events = obs.read_jsonl(p)
    assert headers == whole_headers
    assert events == whole_events[:-1]
    # corruption in the *middle* is a real error, not silently skipped
    lines = raw.decode().strip().split("\n")
    lines[1] = lines[1][:-5]
    open(p, "w").write("\n".join(lines) + "\n")
    with pytest.raises(json.JSONDecodeError):
        obs.read_jsonl(p)


def test_chrome_trace_counters_points_and_gauges(tmp_path):
    rec = obs.Recorder("ct")
    with rec.span("work"):
        rec.count("widgets", 3)
        rec.gauge("temp", 7.5)
        rec.point("cycles", cycle=0, objective=42.0)
    trace = obs.chrome_trace([rec], registry_gauges=True)["traceEvents"]
    cs = [e for e in trace if e["ph"] == "C"]
    assert any(e["name"] == "widgets" and e["args"] == {"value": 3}
               for e in cs)
    assert any(e["name"] == "temp" for e in cs)
    # point() trajectories become multi-series counter tracks
    assert any(e["name"] == "cycles" and e["args"].get("objective") == 42.0
               for e in cs)
    # registry gauges appended as a final snapshot
    assert any(e.get("cat") == "registry" for e in cs)
    # without the flag, no registry snapshot rides along
    plain = obs.chrome_trace([rec])["traceEvents"]
    assert not any(e.get("cat") == "registry" for e in plain)


def test_chrome_trace_named_tracks_and_instants():
    rec = obs.Recorder("tracks")
    rec.begin("req 0", track="slot 0", rid=0)
    rec.instant("tok", track="slot 0", token=5)
    rec.end("req 0", track="slot 0")
    rec.instant("enqueue", track="queue", rid=1)
    trace = obs.chrome_trace([rec])["traceEvents"]
    meta = {e["args"]["name"]: e["tid"] for e in trace
            if e.get("name") == "thread_name"}
    assert {"slot 0", "queue"} <= set(meta)
    assert meta["slot 0"] != meta["queue"]
    span = [e for e in trace if e.get("name") == "req 0"]
    assert [e["ph"] for e in span] == ["B", "E"]
    assert all(e["tid"] == meta["slot 0"] for e in span)
    inst = [e for e in trace if e["ph"] == "i"]
    assert {e["name"] for e in inst} == {"tok", "enqueue"}
    # NullRecorder accepts the same surface
    obs.NULL.begin("x", track="t")
    obs.NULL.instant("x", track="t")
    obs.NULL.end("x", track="t")
