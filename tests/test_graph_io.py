"""Graph containers, Metis/binary IO, graphchecker, generators."""
import numpy as np
import pytest

from repro.core.csr import Graph, GraphFormatError, to_coo, to_ell
from repro.io import binio, metis
from repro.io.generators import (barabasi_albert, erdos_renyi, grid2d,
                                 grid3d, random_geometric, rmat,
                                 watts_strogatz, weighted_grid)


def test_from_edges_dedup_and_symmetry():
    g = Graph.from_edges(4, [0, 1, 0, 0], [1, 0, 2, 2], [1, 2, 5, 7])
    assert g.n == 4
    # (0,1) merged weight 3, (0,2) merged weight 12
    assert g.m == 2
    assert g.check() == []
    assert g.total_ewgt() == 15


def test_graphchecker_catches_errors():
    g = Graph(np.array([0, 1, 2]), np.array([1, 0]), np.ones(2), np.ones(2))
    assert g.check() == []
    # asymmetric weights
    bad = Graph(np.array([0, 1, 2]), np.array([1, 0]), np.ones(2),
                np.array([1, 2]))
    assert "differ" in ";".join(bad.check(raise_on_error=False))
    with pytest.raises(GraphFormatError):
        bad.check()
    # self loop
    loop = Graph(np.array([0, 1]), np.array([0]), np.ones(1), np.ones(1))
    assert any("self" in e for e in loop.check(raise_on_error=False))


@pytest.mark.parametrize("gen", [
    lambda: grid2d(8, 8), lambda: grid3d(4, 4, 4),
    lambda: rmat(8, 4, seed=1), lambda: barabasi_albert(300, 3, seed=1),
    lambda: watts_strogatz(200, 6, 0.1, seed=1),
    lambda: erdos_renyi(200, 6.0, seed=1),
    lambda: random_geometric(300, seed=1), lambda: weighted_grid(8, 8)])
def test_generators_valid(gen):
    g = gen()
    assert g.check() == []
    assert g.n > 0 and g.m > 0


def test_metis_roundtrip(tmp_path):
    g = weighted_grid(7, 9, seed=3)
    p = str(tmp_path / "g.graph")
    metis.write_metis(g, p)
    g2 = metis.read_metis(p)
    assert np.array_equal(g.xadj, g2.xadj)
    assert np.array_equal(g.adjncy, g2.adjncy)
    assert np.array_equal(g.adjwgt, g2.adjwgt)
    assert metis.graphchecker(p) == []


def test_metis_rejects_bad_file(tmp_path):
    p = str(tmp_path / "bad.graph")
    with open(p, "w") as f:
        f.write("2 1\n2\n")        # vertex 2 lists nothing: m mismatch
    assert metis.graphchecker(p) != []


def test_binary_roundtrip(tmp_path):
    g = grid2d(6, 6)
    p = str(tmp_path / "g.bin")
    binio.write_binary(g, p)
    g2 = binio.read_binary(p)
    assert np.array_equal(g.adjncy, g2.adjncy)
    assert np.array_equal(g.xadj, g2.xadj)


def test_graph2binary_external_matches(tmp_path):
    g = grid2d(5, 8)
    mp, bp1, bp2 = (str(tmp_path / n) for n in ("m.graph", "a.bin", "b.bin"))
    metis.write_metis(g, mp)
    binio.graph2binary(mp, bp1)
    binio.graph2binary_external(mp, bp2)
    with open(bp1, "rb") as a, open(bp2, "rb") as b:
        assert a.read() == b.read()


def test_partition_file_roundtrip(tmp_path):
    part = np.array([0, 1, 2, 1, 0])
    p = str(tmp_path / "part")
    metis.write_partition(part, p)
    assert np.array_equal(metis.read_partition(p), part)
    binio.write_partition_binary(part, p + ".bin")
    assert np.array_equal(binio.read_partition_binary(p + ".bin"), part)


def test_device_views():
    g = weighted_grid(6, 6, seed=1)
    ell = to_ell(g)
    coo = to_coo(g)
    assert ell.n_pad % 128 == 0
    assert coo.e_pad % 256 == 0
    # padding carries zero weight
    assert float(coo.w.sum()) == float(g.adjwgt.sum())
    assert float(ell.wgt.sum()) == float(g.adjwgt.sum())


def test_separator_output_format(tmp_path):
    part = np.array([0, 1, 0, 1])
    metis.write_separator(part, np.array([2]), 2, str(tmp_path / "sep"))
    out = np.loadtxt(str(tmp_path / "sep"), dtype=int)
    assert out[2] == 2 and out[0] == 0
