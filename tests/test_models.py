"""Per-arch reduced smoke tests (deliverable f) + decode consistency."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, cell_is_applicable, get_config
from repro.models import transformer as T


def _batch_kwargs(cfg, B):
    kw = {}
    if cfg.n_prefix_embeds:
        kw["prefix_embeds"] = jnp.full(
            (B, cfg.n_prefix_embeds, cfg.d_model), 0.01, jnp.float32)
    if cfg.enc_layers:
        kw["enc_frames"] = jnp.full(
            (B, cfg.enc_positions, cfg.d_model), 0.01, jnp.float32)
    return kw


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    """Reduced config: one forward + one train step, shapes + no NaNs."""
    from repro.train.train_step import make_train_step, init_opt_state
    from repro.train.optimizer import OptConfig
    cfg = get_config(arch_id).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = _batch_kwargs(cfg, B)
    logits, _ = T.forward(params, cfg, tokens, **kw)
    assert logits.shape == (B, S + cfg.n_prefix_embeds, cfg.vocab_pad)
    assert not np.any(np.isnan(np.asarray(logits)))
    # one train step
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab)}
    batch.update(_batch_kwargs(cfg, B))
    step = make_train_step(cfg, OptConfig(peak_lr=1e-3), remat="full")
    p2, opt2, metrics = jax.jit(step)(params, init_opt_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch_id", ["minicpm_2b", "gemma2_9b",
                                     "zamba2_2p7b", "rwkv6_7b",
                                     "starcoder2_15b", "whisper_medium"])
def test_decode_matches_full_forward(arch_id):
    cfg = get_config(arch_id).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = _batch_kwargs(cfg, B)
    full_logits, _ = T.forward(params, cfg, tokens, **kw)
    if cfg.n_prefix_embeds:
        full_logits = full_logits[:, cfg.n_prefix_embeds:]
    caches = T.init_caches(cfg, B, S)
    outs = []
    for t in range(S):
        step_kw = {}
        if cfg.enc_layers and t == 0:
            step_kw["enc_frames"] = kw["enc_frames"]    # prefill step 0
        lg, caches = T.forward(params, cfg, tokens[:, t:t + 1],
                               caches=caches, cache_pos=t, **step_kw)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    rel = float(jnp.abs(full_logits - inc).max()) / \
        float(jnp.abs(full_logits).max())
    assert rel < 2e-3, (arch_id, rel)


def test_moe_mismatch_is_capacity_drops_only():
    cfg = dataclasses.replace(get_config("deepseek_v2_236b").reduced(),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = T.forward(params, cfg, tokens)
    caches = T.init_caches(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = T.forward(params, cfg, tokens[:, t:t + 1],
                               caches=caches, cache_pos=t)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    assert float(jnp.abs(full_logits - inc).max()) < 1e-4


def test_moe_expert_placement_roundtrip():
    from repro.models.moe import (coactivation_graph, expert_placement,
                                  place_experts, init_moe, moe_ffn)
    cfg = get_config("llama4_scout_17b_a16e").reduced()
    rng = np.random.default_rng(0)
    gate_idx = rng.integers(0, cfg.n_experts, (500, 2))
    perm = expert_placement(gate_idx, cfg.n_experts, 4, seed=1)
    assert sorted(perm.tolist()) == list(range(cfg.n_experts))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    placed = place_experts(params, perm)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model)) * 0.1
    y0 = moe_ffn(params, x, cfg)
    y1 = moe_ffn(placed, x, cfg)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_long_500k_applicability_rules():
    runs = {a: cell_is_applicable(get_config(a), "long_500k")[0]
            for a in ARCH_IDS}
    assert runs["zamba2_2p7b"] and runs["rwkv6_7b"]
    for a in ("mistral_large_123b", "gemma2_9b", "deepseek_v2_236b",
              "whisper_medium", "internvl2_26b", "starcoder2_15b",
              "minicpm_2b", "llama4_scout_17b_a16e"):
        assert not runs[a], a


def test_param_counts_sane():
    # published totals (rough): zamba2 ~2.7B, mistral ~123B, deepseek ~236B
    for aid, lo, hi in [("zamba2_2p7b", 1.5e9, 4e9),
                        ("mistral_large_123b", 1.0e11, 1.4e11),
                        ("deepseek_v2_236b", 1.8e11, 2.8e11),
                        ("minicpm_2b", 2e9, 3.6e9),
                        ("rwkv6_7b", 5e9, 9e9)]:
        n = get_config(aid).param_count()
        assert lo < n < hi, (aid, n)
    # MoE active << total
    ds = get_config("deepseek_v2_236b")
    assert ds.active_param_count() < 0.25 * ds.param_count()
