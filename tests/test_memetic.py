"""The memetic engine (core/memetic): deterministic tie-breaking,
entry-point validation, migration topology (independence without
migration, collective ring with it), mesh-vs-host bit-exactness, and the
kahyparE / kabapeE / memetic-separator fronts."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
from jax.sharding import Mesh

from repro.core import interface
from repro.core.evolve import kaffpaE
from repro.core.kabape import kabapeE
from repro.core.kaffpa import PRESETS as GPRESETS, GraphMedium, kaffpa
from repro.core.hypergraph import (connectivity, cut_net, kahypar, kahyparE)
from repro.core.hypergraph import metrics as HM
from repro.core.memetic import (Individual, MemeticConfig, best_index,
                                evolve_islands, island_seed, ring_roll,
                                ring_roll_host, validate_memetic_params,
                                worst_index)
from repro.core.nodesep import (SEP, memetic_node_separator,
                                multilevel_node_separator,
                                separator_invariant_ok,
                                separator_is_feasible)
from repro.core.partition import edge_cut, is_feasible
from repro.io.generators import grid2d, planted_hypergraph

GRID = grid2d(10, 10)
HG = planted_hypergraph(150, 220, blocks=4, seed=7)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh1() -> Mesh:
    return Mesh(np.array(jax.devices()[:1]), ("islands",))


# -- deterministic tie-breaking (satellite bugfix) ---------------------------

def test_fitness_tiebreak_independent_of_insertion_order():
    """Equal-fitness individuals must rank by balance then stamp, not by
    population insertion order (the old loop's min/max over fitness alone
    made trajectories irreproducible)."""
    a = Individual(np.zeros(4, np.int64), 10.0, balance=1.02, stamp=7)
    b = Individual(np.ones(4, np.int64), 10.0, balance=1.00, stamp=9)
    c = Individual(np.full(4, 2, np.int64), 10.0, balance=1.02, stamp=3)
    for pop in ([a, b, c], [c, b, a], [b, c, a], [c, a, b]):
        assert pop[best_index(pop)] is b       # balance breaks the tie
        assert pop[worst_index(pop)] is a      # stamp breaks balance ties

    def run(order):
        pop = list(order)
        w = worst_index(pop)
        child = Individual(np.zeros(4, np.int64), 10.0, 1.01, stamp=5)
        if child.key() <= pop[w].key():
            pop[w] = child
        return {i.stamp for i in pop}

    assert run([a, b, c]) == run([c, b, a]) == {3, 5, 9}


def test_population_trajectory_reproducible():
    """Two identical generations-mode runs produce identical partitions."""
    kw = dict(n_islands=2, population=2, generations=2, seed=13)
    p1 = kaffpaE(GRID, 4, 0.03, "fast", **kw)
    p2 = kaffpaE(GRID, 4, 0.03, "fast", **kw)
    assert np.array_equal(p1, p2)


# -- entry-point validation (satellite bugfix) --------------------------------

@pytest.mark.parametrize("kw", [
    dict(n_islands=0), dict(n_islands=-2), dict(population=0),
    dict(time_limit=-1.0), dict(time_limit=float("nan")),
    dict(generations=-1),
])
def test_validate_memetic_params_rejects(kw):
    base = dict(n_islands=2, population=2, time_limit=1.0, generations=None)
    base.update(kw)
    with pytest.raises(ValueError):
        validate_memetic_params(**base)


def test_entry_points_validate_before_work():
    with pytest.raises(ValueError):
        kaffpaE(GRID, 4, 0.03, "fast", n_islands=0, time_limit=1.0)
    with pytest.raises(ValueError):
        kaffpaE(GRID, 4, 0.03, "fast", time_limit=-2.0)
    with pytest.raises(ValueError):
        kabapeE(GRID, 4, 0.03, "fast", population=0, time_limit=1.0)
    with pytest.raises(ValueError):
        kahyparE(HG, 4, 0.03, "fast", n_islands=-1)
    with pytest.raises(ValueError):
        interface.kaffpaE(GRID.n, None, GRID.xadj, None, GRID.adjncy, 4,
                          0.03, time_limit=-1.0)
    with pytest.raises(ValueError):
        interface.kahyparE(HG.n, HG.m, None, None, HG.eptr, HG.eind, 4,
                           0.03, n_islands=0)
    with pytest.raises(ValueError):
        memetic_node_separator(GRID, 0.2, "fast", population=-1)


def test_config_only_knobs_validated():
    medium = GraphMedium(GRID, GPRESETS["fast"])
    with pytest.raises(ValueError):
        evolve_islands(medium, 4, 0.03,
                       MemeticConfig(n_islands=2, population=2,
                                     generations=1, migration_interval=0),
                       seed=1)
    with pytest.raises(ValueError):
        evolve_islands(medium, 4, 0.03,
                       MemeticConfig(n_islands=2, population=2,
                                     generations=1, combine_prob=1.5),
                       seed=1)
    with pytest.raises(ValueError):
        evolve_islands(medium, 4, 0.03,
                       MemeticConfig(n_islands=1, population=1,
                                     generations=0, replacement="nope"),
                       seed=1)


def test_infeasible_child_never_evicts_feasible_member():
    """Replacement ranks feasibility first: an infeasible child with a
    better objective must not displace a feasible incumbent (otherwise the
    never-worse-than-single-run guarantee breaks)."""
    from repro.core.memetic.driver import _replace_key
    feas = Individual(np.zeros(4, np.int64), 100.0, 1.0, 1, feasible=True)
    bad = Individual(np.ones(4, np.int64), 50.0, 1.5, 2, feasible=False)
    for rule in ("worst", "balanced"):
        rkey = _replace_key(MemeticConfig(replacement=rule))
        pop = [feas]
        w = max(range(len(pop)), key=lambda j: rkey(pop[j]))
        assert not rkey(bad) <= rkey(pop[w]), rule
        # ...but a feasible child still displaces the infeasible one
        assert rkey(feas) <= rkey(bad), rule


def test_time_limit_zero_still_valid():
    """Paper semantics preserved: time_limit == 0 → initial population
    only, not a ValueError."""
    part = kaffpaE(GRID, 4, 0.03, "fast", n_islands=1, population=2,
                   time_limit=0, seed=5)
    assert is_feasible(GRID, part, 4, 0.03)


# -- migration topology (satellite tests) -------------------------------------

def test_no_migration_islands_evolve_independently():
    """With migration off, island i's trajectory is bit-identical to a solo
    run at island_seed(seed, i) — the per-island RNG-stream contract."""
    seed = 11
    medium = GraphMedium(GRID, GPRESETS["fast"])
    multi = evolve_islands(
        medium, 4, 0.03,
        MemeticConfig(n_islands=3, population=2, generations=2,
                      migrate=False), seed)
    for i in range(3):
        solo = evolve_islands(
            GraphMedium(GRID, GPRESETS["fast"]), 4, 0.03,
            MemeticConfig(n_islands=1, population=2, generations=2,
                          migrate=False), island_seed(seed, i))
        got, want = multi.islands[i], solo.islands[0]
        assert len(got) == len(want)
        for x, y in zip(got, want):
            assert np.array_equal(x.part, y.part)
            assert x.key() == y.key()


def test_ring_roll_one_device_mesh_bit_identical_to_host():
    """Acceptance: the 1-device mesh migration round (shard_map + ppermute)
    equals the host-loop fallback bit for bit."""
    rng = np.random.default_rng(0)
    parts = rng.integers(0, 7, size=(4, 37)).astype(np.int32)
    for shift in (1, 2, 3):
        assert np.array_equal(ring_roll(parts, shift, _mesh1()),
                              ring_roll_host(parts, shift))


def test_ring_roll_semantics():
    parts = np.arange(4, dtype=np.int32)[:, None] * np.ones((1, 3), np.int32)
    out = ring_roll(parts, 1)
    # island i receives island (i-1)'s best
    assert [int(r[0]) for r in out] == [3, 0, 1, 2]


@pytest.mark.slow
def test_migration_4dev_mesh_never_worse_than_no_migration():
    """4 fake devices: collective migration stays bit-identical to the host
    ring, and the best objective is never worse than the no-migration run
    on the CI cell."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.memetic import ring_roll, ring_roll_host
        from repro.core.evolve import kaffpaE
        from repro.core.partition import edge_cut, is_feasible
        from repro.io.generators import grid2d
        assert len(jax.devices()) == 4
        mesh = Mesh(np.array(jax.devices()), ("islands",))
        rng = np.random.default_rng(1)
        for I in (4, 8):                   # 1 and 2 islands per device
            parts = rng.integers(0, 9, size=(I, 53)).astype(np.int32)
            for shift in range(1, I):
                assert np.array_equal(ring_roll(parts, shift, mesh),
                                      ring_roll_host(parts, shift)), (I, shift)
        g = grid2d(12, 12)
        mig = kaffpaE(g, 4, 0.03, "fast", n_islands=4, population=2,
                      generations=3, seed=3, mesh=mesh, migrate=True)
        nomig = kaffpaE(g, 4, 0.03, "fast", n_islands=4, population=2,
                        generations=3, seed=3, migrate=False)
        assert is_feasible(g, mig, 4, 0.03)
        assert edge_cut(g, mig) <= edge_cut(g, nomig), (
            edge_cut(g, mig), edge_cut(g, nomig))
        print("MIGRATION_OK", edge_cut(g, mig), edge_cut(g, nomig))
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MIGRATION_OK" in r.stdout, r.stdout + r.stderr


# -- kahyparE ----------------------------------------------------------------

@pytest.mark.parametrize("objective,score", [("km1", connectivity),
                                             ("cut", cut_net)])
def test_kahyparE_never_worse_than_single_run(objective, score):
    """Island 0's first member rides the single run's exact seed, and the
    driver only ever improves — memetic <= single, both objectives."""
    pe = kahyparE(HG, 4, 0.03, "fast", seed=1, objective=objective,
                  n_islands=2, population=2, generations=2)
    ps = kahypar(HG, 4, 0.03, "fast", seed=1, objective=objective)
    assert HM.is_feasible(HG, pe, 4, 0.03)
    assert score(HG, pe) <= score(HG, ps)


def test_strong_preset_member0_matches_single_run():
    """Initial population members get the preset's full V-cycle schedule
    (multilevel.population), so even at vcycles=2 presets the memetic
    result at generations=0 is bit-identical to one `kahypar` run — the
    never-worse guarantee holds at every preset."""
    hg = planted_hypergraph(100, 150, blocks=2, seed=9)
    pe = kahyparE(hg, 2, 0.03, "strong", seed=4, n_islands=1, population=1,
                  generations=0)
    ps = kahypar(hg, 2, 0.03, "strong", seed=4)
    assert np.array_equal(pe, ps)


def test_interface_kahyparE():
    objval, part = interface.kahyparE(
        HG.n, HG.m, None, None, HG.eptr, HG.eind, 4, 0.03, seed=1,
        generations=1)
    assert objval == connectivity(HG, part)
    assert HM.is_feasible(HG, part, 4, 0.03)


def test_interface_kaffpaE():
    cut, part = interface.kaffpaE(GRID.n, None, GRID.xadj, None, GRID.adjncy,
                                  4, 0.03, seed=2, generations=1)
    assert cut == edge_cut(GRID, part)
    assert is_feasible(GRID, part, 4, 0.03)


# -- kabapeE and the memetic separator mode -----------------------------------

def test_kabapeE_strictly_balanced():
    part = kabapeE(GRID, 4, eps=0.0, preset="fast", n_islands=1,
                   population=2, generations=1, seed=4)
    assert is_feasible(GRID, part, 4, 0.0)


def test_memetic_node_separator_valid_and_never_worse():
    sep, part2 = memetic_node_separator(GRID, 0.20, "fast", seed=2,
                                        n_islands=2, population=2,
                                        generations=1)
    labels = part2.copy()
    labels[sep] = SEP
    assert separator_invariant_ok(GRID, labels)
    assert separator_is_feasible(GRID, labels, 0.20)
    sep_s, _ = multilevel_node_separator(GRID, 0.20, "fast", seed=2)
    assert GRID.vwgt[sep].sum() <= GRID.vwgt[sep_s].sum()
