"""KaFFPa / refinement / LP / KaBaPE behaviour tests."""
import numpy as np
import pytest

from repro.core import lp as lp_mod
from repro.core.csr import to_coo
from repro.core.initial import random_partition, recursive_bisection
from repro.core.kabape import balance_path, kabape_refine
from repro.core.kaffpa import PRESETS, kaffpa
from repro.core.partition import (balance, edge_cut, evaluate, is_feasible)
from repro.core.refine import refine_kway, multi_try_refine
from repro.io.generators import barabasi_albert, grid2d


GRID = grid2d(16, 16)
BA = barabasi_albert(600, 3, seed=7)


def test_size_constrained_lp_respects_cap():
    clusters = lp_mod.size_constrained_lp(BA, max_cluster_weight=20, iters=6)
    sizes = np.bincount(clusters)
    assert sizes.max() <= 20
    assert len(np.unique(clusters)) < BA.n            # actually clustered


def test_refine_improves_random():
    p0 = random_partition(GRID, 4, seed=0)
    p1 = refine_kway(GRID, p0, 4, rounds=10, seed=1)
    assert edge_cut(GRID, p1) < edge_cut(GRID, p0)
    assert is_feasible(GRID, p1, 4, 0.03)


def test_refine_never_worsens():
    p = kaffpa(GRID, 4, 0.03, "fast", seed=5)
    c0 = edge_cut(GRID, p)
    p2 = refine_kway(GRID, p, 4, rounds=6, seed=9)
    assert edge_cut(GRID, p2) <= c0


def test_multi_try_refine():
    p0 = random_partition(GRID, 2, seed=3)
    p0 = refine_kway(GRID, p0, 2, rounds=6, seed=3)
    p1 = multi_try_refine(GRID, p0, 2, tries=2, rounds=6, seed=3)
    assert edge_cut(GRID, p1) <= edge_cut(GRID, p0)


@pytest.mark.parametrize("preset", list(PRESETS))
def test_kaffpa_presets_feasible(preset):
    g = BA if "social" in preset else GRID
    part = kaffpa(g, 4, 0.03, preset, seed=2)
    ev = evaluate(g, part, 4)
    assert ev["feasible"], ev
    assert ev["cut"] > 0
    # sane quality: far better than a random partition
    assert ev["cut"] < edge_cut(g, random_partition(g, 4, seed=0)) * 0.8


def test_kaffpa_input_partition_improves():
    p0 = random_partition(GRID, 4, seed=1)
    p1 = kaffpa(GRID, 4, 0.03, "fast", seed=1, input_partition=p0)
    assert edge_cut(GRID, p1) <= edge_cut(GRID, p0)


def test_kaffpa_balance_edges():
    part = kaffpa(BA, 4, 0.05, "fastsocial", seed=1, balance_edges=True)
    gb = BA.with_edge_balanced_weights()
    assert balance(gb, part, 4) <= 1.05 + 1e-6


def test_kabape_perfect_balance():
    p = kaffpa(GRID, 4, 0.03, "fast", seed=3)
    p2 = kabape_refine(GRID, p, 4, eps=0.0, seed=1)
    assert is_feasible(GRID, p2, 4, 0.0)
    assert edge_cut(GRID, p2) <= edge_cut(GRID, p) * 1.2


def test_balance_path_fixes_infeasible():
    # deliberately unbalanced partition
    p = np.zeros(GRID.n, dtype=np.int64)
    p[: GRID.n // 8] = 1
    p[GRID.n // 8: GRID.n // 4] = 2
    p[GRID.n // 4: GRID.n // 2 + 40] = 3
    p2 = balance_path(GRID, p, 4, eps=0.0)
    assert is_feasible(GRID, p2, 4, 0.0)


def test_recursive_bisection_covers_all_blocks():
    part = recursive_bisection(GRID, 5, seed=2)
    assert set(np.unique(part)) == set(range(5))


def test_capped_accept_guarantee():
    import jax.numpy as jnp
    import jax
    coo = to_coo(GRID)
    n = coo.n_pad
    labels = jnp.zeros((n,), jnp.int32)
    proposal = jnp.ones((n,), jnp.int32)     # everyone wants block 1
    sizes = jnp.zeros((2,), jnp.float32).at[0].add(float(GRID.n))
    cap = jnp.array([300.0, 50.0])
    pri = jnp.arange(n, dtype=jnp.float32)
    out = lp_mod.capped_accept(labels, proposal, coo.vwgt, sizes, cap, pri)
    inflow = float(coo.vwgt[np.asarray(out) == 1].sum())
    assert inflow <= 50.0
