"""Training loop, checkpoint/restart, fault tolerance, serving."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train.checkpoint import latest_step, restore, save
from repro.train.data import DataConfig, batches, synthetic_tokens
from repro.train.fault import Watchdog, run_resilient
from repro.train.optimizer import OptConfig, schedule_lr
from repro.train.pipeline import partition_layers
from repro.train.train_step import init_opt_state, make_train_step

CFG = get_config("minicpm_2b").reduced()
OPT = OptConfig(peak_lr=2e-3, warmup_steps=5, stable_steps=60, decay_steps=10)
DC = DataConfig(vocab=CFG.vocab, seq_len=24, global_batch=8)


@pytest.fixture(scope="module")
def step_fn():
    return jax.jit(make_train_step(CFG, OPT, remat="full"))


def test_loss_falls(step_fn):
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    it = batches(DC)
    losses = []
    for _ in range(40):
        params, opt, m = step_fn(params, opt, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses[::10]


def test_wsd_schedule_shape():
    lrs = [float(schedule_lr(OPT, jnp.int32(s))) for s in range(90)]
    assert lrs[2] < lrs[10]                     # warmup
    assert abs(lrs[30] - OPT.peak_lr) < 1e-9    # stable plateau
    assert lrs[-1] < 0.3 * OPT.peak_lr          # sharp decay


def test_data_determinism_and_sharding():
    a = synthetic_tokens(3, 0, 2, DC)
    b = synthetic_tokens(3, 0, 2, DC)
    c = synthetic_tokens(3, 1, 2, DC)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (DC.global_batch // 2, DC.seq_len + 1)


def test_checkpoint_atomic_roundtrip(tmp_path, step_fn):
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    d = str(tmp_path / "ck")
    save(d, 5, (params, opt))
    save(d, 10, (params, opt))
    assert latest_step(d) == 10
    (p2, o2), manifest = restore(d, (params, opt))
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure mismatch refused
    with pytest.raises(ValueError):
        restore(d, (params,))


def test_fault_injection_restart_reproduces(tmp_path, step_fn):
    data_fn = lambda start: batches(DC, start_step=start)  # noqa: E731
    p0 = T.init_params(CFG, jax.random.PRNGKey(0))
    pA, _, info = run_resilient(step_fn, p0, init_opt_state(p0), data_fn,
                                15, str(tmp_path / "a"), ckpt_every=5,
                                fail_at=8)
    assert info["restarts"] == 1
    p1 = T.init_params(CFG, jax.random.PRNGKey(0))
    pB, _, _ = run_resilient(step_fn, p1, init_opt_state(p1), data_fn,
                             15, str(tmp_path / "b"), ckpt_every=5)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_watchdog_flags_stragglers():
    wd = Watchdog(straggler_factor=2.0)
    for _ in range(10):
        wd.observe(0.1)
    assert wd.observe(0.5)
    assert not wd.observe(0.11)


def test_grad_compression_error_feedback():
    from repro.train.train_step import _compress_int8
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # over steps, error feedback keeps the running sum unbiased
    for _ in range(20):
        deq, err = _compress_int8(g, err)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g),
                               atol=0.05)


def test_pipeline_partition_balanced():
    stage = partition_layers(get_config("mistral_large_123b"), 8)
    sizes = np.bincount(stage, minlength=8)
    assert sizes.max() - sizes.min() <= 1
    # contiguity
    assert np.all(np.diff(stage) >= 0)


def test_serving_continuous_batching():
    from repro.serve.batching import serve_requests
    cfg = get_config("minicpm_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [1], [2, 3]]
    reqs = serve_requests(params, cfg, prompts, batch_slots=2, max_len=32,
                          max_new=4)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_pad for r in reqs for t in r.out)


def test_prefill_then_decode():
    from repro.serve.serve_step import prefill_step, decode_step
    cfg = get_config("gemma2_9b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, S + 4)
    last, caches = prefill_step(params, cfg, tokens, caches)
    lg, caches = decode_step(params, cfg,
                             jnp.argmax(last, -1)[:, None].astype(jnp.int32),
                             caches, jnp.int32(S))
    assert lg.shape == (B, cfg.vocab_pad)
    # must equal the full-forward logits at the same position
    full, _ = T.forward(params, cfg, jnp.concatenate(
        [tokens, jnp.argmax(last, -1)[:, None].astype(jnp.int32)], axis=1))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


# -- continuous-batching slot accounting (regression: prefill once leaked
# -- into every slot's cache, and decode shared one position cursor) --------

@pytest.fixture(scope="module")
def serve_params():
    return T.init_params(CFG, jax.random.PRNGKey(0))


def _solo(params, prompt, max_new, max_len=32):
    """Reference: the same request served alone (one slot, empty pool)."""
    from repro.serve.batching import serve_requests
    (req,) = serve_requests(params, CFG, [prompt], batch_slots=1,
                            max_len=max_len, max_new=max_new)
    return req.out


def test_batcher_slot_isolation_matches_solo(serve_params):
    # heterogeneous prompt lengths decoding concurrently must produce the
    # same tokens as each request alone — pins per-slot cache views and
    # per-slot position cursors
    from repro.serve.batching import serve_requests
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [1], [2, 3, 4, 5, 6]]
    refs = [_solo(serve_params, p, 6) for p in prompts]
    reqs = serve_requests(serve_params, CFG, prompts, batch_slots=3,
                          max_len=32, max_new=6)
    assert all(r.done for r in reqs)
    for r, ref in zip(reqs, refs):
        assert r.out == ref, (r.rid, r.out, ref)


def test_batcher_budget_and_capacity_edges(serve_params):
    from repro.serve.batching import ContinuousBatcher, Request, \
        serve_requests
    # max_new=1: exactly the prefill token, slot never occupied afterwards
    reqs = serve_requests(serve_params, CFG, [[1, 2], [3, 4]],
                          batch_slots=2, max_len=32, max_new=1)
    assert all(r.done and len(r.out) == 1 for r in reqs)
    # max_new=0: done immediately, nothing generated
    reqs = serve_requests(serve_params, CFG, [[1, 2]], batch_slots=2,
                          max_len=32, max_new=0)
    assert reqs[0].done and reqs[0].out == []
    # generation stops at cache capacity even with budget left
    reqs = serve_requests(serve_params, CFG, [list(range(1, 13))],
                          batch_slots=1, max_len=16, max_new=50)
    assert reqs[0].done and len(reqs[0].out) == 16 - 12
    # a prompt that cannot fit is rejected loudly, not silently clobbered
    b = ContinuousBatcher(serve_params, CFG, 1, max_len=8)
    with pytest.raises(ValueError):
        b.add(Request(0, np.arange(1, 10, dtype=np.int32), max_new=4))


def test_batcher_slot_reuse_after_done(serve_params):
    # 5 requests through 2 slots: later requests re-use slots freed by
    # earlier ones and must still match their solo outputs
    from repro.serve.batching import serve_stream
    stream = [(0, [1, 2, 3], 2), (0, [4, 5], 5), (1, [6, 7, 8], 3),
              (4, [9, 1], 4), (6, [2, 2, 2, 2], 2)]
    refs = [_solo(serve_params, p, mn) for _, p, mn in stream]
    reqs = serve_stream(serve_params, CFG, stream, batch_slots=2,
                        max_len=32)
    assert all(r.done for r in reqs)
    for r, ref in zip(reqs, refs):
        assert r.out == ref, (r.rid, r.out, ref)


def test_batcher_telemetry_output_identical(serve_params):
    from repro import obs
    from repro.serve.batching import serve_requests
    prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
    plain = serve_requests(serve_params, CFG, prompts, batch_slots=2,
                           max_len=32, max_new=4)
    rec = obs.Recorder("serve")
    tele = obs.ServeTelemetry(recorder=rec)
    traced = serve_requests(serve_params, CFG, prompts, batch_slots=2,
                            max_len=32, max_new=4, telemetry=tele)
    assert [r.out for r in traced] == [r.out for r in plain]
    # every request span on a slot track opened and closed
    evs = [e for e in rec.events if e.get("track", "").startswith("slot")]
    assert sum(e["ph"] == "B" for e in evs) == \
        sum(e["ph"] == "E" for e in evs) > 0
