# Convenience targets; `make verify` is the tier-1 gate from ROADMAP.md.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify test bench bench-smoke example-hypergraph

verify:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q

bench:
	$(PY) benchmarks/run.py

bench-smoke:
	$(PY) benchmarks/run.py --smoke

example-hypergraph:
	$(PY) examples/hypergraph_partition.py
