# Convenience targets; `make verify` is the tier-1 gate from ROADMAP.md.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: verify test analyze bench bench-smoke example-hypergraph

verify:
	$(PY) -m pytest -x -q

# static analysis gate (DESIGN.md §14): trace every registered entry point,
# run the four jaxpr checkers + source lints, fail on findings not in the
# committed baseline
analyze:
	$(PY) -m repro.analysis --out analysis_findings.jsonl \
		--baseline ANALYSIS_BASELINE.json

test:
	$(PY) -m pytest -q

bench:
	$(PY) benchmarks/run.py

bench-smoke:
	$(PY) benchmarks/run.py --smoke

example-hypergraph:
	$(PY) examples/hypergraph_partition.py
