"""End-to-end training driver (deliverable b): train a ~100M-param model for
a few hundred steps on CPU with checkpoint/restart and straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--dim 512]

The config is a scaled minicpm (llama-like) — ~100M params at --dim 512.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train.data import DataConfig, batches
from repro.train.fault import run_resilient
from repro.train.optimizer import OptConfig
from repro.train.train_step import init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (recovery demo)")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("minicpm_2b"),
        n_layers=args.layers, d_model=args.dim,
        n_heads=args.dim // 64, n_kv_heads=args.dim // 64,
        d_ff=4 * args.dim, vocab=8192)
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params, {cfg.n_layers}L d={cfg.d_model}")

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(peak_lr=3e-4, warmup_steps=20,
                        stable_steps=args.steps - 60, decay_steps=40)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="full"))
    opt = init_opt_state(params)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    data_fn = lambda start: batches(dc, start_step=start)  # noqa: E731

    t0 = time.time()
    log = lambda msg: print(f"[{time.time()-t0:7.1f}s] {msg}", flush=True)  # noqa: E731
    params, opt, info = run_resilient(
        step_fn, params, opt, data_fn, args.steps, args.ckpt,
        ckpt_every=50, fail_at=args.fail_at, log=log)
    print(f"done: {info}")


if __name__ == "__main__":
    main()
