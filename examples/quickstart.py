"""Quickstart: partition a graph with every major KaHIP entry point.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.io.generators import grid2d, barabasi_albert
from repro.io.metis import write_metis, write_partition, graphchecker
from repro.core.kaffpa import kaffpa
from repro.core.kabape import kabape_refine
from repro.core.partition import evaluate
from repro.core.separator import node_separator
from repro.core.edgepart import edge_partition
from repro.core.partition import edge_partition_metrics
from repro.core import interface as api


def main():
    g = grid2d(32, 32)
    print(f"mesh graph: n={g.n} m={g.m}")

    # --- kaffpa presets (paper §4.1)
    for preset in ("fast", "eco", "strong"):
        part = kaffpa(g, 4, eps=0.03, preset=preset, seed=1)
        print(f"kaffpa --preconfiguration={preset:7s}:",
              evaluate(g, part, 4))

    # --- perfectly balanced (KaBaPE, §2.3)
    part0 = kaffpa(g, 4, 0.03, "fast", seed=1)
    part_b = kabape_refine(g, part0, 4, eps=0.0)
    print("kabape eps=0:", evaluate(g, part_b, 4, eps=0.0))

    # --- social preset on a scale-free graph (§2.4)
    b = barabasi_albert(2048, 4, seed=1)
    part_s = kaffpa(b, 8, 0.03, "fastsocial", seed=1)
    print("kaffpa fastsocial on BA graph:", evaluate(b, part_s, 8))

    # --- node separator (§2.8)
    sep, two = node_separator(g, eps=0.2, preset="fast", seed=1)
    print(f"2-way node separator: {len(sep)} vertices")

    # --- edge partition (§2.7)
    ep = edge_partition(g, 4, preset="fast", seed=1)
    print("SPAC edge partition:", edge_partition_metrics(g, ep, 4))

    # --- file formats + checker (§3)
    write_metis(g, "/tmp/quickstart.graph")
    assert graphchecker("/tmp/quickstart.graph") == []
    write_partition(part0, "/tmp/tmppartition4")
    print("wrote /tmp/quickstart.graph + /tmp/tmppartition4 (metis formats)")

    # --- the C-style library interface (§5)
    cut, part = api.kaffpa(g.n, None, g.xadj, None, g.adjncy,
                           nparts=2, imbalance=0.03, seed=0, mode=api.ECO)
    print(f"library kaffpa(k=2): edgecut={cut}")

    # --- observability (DESIGN.md §11): spans, counters, trajectories
    from repro import obs
    rec = obs.Recorder("quickstart")
    cut, part = api.kaffpa(g.n, None, g.xadj, None, g.adjncy,
                           nparts=4, imbalance=0.03, seed=0, mode=api.ECO,
                           report=rec)
    print(f"recorded run: edgecut={cut} compiles={rec.compile_count} "
          f"cycles={rec.trajectory('cycles')}")
    obs.write_chrome_trace(rec, "/tmp/quickstart_trace.json")
    print("wrote /tmp/quickstart_trace.json (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
