"""Process mapping end-to-end (paper §2.6 + DESIGN.md §3):

1. partition an application graph into k = prod(hierarchy) blocks,
2. map blocks onto the hierarchical machine (global multisection + swaps),
3. ALSO: map the LM train step's collective traffic onto the TPU pod
   hierarchy — the paper's technique steering the ML framework's mesh.

    PYTHONPATH=src python examples/partition_and_map.py
"""
import numpy as np

from repro.core.mapping import (kaffpa_with_mapping, process_mapping,
                                processor_distance_matrix, qap_cost)
from repro.io.generators import random_geometric
from repro.launch.topology import choose_axis_assignment


def main():
    # --- application graph → hierarchical machine (4 cores × 4 chips × 2)
    g = random_geometric(2048, seed=1)
    part, mapping, qap = kaffpa_with_mapping(g, "4:4:2", "1:10:100",
                                             eps=0.03, preset="eco", seed=1)
    print(f"kaffpa --enable_mapping: QAP cost {qap}")

    # --- synthetic comm matrix: ring-heavy + random background
    k = 32
    rng = np.random.default_rng(0)
    comm = np.zeros((k, k), dtype=np.int64)
    for p in range(k):
        comm[p, (p + 1) % k] = comm[(p + 1) % k, p] = 200
    mapping = process_mapping(comm, "4:4:2", "1:10:100", seed=0)
    dist = processor_distance_matrix([4, 4, 2], [1, 10, 100])
    print(f"ring pattern: mapped QAP {qap_cost(comm, dist, mapping)} "
          f"vs identity {qap_cost(comm, dist, np.arange(k))}")

    # --- LM integration: which mesh axis goes on which hardware level?
    # per-axis collective bytes as the dry-run measures them (example values
    # from minicpm train_4k: FSDP all-gathers dominate on 'data')
    axis_bytes = {"data": 4.1e9, "model": 0.9e9, "pod": 0.4e9}
    axis_sizes = {"data": 16, "model": 16, "pod": 2}
    out = choose_axis_assignment(axis_bytes, axis_sizes,
                                 hierarchy=(16, 16, 2),
                                 distances=(1, 10, 100), seed=0)
    print(f"mesh-axis mapping: QAP {out['qap']} vs identity "
          f"{out['identity_qap']} (improvement {out['improvement']:.1%})")


if __name__ == "__main__":
    main()
