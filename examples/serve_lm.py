"""Serving example (deliverable b): continuous batching over a bursty
request stream with prefill + decode steps, per-slot cursors and live
telemetry (DESIGN.md §13).

    PYTHONPATH=src python examples/serve_lm.py

Writes ``serve_trace.json`` — open it in https://ui.perfetto.dev to see
one timeline row per batcher slot (request → prefill/decode spans,
per-token instants) with queue-depth / tok-per-s counter tracks.
"""
import json

import jax
import numpy as np

from repro import obs
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve.batching import serve_stream


def main():
    cfg = get_config("minicpm_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # bursty arrivals: (tick, prompt, max_new)
    stream = [(int(rng.integers(0, 12)),
               rng.integers(1, cfg.vocab, rng.integers(2, 10)).tolist(),
               6)
              for _ in range(9)]

    rec = obs.Recorder("serve")
    tele = obs.ServeTelemetry(recorder=rec)
    reqs = serve_stream(params, cfg, stream, batch_slots=3, max_len=64,
                        telemetry=tele)
    for r in reqs:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")

    snap = tele.snapshot()
    lat = {k: round(v["p50"], 1) for k, v in snap["latency_us"].items()}
    print(f"{snap['total_requests']} requests, {snap['total_tokens']} "
          f"tokens in {snap['steps']} decode steps")
    print(f"p50 latency (us): {json.dumps(lat)}")
    print(f"throughput: {snap['tok_per_s_window']:.1f} tok/s (window), "
          f"{snap['tok_per_s_ewma']:.1f} tok/s (ewma)")
    n = obs.write_chrome_trace(rec, "serve_trace.json",
                               registry_gauges=True)
    print(f"wrote serve_trace.json ({n} events) — open in ui.perfetto.dev")


if __name__ == "__main__":
    main()
