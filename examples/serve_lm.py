"""Serving example (deliverable b): continuous batching over a request queue
with prefill + decode steps and per-slot cursors.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve.batching import serve_requests


def main():
    cfg = get_config("minicpm_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, rng.integers(2, 10)).tolist()
               for _ in range(9)]
    t0 = time.time()
    reqs = serve_requests(params, cfg, prompts, batch_slots=3,
                          max_len=64, max_new=6)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s on 1 CPU core, 3 slots)")


if __name__ == "__main__":
    main()
