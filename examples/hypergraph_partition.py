"""Hypergraph partitioning quickstart (repro.core.hypergraph):

1. generate a planted-partition hypergraph (2k vertices / 3k nets — the
   data-placement workload shape: nets = co-access sets),
2. partition it with the multilevel kahypar driver for k ∈ {2, 4, 8},
3. compare the connectivity (λ−1) objective against random assignment and
   round-trip the instance through the hMETIS text format.

    PYTHONPATH=src python examples/hypergraph_partition.py
"""
import os
import tempfile
import time

from repro.core.hypergraph import connectivity, evaluate, kahypar
from repro.core.hypergraph.initial import random_partition
from repro.io import hmetis
from repro.io.generators import planted_hypergraph


def main():
    hg = planted_hypergraph(2048, 3072, blocks=8, seed=0)
    print(f"hypergraph: {hg.n} vertices, {hg.m} nets, {hg.pins} pins")

    for k in (2, 4, 8):
        t0 = time.time()
        part = kahypar(hg, k, eps=0.03, preset="eco", seed=1)
        dt = time.time() - t0
        ev = evaluate(hg, part, k)
        rnd = connectivity(hg, random_partition(hg, k, seed=0))
        print(f"k={k}: (λ-1)={ev['km1']} cut-net={ev['cut_net']} "
              f"balance={ev['balance']:.3f} feasible={ev['feasible']} "
              f"| random (λ-1)={rnd} ({rnd / max(ev['km1'], 1):.1f}x worse) "
              f"| {dt:.1f}s")

    # hMETIS round trip — the on-disk interchange format
    path = os.path.join(tempfile.mkdtemp(), "planted.hgr")
    hmetis.write_hmetis(hg, path)
    h2 = hmetis.read_hmetis(path)
    print(f"hMETIS round-trip: {path} "
          f"({h2.m} nets, {h2.n} vertices, checker={h2.check()})")


if __name__ == "__main__":
    main()
